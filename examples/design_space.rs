//! A fast tour of the paper's design-space axes (Fig. 7 in miniature):
//! the I_sat/I_max ratio, the mismatch sigma_VT, beta resolution and
//! counter resolution. The full studies live in the bench targets.
//!
//!     cargo run --release --example design_space

use velm::bench::Table;
use velm::dse::{self, lmin, FastSim};

fn main() {
    let threads = dse::default_threads();

    println!("1. regression error vs I_sat^z/I_max^z (L = 64, paper optimum ~ 0.75)");
    let ratios = vec![0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.5];
    let errs = dse::par_map(ratios.clone(), threads, |r| {
        lmin::mean_error(&FastSim { ratio: r, ..Default::default() }, 64, 600, 3, 17)
    });
    let mut t = Table::new(&["ratio", "sinc RMSE"]);
    for (r, e) in ratios.iter().zip(&errs) {
        t.row(&[format!("{r:.2}"), format!("{e:.4}")]);
    }
    t.print();

    println!("\n2. regression error vs sigma_VT at the optimal ratio (paper: 15-25 mV best)");
    let sigmas = vec![0.002, 0.005, 0.010, 0.016, 0.020, 0.025, 0.035, 0.045];
    let errs = dse::par_map(sigmas.clone(), threads, |s| {
        lmin::mean_error(&FastSim { sigma_vt: s, ..Default::default() }, 64, 600, 3, 23)
    });
    let mut t = Table::new(&["sigma_VT (mV)", "sinc RMSE"]);
    for (s, e) in sigmas.iter().zip(&errs) {
        t.row(&[format!("{:.0}", s * 1e3), format!("{e:.4}")]);
    }
    t.print();

    println!("\n3. L_min to reach error 0.08 at the 0.75 ratio, per sigma_VT");
    let sigmas = vec![0.005, 0.016, 0.025, 0.045];
    let lmins = dse::par_map(sigmas.clone(), threads, |s| {
        lmin::l_min(
            &FastSim { sigma_vt: s, ..Default::default() },
            &lmin::default_l_grid(),
            0.08,
            600,
            3,
            31,
        )
    });
    let mut t = Table::new(&["sigma_VT (mV)", "L_min"]);
    for (s, l) in sigmas.iter().zip(&lmins) {
        t.row(&[
            format!("{:.0}", s * 1e3),
            l.map_or(">256".to_string(), |v| v.to_string()),
        ]);
    }
    t.print();
    println!("\nfull sweeps: cargo bench --bench fig7_design_space");
}
