//! Fig. 16: regress the underlying sinc function from noisy samples
//! through the chip's first stage (Section VI-C).
//!
//!     cargo run --release --example sinc_regression
//!
//! Paper: error 0.021 with L = 128 on-chip vs 0.01 in software.

use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, softelm::SoftElm, ChipHidden};

fn main() -> anyhow::Result<()> {
    let ds = synth::sinc(5000, 500, 0.2, 3);
    println!(
        "sinc regression: {} noisy train samples (sigma = 0.2), {} clean test points",
        ds.n_train(),
        ds.n_test()
    );

    // hardware: d = 1, L = 128 through the chip
    let cfg = ChipConfig::default().with_dims(1, 128).with_b(12);
    let mut hw = ChipHidden::new(ChipModel::fabricate(cfg, 11));
    let (model, _) = elm::train_model(&mut hw, &ds.train_x, &ds.train_y, 1e-4, 14, false)
        .map_err(anyhow::Error::msg)?;
    let hw_err = elm::eval_regression(&mut hw, &model, &ds.test_x, &ds.test_y);

    // software baseline
    let mut soft = SoftElm::with_scale(1, 128, 10.0, 12);
    let (sw_model, _) = elm::train_model(&mut soft, &ds.train_x, &ds.train_y, 1e-4, 32, false)
        .map_err(anyhow::Error::msg)?;
    let sw_err = elm::eval_regression(&mut soft, &sw_model, &ds.test_x, &ds.test_y);

    println!("hardware RMSE vs clean sinc: {hw_err:.4}  (paper: 0.021)");
    println!("software RMSE vs clean sinc: {sw_err:.4}  (paper: ~0.01)");

    // a small ASCII rendering of the regression (Fig. 16 flavour)
    println!("\n   x      sinc(x)   predicted");
    for k in 0..11 {
        let x = -10.0 + 2.0 * k as f64;
        let clean = if x.abs() < 1e-12 { 1.0 } else { x.sin() / x };
        let h = velm::elm::train::HiddenLayer::transform(&mut hw, &[x / 10.0]);
        let pred: f64 = h.iter().zip(&model.head.beta).map(|(a, b)| a * b).sum();
        println!("{x:+6.1}   {clean:+.4}    {pred:+.4}");
    }
    println!(
        "\nchip ledger: {} conversions, {:.3} pJ/MAC",
        hw.chip.ledger.conversions,
        hw.chip.ledger.pj_per_mac()
    );
    Ok(())
}
