//! Section V / VI-D: the weight-reuse rotation technique.
//!
//!     cargo run --release --example dimension_extension
//!
//! Two demonstrations, matching the paper's measurements:
//!  1. leukemia (d = 7129) classified through a 128-channel die via
//!     input-dimension extension (paper: 20.59% with L = 128);
//!  2. diabetes with a deliberately tiny L = 16 die expanded to a
//!     virtual L = 128 (paper: 27.1% -> 22.4%).

use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm;
use velm::extension::VirtualChip;

fn main() -> anyhow::Result<()> {
    // --- 1. input-dimension extension: leukemia d = 7129 ---------------
    let ds = synth::leukemia(5);
    println!(
        "leukemia: d = {}, {} train / {} test",
        ds.d(),
        ds.n_train(),
        ds.n_test()
    );
    let cfg = ChipConfig::default().with_dims(128, 128).with_b(10);
    let chip = ChipModel::fabricate(cfg.clone(), 21);
    let mut vchip = VirtualChip::new(chip, ds.d(), 128).map_err(anyhow::Error::msg)?;
    println!(
        "virtual projection: 128x128 die -> {}x128 via {} chip passes per sample",
        ds.d(),
        vchip.plan.passes()
    );
    let (model, h) = elm::train_model(&mut vchip, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .map_err(anyhow::Error::msg)?;
    let train_err =
        elm::train::misclassification(&elm::train::predict(&h, &model.head), &ds.train_y);
    let test_err = elm::eval_classification(&mut vchip, &model, &ds.test_x, &ds.test_y);
    println!(
        "leukemia: train {:.1}%, test {:.1}% (paper hardware: 20.59%, software: 19.92%)\n",
        train_err * 100.0,
        test_err * 100.0
    );

    // --- 2. hidden-layer extension: diabetes L = 16 -> 128 -------------
    let ds = synth::diabetes(6);
    let small_cfg = ChipConfig::default().with_dims(ds.d(), 16).with_b(10);
    // small die used as-is
    let mut small = elm::ChipHidden::new(ChipModel::fabricate(small_cfg.clone(), 22));
    let (m16, _) = elm::train_model(&mut small, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .map_err(anyhow::Error::msg)?;
    let err16 = elm::eval_classification(&mut small, &m16, &ds.test_x, &ds.test_y);
    // same die expanded to a virtual L = 128 by row rotation
    let mut expanded = VirtualChip::new(ChipModel::fabricate(small_cfg, 22), ds.d(), 128)
        .map_err(anyhow::Error::msg)?;
    let (m128, _) = elm::train_model(&mut expanded, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .map_err(anyhow::Error::msg)?;
    let err128 = elm::eval_classification(&mut expanded, &m128, &ds.test_x, &ds.test_y);
    println!(
        "diabetes: L=16 error {:.1}% -> virtual L=128 error {:.1}% \
         (paper: 27.1% -> 22.4%)",
        err16 * 100.0,
        err128 * 100.0
    );
    println!(
        "hidden extension reuses the same {} physical weights {} times per sample",
        16 * ds.d(),
        expanded.plan.hidden_blocks()
    );
    Ok(())
}
