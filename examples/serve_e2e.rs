//! End-to-end serving driver (the DESIGN.md §8 pipeline, all layers
//! composed): fabricate a multi-die system, train each die in the loop,
//! bring up the TCP front end, fire concurrent client load through real
//! sockets, and report accuracy + latency/throughput, comparing the
//! PJRT-batched hot path against the scalar chip simulator.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Works without artifacts too (falls back to the chip simulator).
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use velm::cli::Args;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_usize("requests", 2000).map_err(anyhow::Error::msg)?;
    let n_clients = args.get_usize("clients", 8).map_err(anyhow::Error::msg)?;
    let ds = synth::brightdata(1);
    let mut chip_cfg = ChipConfig::default().with_b(10);
    chip_cfg.d = ds.d();
    let mut sys = SystemConfig::default();
    sys.n_chips = args.get_usize("chips", 2).map_err(anyhow::Error::msg)?;
    sys.artifact_dir = args.get_or("artifacts", "artifacts");
    sys.pjrt_min_batch = args.get_usize("pjrt-min-batch", 4).map_err(anyhow::Error::msg)?;
    sys.max_wait = std::time::Duration::from_micros(
        args.get_u64("max-wait-us", 1000).map_err(anyhow::Error::msg)?,
    );

    // NOTE: the compiled hidden artifacts are 128-wide; brightdata is
    // d=14, so the serving path below exercises the chip simulator for
    // the hidden stage unless d matches. To exercise PJRT, we pad the
    // feature space to the physical 128 channels (extra channels at -1
    // = code 0, which the S2 switch shuts off — exact).
    let pad = |x: &Vec<f64>| {
        let mut p = vec![-1.0; 128];
        p[..x.len()].copy_from_slice(x);
        p
    };
    let train_x: Vec<Vec<f64>> = ds.train_x.iter().map(pad).collect();
    let test_x: Vec<Vec<f64>> = ds.test_x.iter().map(pad).collect();
    chip_cfg.d = 128;

    println!(
        "training {} dies chip-in-the-loop on {} samples ...",
        sys.n_chips,
        train_x.len()
    );
    let t_train = Instant::now();
    let coord = Arc::new(Coordinator::start(
        &sys, &chip_cfg, &train_x, &ds.train_y, 0.1, 10,
    )?);
    println!("trained in {:.1} s", t_train.elapsed().as_secs_f64());

    // bring up the real TCP front end on an ephemeral port
    let (addr, srv) = server::serve_n(Arc::clone(&coord), n_clients)?;
    println!("serving on {addr}; firing {n_requests} requests from {n_clients} clients");

    let t0 = Instant::now();
    let correct: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let test_x = &test_x;
            let test_y = &ds.test_y;
            handles.push(s.spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_nodelay(true).expect("nodelay");
                let mut writer = stream.try_clone().expect("clone");
                let mut reader = BufReader::new(stream);
                let mut correct = 0usize;
                let per_client = n_requests / n_clients;
                for k in 0..per_client {
                    let idx = (c * per_client + k) % test_x.len();
                    let line: Vec<String> =
                        test_x[idx].iter().map(|v| format!("{v}")).collect();
                    writeln!(writer, "CLASSIFY {}", line.join(",")).expect("write");
                    let mut resp = String::new();
                    reader.read_line(&mut resp).expect("read");
                    let label: f64 = resp
                        .trim()
                        .split_whitespace()
                        .nth(1)
                        .and_then(|t| t.parse().ok())
                        .unwrap_or(0.0);
                    if (label - test_y[idx]).abs() < 1e-9 {
                        correct += 1;
                    }
                }
                writeln!(writer, "QUIT").ok();
                correct
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let wall = t0.elapsed().as_secs_f64();
    let served = (n_requests / n_clients) * n_clients;
    println!("\n=== E2E results ===");
    println!(
        "accuracy: {:.2}% error over {served} requests",
        (1.0 - correct as f64 / served as f64) * 100.0
    );
    println!(
        "throughput: {:.0} classifications/s over TCP (paper chip: 31.6 kHz analog conversion rate)",
        served as f64 / wall
    );
    println!("metrics: {}", coord.metrics.report());
    println!(
        "hidden-layer MAC throughput: {:.1} MMAC/s wall-clock (paper: 404.5 MMAC/s)",
        served as f64 * (128.0 * 128.0) / wall / 1e6
    );
    srv.join();
    Ok(())
}
