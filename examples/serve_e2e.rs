//! End-to-end serving driver (the DESIGN.md §8 pipeline, all layers
//! composed): fabricate a multi-die system, train each die in the loop,
//! bring up the TCP front end, fire concurrent client load through the
//! typed client SDK (DESIGN.md §15), and report accuracy +
//! latency/throughput, comparing the PJRT-batched hot path against the
//! scalar chip simulator.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Works without artifacts too (falls back to the chip simulator).
//! Clients speak the v1 framed protocol and ship `--batch`-row
//! `BatchPredict` frames — one wire round-trip and ONE batcher
//! submission per chunk, which is what lets the per-worker dynamic
//! batcher amortise the hidden-layer pass. `--v0` switches every client
//! to the ASCII line protocol (one round-trip per row) for an A/B of
//! the two wire formats. Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::Instant;

use velm::cli::Args;
use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;
use velm::protocol::PredictRow;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_usize("requests", 2000).map_err(anyhow::Error::msg)?;
    let n_clients = args.get_usize("clients", 8).map_err(anyhow::Error::msg)?;
    let batch = args.get_usize("batch", 25).map_err(anyhow::Error::msg)?.max(1);
    let v0 = args.flag("v0");
    let ds = synth::brightdata(1);
    let mut chip_cfg = ChipConfig::default().with_b(10);
    chip_cfg.d = ds.d();
    let mut sys = SystemConfig::default();
    sys.n_chips = args.get_usize("chips", 2).map_err(anyhow::Error::msg)?;
    sys.artifact_dir = args.get_or("artifacts", "artifacts");
    sys.pjrt_min_batch = args.get_usize("pjrt-min-batch", 4).map_err(anyhow::Error::msg)?;
    sys.max_wait = std::time::Duration::from_micros(
        args.get_u64("max-wait-us", 1000).map_err(anyhow::Error::msg)?,
    );

    // NOTE: the compiled hidden artifacts are 128-wide; brightdata is
    // d=14, so the serving path below exercises the chip simulator for
    // the hidden stage unless d matches. To exercise PJRT, we pad the
    // feature space to the physical 128 channels (extra channels at -1
    // = code 0, which the S2 switch shuts off — exact).
    let pad = |x: &Vec<f64>| {
        let mut p = vec![-1.0; 128];
        p[..x.len()].copy_from_slice(x);
        p
    };
    let train_x: Vec<Vec<f64>> = ds.train_x.iter().map(pad).collect();
    let test_x: Vec<Vec<f64>> = ds.test_x.iter().map(pad).collect();
    chip_cfg.d = 128;

    println!(
        "training {} dies chip-in-the-loop on {} samples ...",
        sys.n_chips,
        train_x.len()
    );
    let t_train = Instant::now();
    let coord = Arc::new(Coordinator::start(
        &sys, &chip_cfg, &train_x, &ds.train_y, 0.1, 10,
    )?);
    println!("trained in {:.1} s", t_train.elapsed().as_secs_f64());

    // bring up the real TCP front end on an ephemeral port
    let (addr, srv) = server::serve_n(Arc::clone(&coord), n_clients)?;
    println!(
        "serving on {addr}; firing {n_requests} requests from {n_clients} clients \
         ({} wire, {batch}-row batches)",
        if v0 { "v0 line" } else { "v1 framed" }
    );

    let t0 = Instant::now();
    let correct: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let test_x = &test_x;
            let test_y = &ds.test_y;
            handles.push(s.spawn(move || {
                let mut client = if v0 {
                    Client::connect_v0(addr).expect("connect v0")
                } else {
                    Client::connect(addr).expect("connect v1")
                };
                let mut correct = 0usize;
                let per_client = n_requests / n_clients;
                let idxs: Vec<usize> = (0..per_client)
                    .map(|k| (c * per_client + k) % test_x.len())
                    .collect();
                for chunk in idxs.chunks(batch) {
                    let rows: Vec<PredictRow> = chunk
                        .iter()
                        .map(|&i| PredictRow { tenant: None, features: test_x[i].clone() })
                        .collect();
                    // v1: one frame + one batcher submission per chunk;
                    // v0: the SDK degrades to one round-trip per row
                    let preds = client.predict_batch(&rows).expect("predict");
                    for (p, &i) in preds.iter().zip(chunk) {
                        if (p.label as f64 - test_y[i]).abs() < 1e-9 {
                            correct += 1;
                        }
                    }
                }
                correct
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let wall = t0.elapsed().as_secs_f64();
    let served = (n_requests / n_clients) * n_clients;
    println!("\n=== E2E results ===");
    println!(
        "accuracy: {:.2}% error over {served} requests",
        (1.0 - correct as f64 / served as f64) * 100.0
    );
    println!(
        "throughput: {:.0} classifications/s over TCP (paper chip: 31.6 kHz analog conversion rate)",
        served as f64 / wall
    );
    println!("metrics: {}", coord.metrics.report());
    println!(
        "hidden-layer MAC throughput: {:.1} MMAC/s wall-clock (paper: 404.5 MMAC/s)",
        served as f64 * (128.0 * 128.0) / wall / 1e6
    );
    srv.join();
    Ok(())
}
