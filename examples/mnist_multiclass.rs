//! Multi-class digit classification through the chip — the paper's
//! stated future work ("classify multi-class image datasets such as
//! MNIST"), on the synthetic 8x8 digits stand-in.
//!
//!     cargo run --release --example mnist_multiclass
//!
//! One-vs-all output weights (Section II), 10-bit fixed-point second
//! stage, chip-in-the-loop training.

use velm::chip::{dac, ChipModel};
use velm::config::ChipConfig;
use velm::datasets::digits;
use velm::elm::multiclass::{eval_multiclass, train_multiclass};
use velm::elm::{train::HiddenLayer, ChipHidden};

fn main() -> anyhow::Result<()> {
    let (ds, train_labels, test_labels) = digits::digits(1500, 500, 7);
    println!(
        "digits: {} train / {} test, d = {} (8x8), 10 classes",
        ds.n_train(),
        ds.n_test(),
        ds.d()
    );
    let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 31));
    let (head, h) = train_multiclass(&mut hidden, &ds.train_x, &train_labels, 10, 0.1)
        .map_err(anyhow::Error::msg)?;
    // train error from the assembled H
    let mut wrong = 0usize;
    for i in 0..ds.n_train() {
        if head.predict(h.row(i)) != train_labels[i] {
            wrong += 1;
        }
    }
    println!("train error: {:.2}%", wrong as f64 / ds.n_train() as f64 * 100.0);
    let err = eval_multiclass(&mut hidden, &head, &ds.test_x, &test_labels);
    println!("test error (float head): {:.2}%", err * 100.0);

    // deployed fixed-point path: 10-bit one-vs-all MACs over raw counts
    let q = head.quantize(10);
    let mut wrong = 0usize;
    for (x, &y) in ds.test_x.iter().zip(&test_labels) {
        let codes = dac::features_to_codes(x, &hidden.chip.cfg);
        let counts = hidden.chip.forward(&codes);
        if q.predict(&counts) != y {
            wrong += 1;
        }
    }
    println!(
        "test error (10-bit second stage): {:.2}%",
        wrong as f64 / ds.n_test() as f64 * 100.0
    );
    println!(
        "chip ledger: {} conversions, {:.2} pJ/MAC simulated",
        hidden.chip.ledger.conversions,
        hidden.chip.ledger.pj_per_mac()
    );
    let _ = hidden.hidden_dim();
    Ok(())
}
