//! Multi-tenant serving (DESIGN.md §14): one die fleet, many models.
//!
//!     cargo run --release --example multi_tenant
//!
//! The σVT-mismatch random projection is task-agnostic (the same
//! observation behind the shared random-feature arrays of
//! arXiv:1512.07783), so one fleet of fabricated dies can serve any
//! number of trained output heads. This demo boots a two-die fleet on a
//! binary task, then registers two more tenants over the SAME dies —
//! 10-class digit classification and a brightness regression — serves
//! all three concurrently, streams OS-ELM updates into one tenant, and
//! finally drifts a die and shows the tenant-aware refit restoring
//! every model at once.

use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::datasets::digits::digits;
use velm::registry::TenantSpec;

fn main() -> anyhow::Result<()> {
    // --- boot: a fleet trained on "digit < 5" (the default tenant) ---
    let (ds, labels, _) = digits(240, 1, 5);
    let ys: Vec<f64> = labels.iter().map(|&c| if c < 5 { 1.0 } else { -1.0 }).collect();
    let cfg = ChipConfig::default().with_dims(64, 96).with_b(10);
    let sys = SystemConfig {
        n_chips: 2,
        artifact_dir: "/nonexistent".into(),
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    println!("booting 2 dies on the binary digit task ...");
    let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ys, 0.1, 10)?;

    // --- register two more models on the same physical dies ---
    // each registration drives the tenant's training set through every
    // die ONCE and solves all of its heads from that shared H (one
    // Cholesky for the 10 one-vs-all digit heads)
    let digits_spec = TenantSpec::from_dataset("digits", "digits", 7, coord.d)
        .map_err(anyhow::Error::msg)?;
    let score = coord.register_tenant(digits_spec)?;
    println!("tenant 'digits' registered: 10 heads, mean train error {:.1}%", score * 100.0);
    let bright_spec = TenantSpec::from_dataset("bright", "brightness", 7, coord.d)
        .map_err(anyhow::Error::msg)?;
    let score = coord.register_tenant(bright_spec)?;
    println!("tenant 'bright' registered: regression, mean train RMSE {score:.4}");
    println!("MODELS: {}", coord.models());

    // --- serve all three models from the one fleet ---
    let (eval, eval_labels, _) = {
        let (d, l, t) = digits(1, 60, 991);
        (d.test_x, t, l)
    };
    let mut default_correct = 0usize;
    let mut digit_correct = 0usize;
    let mut bright_acc = 0.0f64;
    for (x, &label) in eval.iter().zip(&eval_labels) {
        let d = coord.classify(x.clone())?; // default head
        if (d.label == 1) == (label < 5) {
            default_correct += 1;
        }
        let m = coord.classify_tenant(Some("digits"), x.clone())?;
        if m.label as usize == label {
            digit_correct += 1;
        }
        let b = coord.classify_tenant(Some("bright"), x.clone())?;
        let target = x.iter().sum::<f64>() / x.len() as f64;
        bright_acc += (b.score - target) * (b.score - target);
    }
    println!(
        "served {} rows x 3 models: default {}/{} correct, digits {}/{} correct, \
         bright RMSE {:.4}",
        eval.len(),
        default_correct,
        eval.len(),
        digit_correct,
        eval.len(),
        (bright_acc / eval.len() as f64).sqrt()
    );

    // --- OS-ELM: stream labelled traffic into the digits tenant ---
    // each update costs one conversion per die + a shared-P RLS step
    // covering all 10 heads
    let (more, more_labels, _) = {
        let (d, l, _) = digits(40, 1, 1234);
        (d.train_x, l, ())
    };
    for (x, &label) in more.iter().zip(&more_labels) {
        let targets: Vec<f64> =
            (0..10).map(|c| if c == label { 1.0 } else { -1.0 }).collect();
        coord.tenant_update("digits", x, &targets)?;
    }
    println!("streamed {} OS-ELM updates into tenant 'digits'", more.len());

    // --- drift + tenant-aware recovery ---
    println!("\naging die 0 and draining it for recalibration ...");
    coord.inject_drift(Some(0), None, None, Some(0.015));
    coord.drain_die(0)?;
    coord.fleet_tick(); // drained -> recalibrating
    coord.fleet_tick(); // refit: default head AND both tenants re-solve
    println!("fleet: {}", coord.fleet_status());
    let mut digit_correct = 0usize;
    for (x, &label) in eval.iter().zip(&eval_labels) {
        let m = coord.classify_tenant(Some("digits"), x.clone())?;
        if m.label as usize == label {
            digit_correct += 1;
        }
    }
    println!(
        "post-refit digits accuracy: {}/{} (every registered head re-solved \
         chip-in-the-loop)",
        digit_correct,
        eval.len()
    );
    println!("\n{}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
