//! Closed-loop autotune-then-serve (DESIGN.md §10): run the Fig. 7
//! design-space exploration on a workload, extract the Pareto front over
//! error / energy / latency / throughput, pick an operating point (knee
//! by default, weighted with `--weights`), and boot the serving
//! coordinator at exactly that point.
//!
//!     cargo run --release --example autotune [-- --dataset brightdata]
//!
//! This is the paper's methodology used as a *self-configuration* step:
//! the sweep that produced Fig. 7 now chooses how the fleet runs.

use std::time::Instant;

use velm::bench::Table;
use velm::cli::Args;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::datasets::{synth, Dataset};
use velm::dse::{self, Explorer, Objective, SearchSpace};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let name = args.get_or("dataset", "brightdata");
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let ds = synth::by_name(&name, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;

    // Tune on a validation split carved out of the *training* data, so
    // the final test-set accuracy below is reported on rows the tuner
    // never saw (no operating-point selection leakage).
    let n_fit = ds.n_train() * 4 / 5;
    let tune_ds = Dataset {
        name: format!("{name}-tune"),
        train_x: ds.train_x[..n_fit].to_vec(),
        train_y: ds.train_y[..n_fit].to_vec(),
        test_x: ds.train_x[n_fit..].to_vec(),
        test_y: ds.train_y[n_fit..].to_vec(),
    };

    // --- explore: a compact space so the example runs in seconds ---
    let space = SearchSpace {
        sigma_vt: (0.005, 0.045),
        ratio: (0.5, 1.25),
        sigma_steps: 4,
        ratio_steps: 3,
        b: vec![8, 10],
        l: vec![32, 64],
        batch: vec![1, 8, 32],
    };
    let mut objective = Objective::new(&tune_ds, 2, seed);
    objective.max_train = 400;
    objective.max_val = 200;
    println!(
        "exploring {} candidates/round x 2 rounds on {name} (d={}, {} fit / {} validation) ...",
        space.grid_size(),
        ds.d(),
        tune_ds.n_train(),
        tune_ds.n_test()
    );
    let t0 = Instant::now();
    let explorer = Explorer {
        space,
        objective,
        rounds: 2,
        threads: dse::default_threads(),
    };
    let result = explorer.run();
    println!(
        "explored {} points in {:.1} s ({} cache hits)",
        result.evals.len(),
        t0.elapsed().as_secs_f64(),
        result.cache_hits
    );

    // --- select: print the front, take the knee (or weighted pick) ---
    let knee = result.knee.expect("non-empty space");
    let selected = match args.get_f64_list("weights").map_err(anyhow::Error::msg)? {
        Some(w) => {
            if w.len() != 4 {
                anyhow::bail!(
                    "--weights wants 4 values (error,energy,latency,throughput), got {}",
                    w.len()
                );
            }
            result.select(&[w[0], w[1], w[2], w[3]]).unwrap_or(knee)
        }
        None => knee,
    };
    let mut table = Table::new(&[
        "sigma_VT (mV)",
        "ratio",
        "b",
        "L",
        "batch",
        "error",
        "pJ/MAC",
        "kcls/s",
        "",
    ]);
    let mut front = result.front.clone();
    front.sort_by(|a, b| a.error.partial_cmp(&b.error).unwrap());
    for e in front.iter().take(12) {
        table.row(&[
            format!("{:.1}", e.point.sigma_vt * 1e3),
            format!("{:.2}", e.point.ratio),
            format!("{}", e.point.b),
            format!("{}", e.point.l),
            format!("{}", e.point.batch),
            format!("{:.4}", e.error),
            format!("{:.3}", e.energy_pj_per_mac),
            format!("{:.1}", e.throughput_cps / 1e3),
            if e.point == selected.point { "<- selected".into() } else { String::new() },
        ]);
    }
    println!("Pareto front (top rows by error, {} total):", front.len());
    table.print();
    println!("selected: {}", selected.point);
    println!("{}", ChipConfig::from_operating_point(&selected.point, ds.d()).summary());

    // --- deploy: boot the coordinator at the selected point ---
    let sys = SystemConfig {
        n_chips: 2,
        artifact_dir: args.get_or("artifacts", "artifacts"),
        ..Default::default()
    };
    println!("\ntraining {} dies at the selected operating point ...", sys.n_chips);
    let coord = Coordinator::start_tuned(&sys, &selected.point, &ds.train_x, &ds.train_y, 0.1, 10)?;
    let n_eval = ds.n_test().min(400);
    let mut correct = 0usize;
    let t1 = Instant::now();
    for (x, &y) in ds.test_x.iter().take(n_eval).zip(&ds.test_y) {
        let resp = coord.classify(x.clone())?;
        if (resp.label as f64 - y).abs() < 1e-9 {
            correct += 1;
        }
    }
    let wall = t1.elapsed().as_secs_f64();
    println!(
        "served {n_eval} requests at the tuned point: {:.2}% error, {:.0} cls/s wall-clock",
        (1.0 - correct as f64 / n_eval as f64) * 100.0,
        n_eval as f64 / wall
    );
    println!("metrics: {}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
