//! Online/adaptive second-stage training (paper ref [15]): stream the
//! training set through the chip once, updating the output weights by
//! recursive least squares after every conversion — no batch re-solve,
//! O(L^2) per sample. Shows the error trajectory converging to the
//! batch solution, and adaptation after a mid-stream temperature step
//! (the Fig. 18 "retraining recovers accuracy" observation, done live).
//!
//!     cargo run --release --example online_learning

use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::online::OnlineElm;
use velm::elm::{self, train::HiddenLayer, ChipHidden};

fn main() -> anyhow::Result<()> {
    let ds = synth::australian(3);
    let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 23));

    // online pass over the training stream
    let mut online = OnlineElm::new(128, 0.1);
    let mut seen_err = 0usize;
    for (k, (x, &y)) in ds.train_x.iter().zip(&ds.train_y).enumerate() {
        let h = hidden.transform(x);
        // prequential error: predict before updating
        if online.predict(&h).signum() != y.signum() {
            seen_err += 1;
        }
        online.update(&h, y);
        if (k + 1) % 100 == 0 {
            println!(
                "after {:4} samples: prequential error {:.1}%",
                k + 1,
                seen_err as f64 / (k + 1) as f64 * 100.0
            );
        }
    }

    // compare to the batch solve on the same die
    let (batch_model, _) =
        elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false)
            .map_err(anyhow::Error::msg)?;
    let test_err_online = {
        let mut wrong = 0;
        for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
            let h = hidden.transform(x);
            if online.predict(&h).signum() != y.signum() {
                wrong += 1;
            }
        }
        wrong as f64 / ds.n_test() as f64
    };
    let test_err_batch =
        elm::eval_classification(&mut hidden, &batch_model, &ds.test_x, &ds.test_y);
    println!(
        "\ntest error: online {:.2}% vs batch {:.2}% (should be ~equal)",
        test_err_online * 100.0,
        test_err_batch * 100.0
    );

    // drift adaptation: step the temperature, keep learning online
    hidden.chip.set_temp(320.0);
    let mut drift_wrong_frozen = 0usize;
    let mut drift_wrong_online = 0usize;
    let mut adaptive = online.clone();
    for (x, &y) in ds.train_x.iter().zip(&ds.train_y).take(300) {
        let h = hidden.transform(x);
        if online.predict(&h).signum() != y.signum() {
            drift_wrong_frozen += 1;
        }
        if adaptive.predict(&h).signum() != y.signum() {
            drift_wrong_online += 1;
        }
        adaptive.update(&h, y);
    }
    println!(
        "after +20K temperature step (300 samples): frozen weights {:.1}% vs \
         online-adapting {:.1}% error",
        drift_wrong_frozen as f64 / 3.0,
        drift_wrong_online as f64 / 3.0
    );
    println!("(the paper stores per-temperature weights; online RLS re-learns them live)");
    Ok(())
}
