//! Drift detection and online recovery, end to end (DESIGN.md §12):
//!
//! A serving fleet (2 active dies + 1 hot standby) takes a Fig. 18-style
//! temperature ramp plus mismatch aging on die 0. The fleet manager's
//! probes detect the drift, pull the die from rotation, refit its head
//! chip-in-the-loop and re-admit it — while traffic keeps flowing the
//! whole time. A control fleet takes the same drift with the manager
//! switched off and degrades instead.
//!
//!     cargo run --release --example drift_recovery

use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::datasets::synth;
use velm::fleet::{DriftEvent, DriftSchedule};

fn accuracy(coord: &Coordinator, xs: &[Vec<f64>], ys: &[f64]) -> anyhow::Result<f64> {
    let mut correct = 0usize;
    for (x, &y) in xs.iter().zip(ys) {
        let resp = coord.classify(x.clone())?;
        if (resp.label as f64 - y).abs() < 1e-9 {
            correct += 1;
        }
    }
    Ok(correct as f64 / xs.len() as f64)
}

fn drift_schedule() -> DriftSchedule {
    // ticks 1..=4: ramp die 0 from 310 K to 355 K (Fig. 18 territory),
    // then age its mismatch profile by 10 mV — the part renormalisation
    // cannot cancel, forcing the drain + refit path
    DriftSchedule::temperature_ramp(Some(0), 1, 4, 310.0, 355.0).with(DriftEvent {
        at_tick: 4,
        die: Some(0),
        vdd: None,
        temp_k: None,
        age_sigma_vt: Some(0.010),
    })
}

fn main() -> anyhow::Result<()> {
    let ds = synth::brightdata(7).with_test_subsample(150, 7);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let mut sys = SystemConfig::default();
    sys.n_chips = 2;
    sys.standby_chips = 1;
    sys.max_wait = std::time::Duration::from_millis(1);
    sys.artifact_dir = "/nonexistent".into(); // chip-sim path, self-contained

    println!("== treated fleet: manager probes and recovers ==");
    let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)?;
    println!("boot: {}", coord.fleet_status());
    let pre = accuracy(&coord, &ds.test_x, &ds.test_y)?;
    println!("pre-drift accuracy: {:.1}%", pre * 100.0);

    coord.set_drift_schedule(drift_schedule());
    let mut served_every_tick = true;
    for tick in 0..10 {
        coord.fleet_tick();
        // traffic keeps flowing between ticks: no downtime allowed
        let burst = accuracy(&coord, &ds.test_x[..20], &ds.test_y[..20]);
        served_every_tick &= burst.is_ok();
        println!(
            "tick {tick}: {} | burst {}",
            coord.fleet_status(),
            match burst {
                Ok(a) => format!("{:.0}%", a * 100.0),
                Err(e) => format!("FAILED: {e}"),
            }
        );
    }
    let post = accuracy(&coord, &ds.test_x, &ds.test_y)?;
    println!("post-recovery accuracy: {:.1}%", post * 100.0);
    println!("fleet event log:");
    for line in coord.fleet_log() {
        println!("  {line}");
    }

    println!("\n== control fleet: same drift, no fleet manager ==");
    let control = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)?;
    // inject the end state of the same schedule directly, never tick
    control.inject_drift(Some(0), None, Some(355.0), Some(0.010));
    // let the workers absorb the control message before measuring
    std::thread::sleep(std::time::Duration::from_millis(20));
    let control_acc = accuracy(&control, &ds.test_x, &ds.test_y)?;
    println!("untreated accuracy under the same drift: {:.1}%", control_acc * 100.0);

    println!("\nsummary:");
    println!("  pre-drift        {:.1}%", pre * 100.0);
    println!("  treated (fleet)  {:.1}%  <- detect -> renormalise/refit -> re-admit", post * 100.0);
    println!("  untreated        {:.1}%", control_acc * 100.0);
    println!(
        "  served every tick without downtime: {}",
        if served_every_tick { "yes" } else { "NO" }
    );
    control.shutdown();
    coord.shutdown();
    Ok(())
}
