//! Unsupervised dimension reduction + k-means (paper conclusion, refs
//! [33]/[34]): run the digits through the chip in *linear* neuron mode
//! (no saturation), cluster the hidden activations, and compare against
//! clustering the raw pixels.
//!
//!     cargo run --release --example clustering

use velm::chip::ChipModel;
use velm::config::{ChipConfig, Transfer};
use velm::datasets::digits;
use velm::elm::cluster::{clustering_accuracy, KMeans};
use velm::elm::{train::HiddenLayer, ChipHidden};
use velm::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    // 4 visually distinct digit classes keep k-means honest
    let keep = [0usize, 1, 4, 7];
    let (ds, labels, _) = digits::digits(1200, 10, 3);
    let mut pts_raw = Vec::new();
    let mut truth = Vec::new();
    for (x, &l) in ds.train_x.iter().zip(&labels) {
        if let Some(pos) = keep.iter().position(|&k| k == l) {
            pts_raw.push(x.clone());
            truth.push(pos);
        }
    }
    println!("{} samples across {} digit classes", pts_raw.len(), keep.len());

    // chip as a linear random projector: 64 pixels -> 32 hidden dims
    let cfg = ChipConfig::default()
        .with_dims(64, 32)
        .with_b(14)
        .with_mode(Transfer::Linear);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 17));
    let projected: Vec<Vec<f64>> = pts_raw.iter().map(|x| hidden.transform(x)).collect();

    let mut rng = Prng::new(5);
    let km_raw = KMeans::fit(&pts_raw, keep.len(), 100, &mut rng);
    let mut rng = Prng::new(5);
    let km_proj = KMeans::fit(&projected, keep.len(), 100, &mut rng);

    let acc_raw = clustering_accuracy(
        &pts_raw.iter().map(|p| km_raw.assign(p)).collect::<Vec<_>>(),
        &truth,
        keep.len(),
    );
    let acc_proj = clustering_accuracy(
        &projected.iter().map(|p| km_proj.assign(p)).collect::<Vec<_>>(),
        &truth,
        keep.len(),
    );
    println!("k-means on raw 64-d pixels:        accuracy {:.1}%", acc_raw * 100.0);
    println!(
        "k-means on 32-d chip projections:  accuracy {:.1}% (dimension halved)",
        acc_proj * 100.0
    );
    println!(
        "claim (conclusion + [34]): random projection preserves cluster structure\n\
         while halving the dimension the iterative algorithm touches."
    );
    Ok(())
}
