//! Quickstart: fabricate a die, look at its mismatch, train a tiny
//! classifier chip-in-the-loop, and classify a few samples.
//!
//!     cargo run --release --example quickstart

use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, ChipHidden};
use velm::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    // 1. "Tape out" a 128x128 die: the seed is the silicon.
    let cfg = ChipConfig::default().with_b(10);
    let mut chip = ChipModel::fabricate(cfg.clone(), 42);
    println!("{}\n", cfg.summary());

    // 2. Push one input vector through the mixed-signal first stage.
    let mut rng = Prng::new(7);
    let codes: Vec<u16> = (0..cfg.d).map(|_| rng.usize(1024) as u16).collect();
    let h = chip.forward(&codes);
    println!(
        "one conversion: H[0..8] = {:?} (cap {}), T_c = {:.1} us, {:.3} pJ/MAC",
        &h[..8],
        cfg.cap(),
        chip.ledger.sim_time * 1e6,
        chip.ledger.pj_per_mac()
    );

    // 3. Chip-in-the-loop ELM training on a real (synthetic-UCI) task.
    let ds = synth::brightdata(1).with_test_subsample(400, 1);
    let mut cfg_ds = cfg.clone();
    cfg_ds.d = ds.d();
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg_ds, 42));
    let (model, _) =
        elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false)
            .map_err(anyhow::Error::msg)?;
    let err = elm::eval_classification_fixed(&mut hidden, &model, &ds.test_x, &ds.test_y);
    println!(
        "\nbrightdata: test error {:.2}% with L = {} hidden neurons \
         (paper, full UCI set: 1.26%)",
        err * 100.0,
        hidden.chip.cfg.l
    );

    // 4. Classify a couple of raw feature vectors through the deployed
    //    fixed-point second stage.
    for (x, y) in ds.test_x.iter().zip(&ds.test_y).take(3) {
        let codes = velm::chip::dac::features_to_codes(x, &hidden.chip.cfg);
        let hv = hidden.chip.forward(&codes);
        let score = model
            .second
            .score(&hv, velm::elm::secondstage::codes_sum(&codes));
        println!("sample -> score {score:+.3}, truth {y:+.0}");
    }
    Ok(())
}
