//! Table II walk-through: hardware ELM (L = 128, counter nonlinearity,
//! fixed-point second stage) vs the software float baseline (sigmoid,
//! L = 1000) on the four UCI-shaped classification tasks.
//!
//!     cargo run --release --example uci_classify [-- --full]
//!
//! `--full` uses the complete test splits (the adult set has 27,780 test
//! rows); the default subsamples for a quick run. The bench target
//! `table2_uci` produces the full paper row set.

use velm::bench::Table;
use velm::chip::ChipModel;
use velm::cli::Args;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, softelm::SoftElm, ChipHidden};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let full = args.flag("full");
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let paper: &[(&str, f64, f64)] = &[
        ("diabetes", 22.05, 22.91),
        ("australian", 13.82, 12.11),
        ("brightdata", 0.69, 1.26),
        ("adult", 15.41, 15.57),
    ];
    let mut table = Table::new(&[
        "Dataset", "d", "N_train", "N_test",
        "SW err% (paper)", "SW err% (ours)",
        "HW err% (paper)", "HW err% (ours)",
    ]);
    for &(name, sw_paper, hw_paper) in paper {
        let mut ds = synth::by_name(name, seed).unwrap();
        if !full {
            ds = ds.with_test_subsample(600, seed);
        }
        // software baseline: sigmoid, L = 1000 (ref [12] configuration)
        let mut soft = SoftElm::new(ds.d(), 1000, seed + 10);
        let (sw_model, _) =
            elm::train_model(&mut soft, &ds.train_x, &ds.train_y, 50.0, 32, false)
                .map_err(anyhow::Error::msg)?;
        let sw_err =
            elm::eval_classification(&mut soft, &sw_model, &ds.test_x, &ds.test_y) * 100.0;
        // hardware: the chip at L = 128 with 10-bit beta
        let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
        let mut hw = ChipHidden::new(ChipModel::fabricate(cfg, seed + 20));
        let (hw_model, _) =
            elm::train_model(&mut hw, &ds.train_x, &ds.train_y, 0.1, 10, false)
                .map_err(anyhow::Error::msg)?;
        let hw_err =
            elm::eval_classification_fixed(&mut hw, &hw_model, &ds.test_x, &ds.test_y) * 100.0;
        table.row(&[
            name.to_string(),
            format!("{}", ds.d()),
            format!("{}", ds.n_train()),
            format!("{}", ds.n_test()),
            format!("{sw_paper:.2}"),
            format!("{sw_err:.2}"),
            format!("{hw_paper:.2}"),
            format!("{hw_err:.2}"),
        ]);
    }
    println!("Table II reproduction (synthetic UCI stand-ins; see DESIGN.md §4):");
    table.print();
    println!("\nClaim under test: HW (L=128, saturating counter, 10-bit beta)");
    println!("stays within a couple of points of SW (L=1000, sigmoid, float).");
    Ok(())
}
