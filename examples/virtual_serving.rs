//! Virtual-die serving end to end (DESIGN.md §13): a fleet fabricated
//! at k x N serves a d=3k, L=3N workload through the Section V weight
//! rotation — the paper's answer to "a major limit imposed on most
//! hardware machine learners". The pass-aware autotuner prices the
//! rotation (each request costs ceil(d/k) x ceil(L/N) physical
//! conversions) so the knee trades passes against the accuracy a wider
//! virtual L buys; the selected point then boots the fleet, which
//! serves over real TCP sockets with per-die heads.
//!
//!     cargo run --release --example virtual_serving
//!
//! Options: --phys-d K (default 4), --phys-l N (default 16),
//!          --chips M (default 2), --requests R (default 200)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use velm::cli::Args;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;
use velm::dse::{self, Explorer, Objective, SearchSpace};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let k = args.get_usize("phys-d", 4).map_err(anyhow::Error::msg)?;
    let n_phys = args.get_usize("phys-l", 16).map_err(anyhow::Error::msg)?;
    let chips = args.get_usize("chips", 2).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_usize("requests", 200).map_err(anyhow::Error::msg)?;
    let d = 3 * k;
    let l = 3 * n_phys;

    // a near-separable d=3k classification task the physical array
    // cannot hold without rotation
    let ds = synth::classification_margin(
        "virtual-blobs",
        d,
        400,
        200,
        synth::FeatureStyle::Continuous,
        0.01,
        0.5,
        9,
    );
    println!(
        "workload: d={} on a {}x{} die -> {} input chunks x {} hidden blocks",
        d,
        k,
        n_phys,
        d.div_ceil(k),
        l.div_ceil(n_phys)
    );

    // --- tune: pass-aware objective over L at and beyond the die ---
    let mut objective = Objective::new(&ds, 2, 11);
    objective.max_train = 200;
    objective.phys = Some((k, n_phys));
    let space = SearchSpace {
        sigma_vt: (0.010, 0.030),
        ratio: (0.75, 0.75),
        sigma_steps: 3,
        ratio_steps: 1,
        b: vec![10],
        l: vec![n_phys, l], // physical width vs the 3x virtual width
        batch: vec![8],
    };
    let explorer =
        Explorer { space, objective, rounds: 2, threads: dse::default_threads() };
    let t0 = Instant::now();
    let result = explorer.run();
    let knee = result.knee.expect("empty design space");
    println!(
        "tuned in {:.1} s over {} evaluations: knee {}",
        t0.elapsed().as_secs_f64(),
        result.evals.len(),
        knee.point
    );
    for e in &result.front {
        println!(
            "  front: L={:<3} err {:.4}  {:.2} pJ/MAC  {:.0} us/batch",
            e.point.l,
            e.error,
            e.energy_pj_per_mac,
            e.latency_s * 1e6
        );
    }

    // --- deploy: fabricate k x N dies, serve the knee's d x L ---
    // the knee decides L: the physical width (passes not worth it) or
    // the 3x virtual width the rotation makes reachable
    let l_served = knee.point.l.max(1);
    let cfg = ChipConfig::default()
        .with_dims(k, n_phys.min(l_served))
        .with_b(knee.point.b)
        .with_sigma_vt(knee.point.sigma_vt)
        .with_sat_ratio(knee.point.ratio);
    let mut sys = SystemConfig::default();
    sys.n_chips = chips;
    sys.artifact_dir = "/nonexistent".into(); // rotation runs on the sim
    sys.max_batch = knee.point.batch.max(1);
    sys.max_wait = std::time::Duration::from_millis(1);
    sys.virtual_d = Some(d);
    sys.virtual_l = Some(l_served);
    println!(
        "training {} dies chip-in-the-loop at d={d}, L={l_served} ...",
        chips
    );
    let t1 = Instant::now();
    let coord = Arc::new(Coordinator::start(
        &sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10,
    )?);
    println!(
        "trained in {:.1} s; {} rotation passes per request",
        t1.elapsed().as_secs_f64(),
        coord.passes
    );

    // a probe cycle on the virtual fleet before traffic
    coord.fleet_tick();
    println!("fleet after probe tick: {}", coord.fleet_status());

    // --- serve over a real TCP socket ---
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1)?;
    println!("serving on {addr}; firing {n_requests} requests");
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut correct = 0usize;
    let t2 = Instant::now();
    for i in 0..n_requests {
        let idx = i % ds.test_x.len();
        let fields: Vec<String> = ds.test_x[idx].iter().map(|v| format!("{v}")).collect();
        writeln!(writer, "CLASSIFY {}", fields.join(","))?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let label: f64 = line
            .trim()
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or(0.0);
        if (label - ds.test_y[idx]).abs() < 1e-9 {
            correct += 1;
        }
    }
    let wall = t2.elapsed().as_secs_f64();
    writeln!(writer, "QUIT")?;
    srv.join();

    println!("\n=== virtual serving results ===");
    println!(
        "accuracy: {:.1}% over {} requests ({} passes each)",
        correct as f64 / n_requests as f64 * 100.0,
        n_requests,
        coord.passes
    );
    println!("throughput: {:.0} classifications/s over TCP", n_requests as f64 / wall);
    println!("metrics: {}", coord.metrics.report());
    Ok(())
}
