"""Shared chip operating-point parameters for the L1/L2 compute graphs.

These mirror `velm::config::ChipConfig` on the Rust side (values from
Table I and Section III-D of the paper). The AOT artifacts bake one
operating point per executable — Python is build-time only, so runtime
sweeps over VDD / temperature use the Rust behavioural simulator instead.

Units are SI throughout (amps, seconds, farads, volts).
"""

from dataclasses import dataclass, replace


#: Thermal voltage at 300 K (used by eq. 12 weight model on the Rust side).
UT_300K = 0.02585

#: Paper section III-D nominal conversion gain: 26 kHz/nA.
K_NEU_NOMINAL = 26e3 / 1e-9


@dataclass(frozen=True)
class ChipParams:
    """One operating point of the mixed-signal ELM chip (paper Table I).

    The forward transfer implemented by both the Pallas kernel and the
    jnp oracle is, per sample ``x`` (10-bit codes) and neuron ``j``::

        i_in[i]  = x[i] / 2**b_in * i_max                     (eq. 4)
        z[j]     = sum_i i_in[i] * w[i, j]                    (KCL column sum)
        f_sp[j]  = z (i_rst - z) / (i_rst c_b vdd)            (eq. 8, clamped >= 0)
        H[j]     = min(floor(f_sp * t_neu), 2**b)             (eq. 11)

    ``mode`` selects the quadratic eq. 8 transfer or its small-signal
    linearisation ``f = K_neu z`` (eq. 9) used for the design-space
    simulations in Section III-D.
    """

    d: int = 128            # input channels (physical k)
    l: int = 128            # hidden neurons (physical N)
    b_in: int = 10          # input DAC bits
    b: int = 14             # valid counter MSB (output resolution)
    i_max: float = 1e-9     # full-scale input current per channel [A]
    i_rst: float = 512e-9   # neuron reset current [A]
    c_b: float = 1.0 / (K_NEU_NOMINAL * 1.0)  # feedback cap for K_neu = 26 kHz/nA
    vdd: float = 1.0        # supply [V]
    i_lk: float = 0.0       # leakage [A] (negligible, eq. 8 assumption)
    sat_ratio: float = 0.75  # I_sat^z / I_max^z design point (Fig. 7a)
    mode: str = "quadratic"  # "quadratic" (eq. 8) | "linear" (eq. 9)

    @property
    def k_neu(self) -> float:
        """Current-to-frequency conversion gain 1/(C_b VDD) [Hz/A] (eq. 10)."""
        return 1.0 / (self.c_b * self.vdd)

    @property
    def i_max_z(self) -> float:
        """Maximum column current I_max^z = d * I_max [A]."""
        return self.d * self.i_max

    @property
    def i_sat_z(self) -> float:
        """Column current at which the counter saturates (Section III-D)."""
        return self.sat_ratio * self.i_max_z

    @property
    def i_flx(self) -> float:
        """Inflection current I_rst / 2 where f_sp peaks (Fig. 5a)."""
        return self.i_rst / 2.0

    @property
    def t_neu(self) -> float:
        """Counting window chosen so H = 2^b exactly at I_sat^z (eq. 19)."""
        return (2.0**self.b) / (self.k_neu * self.i_sat_z)

    @property
    def cap(self) -> int:
        """Counter saturation value 2^b (eq. 11)."""
        return 1 << self.b

    @property
    def code_scale(self) -> float:
        """Scale folding DAC code->current: i_in = code * code_scale."""
        return self.i_max / (1 << self.b_in)

    def with_(self, **kw) -> "ChipParams":
        """Functional update (frozen dataclass)."""
        return replace(self, **kw)


#: Operating point used for the serving artifacts (Table I defaults).
DEFAULT = ChipParams()
