"""L1 Pallas kernel for the digital second stage: scores = H @ beta.

The paper's second stage is an L-wide fixed-point MAC per output (the
FPGA / future on-die multiplier array, Section VI-B). As a Pallas kernel
it is a skinny matvec batched over requests — memory-bound, so the tiling
keeps H rows resident in VMEM and broadcasts beta. Fused with an optional
eq. 26 normalisation so normalised serving needs no extra HBM pass.

interpret=True as everywhere (CPU image); the oracle is plain jnp.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128
BLOCK_L = 128


def _kernel(h_ref, beta_ref, xsum_ref, o_ref, *, normalize: bool):
    h = h_ref[...]
    if normalize:
        hs = jnp.sum(h, axis=-1, keepdims=True)
        g = xsum_ref[...] / jnp.maximum(hs, 1.0)
        h = h * g
    # [bb, L] @ [L, 1] -> [bb, 1]
    o_ref[...] = jnp.dot(h, beta_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("normalize", "bb"))
def predict(h, beta, xsum=None, normalize: bool = False, bb: int = BLOCK_B):
    """Scores for a batch: h [B, L], beta [L, 1], xsum [B, 1] (eq. 26
    numerator, required when normalize=True). B must be a multiple of bb;
    L must fit one block (the physical chip is 128-wide)."""
    bsz, l = h.shape
    assert beta.shape == (l, 1), f"beta shape {beta.shape}"
    assert bsz % bb == 0, f"batch {bsz} not a multiple of {bb}"
    assert l <= BLOCK_L, f"L={l} exceeds one block"
    if xsum is None:
        xsum = jnp.zeros((bsz, 1), jnp.float32)
    grid = (bsz // bb,)
    return pl.pallas_call(
        functools.partial(_kernel, normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, l), lambda i: (i, 0)),
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1), jnp.float32),
        interpret=True,
    )(h.astype(jnp.float32), beta.astype(jnp.float32), xsum.astype(jnp.float32))
