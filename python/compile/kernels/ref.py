"""Pure-jnp oracle for the chip forward pass (L1 correctness reference).

Implements exactly the quantised math of `velm::chip::ChipModel` (Rust) and
`kernels/elm_forward.py` (Pallas): 10-bit DAC -> mismatch VMM -> neuron
transfer (eq. 8 / eq. 9) -> saturating counter (eq. 11). Used by pytest to
check the Pallas kernel and by `model.py` as an interpret-free fallback.
"""

import jax.numpy as jnp

from ..params import ChipParams


def neuron_freq(z, p: ChipParams):
    """Spiking frequency f_sp(I^z) [Hz] (eq. 8, or eq. 9 in linear mode).

    The quadratic transfer is clamped to zero outside [0, I_rst]: below
    zero there is no input current, above I_rst the reset current can no
    longer recharge V_mem and the oscillator stalls (Fig. 5a).
    """
    z = jnp.asarray(z)
    if p.mode == "linear":
        return jnp.maximum(z, 0.0) * p.k_neu
    zc = jnp.clip(z, 0.0, p.i_rst)
    return zc * (p.i_rst - zc) / (p.i_rst * p.c_b * p.vdd)


def counter(freq, p: ChipParams):
    """Saturating spike count H = min(floor(f_sp T_neu), 2^b) (eq. 11)."""
    return jnp.minimum(jnp.floor(freq * p.t_neu), float(p.cap))


def dac_current(codes, p: ChipParams):
    """Current-splitting DAC output per channel (eq. 4): code/2^b_in * I_max."""
    return codes.astype(jnp.float32) * jnp.float32(p.code_scale)


def hidden(codes, w, p: ChipParams):
    """Full first-stage transfer: codes [B, d] x weights [d, L] -> H [B, L].

    `w` is the log-normal mismatch weight matrix exp(dV_T / U_T) (eq. 12),
    sampled at fabrication time by the caller.
    """
    i_in = dac_current(codes, p)          # [B, d] input currents
    z = i_in @ w.astype(jnp.float32)      # [B, L] column currents (KCL)
    return counter(neuron_freq(z, p), p)


def normalize(h, codes):
    """Eq. 26 normalisation: h_j * sum_i(x_i) / sum_j(h_j).

    Makes the hidden vector robust to common-mode VDD / temperature shifts
    (Section VI-F). Guards the h-sum against all-zero rows.
    """
    xs = jnp.sum(codes.astype(jnp.float32), axis=-1, keepdims=True)
    hs = jnp.sum(h, axis=-1, keepdims=True)
    return h * xs / jnp.maximum(hs, 1.0)
