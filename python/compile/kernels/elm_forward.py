"""L1 Pallas kernel: mismatch VMM fused with the neuron/counter transfer.

This is the chip's compute hot-spot — the d x L random projection that the
paper performs in the analog current-mirror array — expressed as a tiled
matmul for the MXU, with the cheap elementwise neuron transfer (eq. 8) and
saturating counter (eq. 11) fused into the epilogue so the hidden matrix H
never leaves VMEM at more precision than its counter bits carry.

TPU mapping (DESIGN.md §Hardware-Adaptation): the physical chip array is
exactly 128 x 128, i.e. one MXU tile; a chip "conversion" is one (bm x bk)
x (bk x bn) tile pass. BlockSpec expresses the HBM->VMEM schedule that the
paper's pitch-matched row/column layout provides in silicon. The weight
matrix is a runtime argument (mismatch is frozen at fabrication, sampled by
the caller), while the operating point (i_max, i_rst, c_b, vdd, t_neu, 2^b)
is baked per artifact variant — matching "one compiled executable per model
variant" on the Rust side.

interpret=True everywhere: the CPU image cannot execute Mosaic custom
calls; real-TPU behaviour is estimated in DESIGN.md §9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import ChipParams

#: Default tile sizes: one MXU tile = one physical chip pass.
BLOCK_B = 128
BLOCK_D = 128
BLOCK_L = 128


def _epilogue(acc, p: ChipParams):
    """Fused DAC-scale + neuron transfer + counter on an accumulated tile.

    `acc` holds the raw code-dot-weight partial sums; the DAC scale
    code -> current (eq. 4) is folded in here once instead of scaling the
    whole input matrix in HBM.
    """
    z = acc * jnp.float32(p.code_scale)
    if p.mode == "linear":
        f = jnp.maximum(z, 0.0) * jnp.float32(p.k_neu)
    else:
        zc = jnp.clip(z, 0.0, jnp.float32(p.i_rst))
        f = zc * (jnp.float32(p.i_rst) - zc) * jnp.float32(
            1.0 / (p.i_rst * p.c_b * p.vdd)
        )
    return jnp.minimum(jnp.floor(f * jnp.float32(p.t_neu)), jnp.float32(p.cap))


def _kernel(x_ref, w_ref, o_ref, *, nk: int, p: ChipParams):
    """Grid point (i, j, k): accumulate X[i,k] @ W[k,j] into O[i,j].

    O's index_map ignores k, so the same VMEM tile is revisited across the
    k steps and doubles as the accumulator; the epilogue fires on the last
    k step, in-place.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = _epilogue(o_ref[...], p)


@functools.partial(jax.jit, static_argnames=("p", "bb", "bd", "bl"))
def hidden(codes, w, p: ChipParams, bb: int = BLOCK_B, bd: int = BLOCK_D,
           bl: int = BLOCK_L):
    """Chip first stage H = counter(f_sp(codes @ w)) as a Pallas call.

    codes: f32[B, d] DAC codes in [0, 2^b_in); w: f32[d, L] mismatch
    weights. B, d, L must be multiples of the block sizes — `model.py`
    pads with zero rows/columns (zero codes contribute no current; extra
    hidden columns are sliced off), which is exact for this transfer.
    """
    bsz, d = codes.shape
    d2, l = w.shape
    assert d == d2, f"codes/weights disagree on d: {d} vs {d2}"
    assert bsz % bb == 0 and d % bd == 0 and l % bl == 0, (
        f"shapes ({bsz},{d},{l}) not multiples of blocks ({bb},{bd},{bl})"
    )
    nk = d // bd
    grid = (bsz // bb, l // bl, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bl), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bl), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, l), jnp.float32),
        interpret=True,
    )(codes.astype(jnp.float32), w.astype(jnp.float32))
