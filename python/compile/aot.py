"""AOT lowering: JAX/Pallas L2 graphs -> artifacts/*.hlo.txt for Rust.

Run once at build time (`make artifacts`); Python never executes on the
request path. The interchange format is HLO *text*, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla_extension 0.5.1 behind the Rust `xla` crate rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Every artifact is one operating point x one shape. A `manifest.txt` is
written next to the artifacts so `velm::runtime::ArtifactStore` can
discover them without parsing HLO:

    name|file|arg0=BxD;arg1=DxL;...|chip params as key=value,...
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .params import DEFAULT, ChipParams

#: Batch shapes compiled for the serving hot path. The coordinator's
#: dynamic batcher rounds batches up to the nearest compiled shape.
HIDDEN_BATCHES = (1, 32, 128, 512)
PREDICT_BATCHES = (1, 32, 128, 512)
#: Max training-set rows per train artifact (zero-row padding is exact).
TRAIN_ROWS = (1024, 5120)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _params_str(p: ChipParams) -> str:
    keys = ("d", "l", "b_in", "b", "i_max", "i_rst", "c_b", "vdd",
            "sat_ratio", "mode")
    items = [f"{k}={getattr(p, k)}" for k in keys]
    items.append(f"t_neu={p.t_neu}")
    items.append(f"k_neu={p.k_neu}")
    return ",".join(items)


def build_all(out_dir: str, p: ChipParams = DEFAULT) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(name: str, lowered, arg_shapes, params=""):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        shapes = ";".join("x".join(str(s) for s in sh) for sh in arg_shapes)
        manifest.append(f"{name}|{fname}|{shapes}|{params}")
        print(f"  {fname}: {len(text)} chars")

    d, l = p.d, p.l
    for bsz in HIDDEN_BATCHES:
        lowered = jax.jit(model.hidden_fn(p)).lower(_spec(bsz, d), _spec(d, l))
        emit(f"hidden_b{bsz}_d{d}_l{l}", lowered, [(bsz, d), (d, l)],
             _params_str(p))
        lowered = jax.jit(model.hidden_fn(p, normalized=True)).lower(
            _spec(bsz, d), _spec(d, l))
        emit(f"hidden_norm_b{bsz}_d{d}_l{l}", lowered, [(bsz, d), (d, l)],
             _params_str(p))

    for n in TRAIN_ROWS:
        lowered = jax.jit(model.train_fn).lower(
            _spec(n, l), _spec(n, 1), _spec(1))
        emit(f"train_n{n}_l{l}", lowered, [(n, l), (n, 1), (1,)])

    for bsz in PREDICT_BATCHES:
        lowered = jax.jit(model.predict_fn).lower(_spec(bsz, l), _spec(l, 1))
        emit(f"predict_b{bsz}_l{l}", lowered, [(bsz, l), (l, 1)])

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="legacy single-file target; artifacts are written "
                         "to its parent directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    entries = build_all(out_dir)
    # Keep the Makefile's stamp target alive: point it at the manifest.
    with open(args.out, "w") as f:
        f.write("# stamp file; real artifacts listed in manifest.txt\n")
        f.write("\n".join(entries) + "\n")
    print(f"wrote {len(entries)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
