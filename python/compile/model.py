"""L2 JAX model: the full ELM compute graphs, built on the L1 kernel.

Three graphs get AOT-lowered (by `aot.py`) and executed from Rust:

  hidden      codes [B,d], W [d,L]            -> H [B,L]      (first stage)
  hidden_norm codes [B,d], W [d,L]            -> Hn [B,L]     (+ eq. 26)
  train_beta  H [N,L], T [N,1], lam [1]       -> beta [L,1]   (ridge solve)
  predict     H [B,L], beta [L,1]             -> scores [B,1] (second stage)

The ridge solve is written as Gauss-Jordan elimination in pure jnp/lax —
NOT jnp.linalg — because jax's CPU linalg lowers to LAPACK custom-calls
that the xla_extension 0.5.1 runtime behind the Rust `xla` crate cannot
execute. H^T H + I/C is SPD, so elimination without pivoting is stable.

Zero-padding is exact end to end: zero code rows produce zero current
(no H contribution), zero H rows contribute nothing to H^T H or H^T T,
so one artifact per *maximum* shape serves all smaller workloads.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .params import ChipParams, DEFAULT
from .kernels import elm_forward, ref


def _pad_axis(x, axis: int, multiple: int):
    """Zero-pad `x` along `axis` up to the next multiple of `multiple`."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


def hidden(codes, w, p: ChipParams = DEFAULT, use_pallas: bool = True):
    """First-stage transfer H = counter(f_sp(DAC(codes) @ w)) (eqs. 4,8,11).

    Pads ragged shapes up to the kernel block sizes and slices the result
    back; pallas and the jnp oracle are interchangeable here (pytest pins
    them together), `use_pallas=False` is a build-time debugging escape.
    """
    bsz, _ = codes.shape
    l = w.shape[1]
    if not use_pallas:
        return ref.hidden(codes, w, p)
    bb = min(bsz, elm_forward.BLOCK_B)
    cp = _pad_axis(_pad_axis(codes, 0, bb), 1, elm_forward.BLOCK_D)
    wp = _pad_axis(_pad_axis(w, 0, elm_forward.BLOCK_D), 1, elm_forward.BLOCK_L)
    h = elm_forward.hidden(cp, wp, p, bb=bb)
    return h[:bsz, :l]


def hidden_norm(codes, w, p: ChipParams = DEFAULT, use_pallas: bool = True):
    """First stage followed by the eq. 26 robustness normalisation."""
    h = hidden(codes, w, p, use_pallas)
    return ref.normalize(h, codes)


def gauss_jordan_solve(a, b):
    """Solve a @ x = b for SPD `a` by vectorised Gauss-Jordan (pure HLO).

    a: [L, L] SPD, b: [L, O]. Lowers to a fori_loop of rank-1 updates —
    no LAPACK custom-calls, so the artifact runs on any PJRT backend.
    """
    l = a.shape[0]
    m = jnp.concatenate([a, b], axis=1)  # [L, L+O] augmented system

    def step(j, m):
        pivot = lax.dynamic_index_in_dim(m, j, axis=0, keepdims=False)[j]
        row = lax.dynamic_index_in_dim(m, j, axis=0, keepdims=False) / pivot
        col = lax.dynamic_index_in_dim(m, j, axis=1, keepdims=False)
        m = m - jnp.outer(col, row)
        return lax.dynamic_update_index_in_dim(m, row, j, axis=0)

    m = lax.fori_loop(0, l, step, m)
    return m[:, l:]


def train_beta(h, t, lam):
    """Ridge-regularised ELM output weights (eq. 3 + Section II).

    beta = (H^T H + I/C)^-1 H^T T with lam = 1/C passed as a length-1
    array (scalars cross the Rust FFI most simply as rank-1 literals).
    """
    h = h.astype(jnp.float32)
    t = t.astype(jnp.float32)
    l = h.shape[1]
    a = h.T @ h + lam[0] * jnp.eye(l, dtype=jnp.float32)
    return gauss_jordan_solve(a, h.T @ t)


def predict(h, beta):
    """Second-stage scores o = H @ beta (eq. 1)."""
    return h.astype(jnp.float32) @ beta.astype(jnp.float32)


def quantize_beta(beta, bits: int):
    """Symmetric uniform quantisation of beta to `bits` (Fig. 7b study).

    Matches `velm::elm::secondstage::quantize` on the Rust side: scale to
    the max magnitude, round to the signed grid, de-scale.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(beta)), 1e-30)
    levels = float(1 << (bits - 1)) - 1.0
    return jnp.round(beta / scale * levels) / levels * scale


# ---------------------------------------------------------------------------
# Jitted entry points for AOT lowering (static shapes per variant).
# ---------------------------------------------------------------------------

def hidden_fn(p: ChipParams = DEFAULT, normalized: bool = False):
    """Returns the (codes, w) -> H jittable for one operating point."""
    f = hidden_norm if normalized else hidden

    @jax.jit
    def run(codes, w):
        return (f(codes, w, p),)

    return run


@jax.jit
def train_fn(h, t, lam):
    return (train_beta(h, t, lam),)


@jax.jit
def predict_fn(h, beta):
    return (predict(h, beta),)
