"""Pallas second-stage kernel vs plain-jnp oracle (incl. eq. 26 fusion)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import secondstage
from compile.kernels import ref


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 4),
    l=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_predict_matches_matmul(nb, l, seed):
    rng = np.random.default_rng(seed)
    bb = 8
    b = nb * bb
    h = rng.uniform(0, 1000, size=(b, l)).astype(np.float32)
    beta = rng.normal(size=(l, 1)).astype(np.float32)
    out = np.asarray(secondstage.predict(jnp.asarray(h), jnp.asarray(beta), bb=bb))
    np.testing.assert_allclose(out, h @ beta, rtol=2e-5, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_predict_normalized_matches_ref(seed):
    rng = np.random.default_rng(seed)
    b, l = 16, 32
    h = rng.uniform(0, 1000, size=(b, l)).astype(np.float32)
    codes = rng.integers(1, 1024, size=(b, 8)).astype(np.float32)
    xsum = codes.sum(axis=1, keepdims=True).astype(np.float32)
    beta = rng.normal(size=(l, 1)).astype(np.float32)
    out = np.asarray(
        secondstage.predict(
            jnp.asarray(h), jnp.asarray(beta), jnp.asarray(xsum),
            normalize=True, bb=8,
        )
    )
    hn = np.asarray(ref.normalize(jnp.asarray(h), jnp.asarray(codes)))
    np.testing.assert_allclose(out, hn @ beta, rtol=2e-4, atol=1e-2)


def test_zero_hidden_rows_score_zero_when_normalized():
    h = jnp.zeros((8, 16), jnp.float32)
    beta = jnp.ones((16, 1), jnp.float32)
    xsum = jnp.full((8, 1), 100.0, jnp.float32)
    out = np.asarray(secondstage.predict(h, beta, xsum, normalize=True, bb=8))
    assert np.all(out == 0.0)
