"""AOT path: lowering produces parseable HLO text + a sane manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.params import ChipParams


def test_hidden_lowers_to_hlo_text():
    p = ChipParams(d=8, l=8)
    lowered = jax.jit(model.hidden_fn(p)).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # the epilogue's floor survives into HLO (the counter quantisation)
    assert "floor" in text


def test_train_lowers_without_custom_calls():
    """The ridge solve must not lean on LAPACK custom-calls (xla 0.5.1)."""
    lowered = jax.jit(model.train_fn).lower(
        jax.ShapeDtypeStruct((32, 8), jnp.float32),
        jax.ShapeDtypeStruct((32, 1), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "custom-call" not in text, "train graph must be pure HLO"
    assert "while" in text  # the Gauss-Jordan fori_loop


def test_predict_lowers_clean():
    lowered = jax.jit(model.predict_fn).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 1), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "custom-call" not in text


def test_build_all_small(tmp_path):
    """End-to-end artifact build at a reduced operating point."""
    old_h, old_p, old_t = aot.HIDDEN_BATCHES, aot.PREDICT_BATCHES, aot.TRAIN_ROWS
    aot.HIDDEN_BATCHES, aot.PREDICT_BATCHES, aot.TRAIN_ROWS = (2,), (2,), (16,)
    try:
        entries = aot.build_all(str(tmp_path), ChipParams(d=8, l=8))
    finally:
        aot.HIDDEN_BATCHES, aot.PREDICT_BATCHES, aot.TRAIN_ROWS = (
            old_h, old_p, old_t)
    assert len(entries) == 4  # hidden, hidden_norm, train, predict
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 4
    for line in manifest:
        name, fname, shapes, _params = line.split("|")
        assert (tmp_path / fname).exists()
        assert all("x" in s or s.isdigit() for s in shapes.split(";"))
    # hidden manifest row carries the baked operating point
    hid = [l for l in manifest if l.startswith("hidden_b")][0]
    assert "t_neu=" in hid and "mode=quadratic" in hid


def test_hidden_artifact_numerics_roundtrip(tmp_path):
    """Execute the lowered hidden graph via jax and compare to the oracle
    (the Rust-side execution of the same text is covered by cargo tests)."""
    from compile.kernels import ref
    p = ChipParams(d=8, l=8)
    rng = np.random.default_rng(11)
    codes = rng.integers(0, 1024, size=(4, 8)).astype(np.float32)
    w = np.exp(rng.normal(0, 0.016, size=(8, 8)) / 0.02585).astype(np.float32)
    run = model.hidden_fn(p)
    out = np.asarray(run(jnp.asarray(codes), jnp.asarray(w))[0])
    expect = np.asarray(ref.hidden(jnp.asarray(codes), jnp.asarray(w), p))
    assert np.abs(out - expect).max() <= 1.0
