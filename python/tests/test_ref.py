"""Unit properties of the jnp oracle itself (paper equations 4, 8, 9, 11, 26)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.params import ChipParams
from compile.kernels import ref

P = ChipParams(d=8, l=8)


def test_neuron_freq_peak_at_iflx():
    """f_sp peaks at I_rst/2 = I_flx and is zero at 0 and I_rst (Fig. 5a)."""
    z = np.linspace(0.0, P.i_rst, 2001)
    f = np.asarray(ref.neuron_freq(z, P))
    assert f[0] == 0.0
    assert abs(f[-1]) < 1e-6
    peak = z[np.argmax(f)]
    assert abs(peak - P.i_flx) < P.i_rst / 1000
    # eq. 8 peak value: I_rst / (4 C_b VDD)
    fmax_theory = P.i_rst / (4 * P.c_b * P.vdd)
    np.testing.assert_allclose(f.max(), fmax_theory, rtol=1e-3)


def test_neuron_freq_linear_region():
    """For I^z << I_rst, eq. 8 collapses to eq. 9: f = K_neu I^z."""
    z = np.linspace(0.0, P.i_rst / 50, 100)
    quad = np.asarray(ref.neuron_freq(z, P))
    lin = z * P.k_neu
    np.testing.assert_allclose(quad, lin, rtol=0.025)


@settings(max_examples=30, deadline=None)
@given(st.floats(-1e-9, 1e-5))
def test_neuron_freq_nonnegative_and_clamped(z):
    f = float(ref.neuron_freq(jnp.float32(z), P))
    assert f >= 0.0
    assert f <= P.i_rst / (4 * P.c_b * P.vdd) * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1023), st.integers(0, 1023))
def test_dac_monotone(c1, c2):
    """Eq. 4 DAC is monotone and exactly linear in the code."""
    i1 = float(ref.dac_current(jnp.float32(c1), P))
    i2 = float(ref.dac_current(jnp.float32(c2), P))
    if c1 < c2:
        assert i1 < i2
    np.testing.assert_allclose(i1, c1 / 1024 * P.i_max, rtol=1e-6)


def test_counter_saturates():
    freq = jnp.asarray([0.0, 1.0 / P.t_neu, 1e12])
    h = np.asarray(ref.counter(freq, P))
    assert h[0] == 0.0
    assert h[1] == 1.0
    assert h[2] == P.cap


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_normalize_cancels_common_mode_gain(seed):
    """Eq. 26: a common-mode gain g on every h_j cancels exactly."""
    rng = np.random.default_rng(seed)
    h = rng.uniform(1.0, 100.0, size=(4, 8)).astype(np.float32)
    codes = rng.integers(1, 1024, size=(4, 8)).astype(np.float32)
    g = 1.0 + rng.uniform(-0.3, 0.3)
    n0 = np.asarray(ref.normalize(jnp.asarray(h), jnp.asarray(codes)))
    n1 = np.asarray(ref.normalize(jnp.asarray(g * h), jnp.asarray(codes)))
    np.testing.assert_allclose(n0, n1, rtol=1e-4)


def test_normalize_zero_row_guard():
    h = jnp.zeros((2, 4), jnp.float32)
    codes = jnp.ones((2, 4), jnp.float32)
    out = np.asarray(ref.normalize(h, codes))
    assert np.all(np.isfinite(out))
    assert np.all(out == 0.0)
