"""L2 model graphs: ridge solve, padding exactness, quantisation, predict."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.params import ChipParams


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 24))
def test_gauss_jordan_matches_numpy(seed, l):
    """Pure-HLO elimination equals numpy's LAPACK solve on SPD systems."""
    rng = np.random.default_rng(seed)
    h = rng.normal(size=(3 * l, l)).astype(np.float32)
    a = h.T @ h + 0.1 * np.eye(l, dtype=np.float32)
    b = rng.normal(size=(l, 2)).astype(np.float32)
    x = np.asarray(model.gauss_jordan_solve(jnp.asarray(a), jnp.asarray(b)))
    x_np = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(x, x_np, rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_train_beta_is_ridge_optimum(seed):
    """beta minimises ||H b - T||^2 + lam ||b||^2: gradient must vanish."""
    rng = np.random.default_rng(seed)
    n, l = 64, 16
    h = rng.normal(size=(n, l)).astype(np.float32)
    t = rng.normal(size=(n, 1)).astype(np.float32)
    lam = np.asarray([0.5], np.float32)
    beta = np.asarray(model.train_beta(jnp.asarray(h), jnp.asarray(t),
                                       jnp.asarray(lam)))
    grad = h.T @ (h @ beta - t) + lam[0] * beta
    assert np.abs(grad).max() < 5e-2 * max(1.0, np.abs(h.T @ t).max())


def test_train_beta_zero_row_padding_exact():
    """Appending zero H rows / zero targets leaves beta unchanged."""
    rng = np.random.default_rng(0)
    n, l = 40, 8
    h = rng.normal(size=(n, l)).astype(np.float32)
    t = rng.normal(size=(n, 1)).astype(np.float32)
    lam = jnp.asarray([0.3], jnp.float32)
    b0 = np.asarray(model.train_beta(jnp.asarray(h), jnp.asarray(t), lam))
    hp = np.vstack([h, np.zeros((24, l), np.float32)])
    tp = np.vstack([t, np.zeros((24, 1), np.float32)])
    b1 = np.asarray(model.train_beta(jnp.asarray(hp), jnp.asarray(tp), lam))
    np.testing.assert_allclose(b0, b1, rtol=1e-5, atol=1e-6)


def test_hidden_padding_exact():
    """Ragged shapes through the padded pallas path equal the oracle."""
    from compile.kernels import ref
    rng = np.random.default_rng(1)
    p = ChipParams(d=10, l=13)
    codes = rng.integers(0, 1024, size=(5, 10)).astype(np.float32)
    w = np.exp(rng.normal(0, 0.016, size=(10, 13)) / 0.02585).astype(np.float32)
    h_pal = np.asarray(model.hidden(jnp.asarray(codes), jnp.asarray(w), p))
    h_ref = np.asarray(ref.hidden(jnp.asarray(codes), jnp.asarray(w), p))
    assert h_pal.shape == (5, 13)
    assert np.abs(h_pal - h_ref).max() <= 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 16))
def test_quantize_beta_error_bound(seed, bits):
    """Quantisation error is bounded by half an LSB of the max magnitude."""
    rng = np.random.default_rng(seed)
    beta = rng.normal(size=(16, 1)).astype(np.float32)
    q = np.asarray(model.quantize_beta(jnp.asarray(beta), bits))
    scale = np.abs(beta).max()
    lsb = scale / (2 ** (bits - 1) - 1)
    assert np.abs(q - beta).max() <= 0.5 * lsb * (1 + 1e-5)


def test_predict_matches_matmul():
    rng = np.random.default_rng(2)
    h = rng.normal(size=(6, 8)).astype(np.float32)
    beta = rng.normal(size=(8, 1)).astype(np.float32)
    out = np.asarray(model.predict(jnp.asarray(h), jnp.asarray(beta)))
    np.testing.assert_allclose(out, h @ beta, rtol=1e-5)


def test_end_to_end_sinc_regression_small():
    """Miniature Fig. 16: chip-forward features + ridge solve fit sinc."""
    rng = np.random.default_rng(3)
    d, l, n = 1, 64, 400
    p = ChipParams(d=d, l=l, b=10)
    x = rng.uniform(-1, 1, size=(n, 1))
    y = np.sinc(5 * x[:, 0]) + rng.normal(0, 0.05, size=n)
    codes = np.round((x + 1) / 2 * 1023).astype(np.float32)
    w = np.exp(rng.normal(0, 0.025, size=(d, l)) / 0.02585).astype(np.float32)
    # two-point affine feature trick is impossible at d=1 through a
    # log-normal VMM alone; the saturating counter supplies the
    # nonlinearity exactly as in the paper (Section VI-C).
    h = np.asarray(model.hidden(jnp.asarray(codes), jnp.asarray(w), p))
    lam = jnp.asarray([1e-3], jnp.float32)
    beta = model.train_beta(jnp.asarray(h), jnp.asarray(y[:, None]), lam)
    pred = np.asarray(model.predict(jnp.asarray(h), beta))[:, 0]
    clean = np.sinc(5 * x[:, 0])
    rmse = np.sqrt(np.mean((pred - clean) ** 2))
    assert rmse < 0.2, f"train-set sinc rmse too high: {rmse}"
