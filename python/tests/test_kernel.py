"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, dtypes and operating points; the
CORE signal is that `elm_forward.hidden` and `ref.hidden` agree. Counts
may legitimately differ by 1 LSB where the pre-floor spike estimate
f_sp*T_neu lands within float-reassociation distance of an integer
(blocked vs flat accumulation order), so the check is: pre-floor
frequencies allclose AND counts within 1, with ties accounted for.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.params import ChipParams
from compile.kernels import elm_forward, ref


def make_params(d, l, mode="quadratic", b=14):
    return ChipParams(d=d, l=l, mode=mode, b=b)


def lognormal_w(rng, d, l, sigma_vt=0.016, ut=0.02585):
    """Fabrication-time mismatch weights, eq. 12."""
    return np.exp(rng.normal(0.0, sigma_vt, size=(d, l)) / ut).astype(np.float32)


def check_match(h_ker, h_ref, freq_ref, p):
    h_ker = np.asarray(h_ker)
    h_ref = np.asarray(h_ref)
    diff = np.abs(h_ker - h_ref)
    assert diff.max() <= 1.0, f"count mismatch > 1 LSB: {diff.max()}"
    if diff.max() > 0:
        # any 1-LSB disagreements must sit on a floor boundary
        est = np.asarray(freq_ref * p.t_neu)
        near = np.abs(est - np.round(est)) < 1e-2 * np.maximum(est, 1.0)
        assert np.all(near[diff > 0]), "off-boundary count mismatch"


@settings(max_examples=25, deadline=None)
@given(
    bsz=st.integers(1, 6),
    dt=st.integers(1, 5),
    lt=st.integers(1, 5),
    mode=st.sampled_from(["quadratic", "linear"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_blocked(bsz, dt, lt, mode, seed):
    """Random shapes as multiples of a small block (exercises the grid)."""
    bb, bd, bl = 4, 8, 8
    bsz, d, l = bsz * bb, dt * bd, lt * bl
    rng = np.random.default_rng(seed)
    p = make_params(d, l, mode)
    codes = rng.integers(0, 1024, size=(bsz, d)).astype(np.float32)
    w = lognormal_w(rng, d, l)
    h_ker = elm_forward.hidden(jnp.asarray(codes), jnp.asarray(w), p,
                               bb=bb, bd=bd, bl=bl)
    z = ref.dac_current(jnp.asarray(codes), p) @ jnp.asarray(w)
    freq = ref.neuron_freq(z, p)
    h_ref = ref.hidden(jnp.asarray(codes), jnp.asarray(w), p)
    check_match(h_ker, h_ref, freq, p)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float64, np.int32]))
def test_kernel_input_dtypes(seed, dtype):
    """Codes arriving as other dtypes are cast identically on both paths."""
    rng = np.random.default_rng(seed)
    d = l = 8
    p = make_params(d, l)
    codes = rng.integers(0, 1024, size=(4, d)).astype(dtype)
    w = lognormal_w(rng, d, l)
    h_ker = elm_forward.hidden(jnp.asarray(codes), jnp.asarray(w), p,
                               bb=4, bd=8, bl=8)
    z = ref.dac_current(jnp.asarray(codes), p) @ jnp.asarray(w)
    h_ref = ref.hidden(jnp.asarray(codes), jnp.asarray(w), p)
    check_match(h_ker, h_ref, ref.neuron_freq(z, p), p)


def test_kernel_full_chip_shape():
    """The physical 128x128 array at serving batch 32, one MXU tile."""
    rng = np.random.default_rng(7)
    p = make_params(128, 128)
    codes = rng.integers(0, 1024, size=(32, 128)).astype(np.float32)
    w = lognormal_w(rng, 128, 128)
    h_ker = elm_forward.hidden(jnp.asarray(codes), jnp.asarray(w), p, bb=32)
    z = ref.dac_current(jnp.asarray(codes), p) @ jnp.asarray(w)
    h_ref = ref.hidden(jnp.asarray(codes), jnp.asarray(w), p)
    check_match(h_ker, h_ref, ref.neuron_freq(z, p), p)
    # sanity: the counter cap is respected and some neurons are active
    assert np.asarray(h_ker).max() <= p.cap
    assert np.asarray(h_ker).max() > 0


def test_kernel_zero_input_gives_zero_counts():
    """S2 switch behaviour: all-zero codes shut the row off (eq. 5)."""
    p = make_params(8, 8)
    codes = jnp.zeros((4, 8), jnp.float32)
    w = jnp.ones((8, 8), jnp.float32)
    h = elm_forward.hidden(codes, w, p, bb=4, bd=8, bl=8)
    assert np.all(np.asarray(h) == 0.0)


def test_kernel_saturation_at_cap():
    """Currents far above I_sat^z pin every counter at 2^b (eq. 11)."""
    p = make_params(8, 8, b=6)
    codes = jnp.full((4, 8), 1023.0, jnp.float32)
    w = jnp.full((8, 8), 500.0, jnp.float32)  # huge gain: z ~ 4 uA >> I_rst
    h = elm_forward.hidden(codes, w, p, bb=4, bd=8, bl=8)
    # z >> i_rst stalls the oscillator in quadratic mode -> 0, so use linear
    p_lin = p.with_(mode="linear")
    h_lin = elm_forward.hidden(codes, w, p_lin, bb=4, bd=8, bl=8)
    assert np.all(np.asarray(h_lin) == p.cap)
    # quadratic mode: oscillator stalls above I_rst (Fig. 5a right edge)
    assert np.all(np.asarray(h) == 0.0)


@pytest.mark.parametrize("bb,bd,bl", [(1, 8, 8), (2, 16, 8), (8, 8, 16)])
def test_kernel_block_shape_invariance(bb, bd, bl):
    """H is invariant to the VMEM tiling choice (same math, any schedule)."""
    rng = np.random.default_rng(3)
    d, l, bsz = 16, 16, 8
    p = make_params(d, l)
    codes = rng.integers(0, 1024, size=(bsz, d)).astype(np.float32)
    w = lognormal_w(rng, d, l)
    base = elm_forward.hidden(jnp.asarray(codes), jnp.asarray(w), p,
                              bb=8, bd=16, bl=16)
    other = elm_forward.hidden(jnp.asarray(codes), jnp.asarray(w), p,
                               bb=bb, bd=bd, bl=bl)
    z = ref.dac_current(jnp.asarray(codes), p) @ jnp.asarray(w)
    check_match(other, np.asarray(base), ref.neuron_freq(z, p), p)
