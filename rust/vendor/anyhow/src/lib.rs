//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline vendor set has no crates.io access, so this shim carries
//! exactly the subset of anyhow's API that velm uses: the type-erased
//! [`Error`] with a context chain, [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!`
//! macros. Semantics match upstream where it matters:
//!
//!   * `Display` prints the outermost message; the alternate form
//!     (`{:#}`) prints the whole chain joined by `": "`.
//!   * `Debug` (what `fn main() -> Result<()>` prints on exit) shows the
//!     outermost message followed by a `Caused by:` list.
//!   * Any `std::error::Error + Send + Sync + 'static` converts into
//!     [`Error`] via `?`, capturing its `source()` chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result` defaulted to the type-erased [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Type-erased error: an outermost-first chain of messages.
pub struct Error {
    /// `chain[0]` is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context onto the chain.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside the std identity `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("layer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("layer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing thing"));
    }

    #[test]
    fn option_context_and_with_context() {
        let a: Result<u8> = None.context("nothing here");
        assert_eq!(format!("{}", a.unwrap_err()), "nothing here");
        let b: Result<u8> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(format!("{}", b.unwrap_err()), "missing 7");
        assert_eq!(Some(3u8).context("ok").unwrap(), 3);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/velm")?;
            Ok(s)
        }
        assert!(g().is_err());
    }

    #[test]
    fn error_msg_as_function_value() {
        let r: Result<()> = Err("stringy".to_string()).map_err(Error::msg);
        assert_eq!(format!("{}", r.unwrap_err()), "stringy");
    }
}
