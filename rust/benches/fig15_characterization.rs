//! Fig. 15: die characterisation — neuron transfer-curve spread, the
//! 128x128 mismatch surface, and the log-normal weight histogram with
//! the sigma_VT extraction, across a batch of 9 dies (the paper
//! measured 9 chips: 15.36-16.26 mV).
//!
//!     cargo bench --bench fig15_characterization

use velm::bench::{bench, section, Table};
use velm::chip::ChipModel;
use velm::config::{thermal_voltage, ChipConfig};
use velm::util::stats;

fn sigma_from_surface(chip: &mut ChipModel) -> f64 {
    let surf = chip.weight_surface(100);
    let mut vals: Vec<f64> = surf.data.iter().cloned().filter(|&v| v > 0.0).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = vals[vals.len() / 2];
    let logs: Vec<f64> = vals.iter().map(|v| (v / median).ln()).collect();
    let (_, s) = stats::fit_gaussian(&logs);
    s * thermal_voltage(chip.cfg.temp_k)
}

fn main() {
    let cfg = ChipConfig::default();

    section("Fig 15(a): transfer-curve spread across the 128 neurons");
    let mut chip = ChipModel::fabricate(cfg.clone(), 1);
    let sweep: Vec<u16> = (0..=10).map(|k| (k * 102) as u16).collect();
    let curves = chip.transfer_curves(0, &sweep);
    let top: Vec<f64> = curves.last().unwrap().iter().map(|&c| c as f64).collect();
    println!(
        "at Data_in = {}: count mean {:.0}, std {:.0} ({:.0}% relative spread across neurons)",
        sweep.last().unwrap(),
        stats::mean(&top),
        stats::std(&top),
        stats::std(&top) / stats::mean(&top) * 100.0
    );
    println!("paper: 'significant variation between the transfer curves' — the mismatch resource.");

    section("Fig 15(b,c): weight surface + log-normal fit over 9 dies");
    let mut t = Table::new(&["die", "sigma_dVT extracted (mV)"]);
    let mut sigmas = Vec::new();
    for die in 0..9u64 {
        let mut chip = ChipModel::fabricate(cfg.clone(), 100 + die);
        let s = sigma_from_surface(&mut chip);
        sigmas.push(s * 1e3);
        t.row(&[format!("{die}"), format!("{:.2}", s * 1e3)]);
    }
    t.print();
    let lo = sigmas.iter().cloned().fold(f64::MAX, f64::min);
    let hi = sigmas.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "extracted sigma_dVT range [{lo:.2}, {hi:.2}] mV around fabricated {:.1} mV\n\
         (paper, 9 chips: 15.36 - 16.26 mV around ~16 mV)",
        cfg.sigma_vt * 1e3
    );

    section("weight histogram shape (die 0, normalised by median)");
    let mut chip = ChipModel::fabricate(cfg.clone(), 100);
    let surf = chip.weight_surface(100);
    let vals: Vec<f64> = surf.data.iter().cloned().filter(|&v| v > 0.0).collect();
    let mut sorted = vals.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let norm: Vec<f64> = vals.iter().map(|v| v / median).collect();
    let (centers, counts) = stats::histogram(&norm, 0.0, 4.0, 16);
    for (c, n) in centers.iter().zip(&counts) {
        println!("{c:5.2} | {}", "#".repeat(n / 40));
    }
    println!("right-skewed log-normal, as Fig 15(c).");

    section("timing");
    bench("128x128 weight_surface (128 conversions)", 1.0, || {
        let mut chip = ChipModel::fabricate(cfg.clone(), 7);
        std::hint::black_box(chip.weight_surface(100));
    });
}
