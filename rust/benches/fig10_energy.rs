//! Fig. 10: energy per conversion E_c vs I_max^z (and its T_neu view)
//! for VDD in {0.8, 1.0, 1.2} V — the "operate briefly at high frequency"
//! design rule, with the minimum near (slightly below) I_flx.
//!
//!     cargo bench --bench fig10_energy

use velm::bench::{section, Table};
use velm::chip::energy;
use velm::config::ChipConfig;

fn main() {
    let base = ChipConfig::default().with_b(10); // paper: Fig 10 plotted with b = 10

    section("Fig 10(a): E_c vs I_max^z for three VDDs");
    let mut t = Table::new(&[
        "I_max^z / I_flx(1V)", "E_c @0.8V (pJ)", "E_c @1.0V (pJ)", "E_c @1.2V (pJ)",
    ]);
    let i_flx_nom = base.i_flx();
    let fracs: Vec<f64> = (1..=14).map(|k| k as f64 * 0.18).collect();
    for &fr in &fracs {
        let i = fr * i_flx_nom;
        let cells: Vec<String> = [0.8, 1.0, 1.2]
            .iter()
            .map(|&v| {
                let c = base.clone().with_vdd(v);
                let e = energy::e_c(i, &c);
                if e.is_finite() {
                    format!("{:.2}", e * 1e12)
                } else {
                    "-".to_string() // I_sat beyond this VDD's I_rst
                }
            })
            .collect();
        t.row(&[format!("{fr:.2}"), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
    }
    t.print();

    section("minimum location and value per VDD");
    let mut t = Table::new(&[
        "VDD (V)", "argmin I_max^z / I_flx(VDD)", "min E_c (pJ)", "T_neu at min (us)",
    ]);
    for &v in &[0.8, 1.0, 1.2] {
        let c = base.clone().with_vdd(v);
        let grid: Vec<f64> = (1..=120).map(|k| k as f64 / 120.0 * 1.33 * c.i_rst()).collect();
        let (mut best_i, mut best_e) = (0.0, f64::MAX);
        for &i in &grid {
            let e = energy::e_c(i, &c);
            if e < best_e {
                best_e = e;
                best_i = i;
            }
        }
        let f_sat = velm::chip::neuron::f_sp(c.sat_ratio * best_i, &c);
        t.row(&[
            format!("{v:.1}"),
            format!("{:.2}", best_i / c.i_flx()),
            format!("{:.2}", best_e * 1e12),
            format!("{:.1}", c.cap() as f64 / f_sat * 1e6),
        ]);
    }
    t.print();
    println!(
        "paper shape: minimum near I_flx (optimum slightly off peak due to the\n\
         V_mem short-circuit blowup); lower VDD -> lower minimum energy but\n\
         longer conversion time (Fig 10b)."
    );

    section("Fig 10(b): the same minimum in T_neu coordinates");
    let mut t = Table::new(&["VDD (V)", "E_c at T_neu=0.2ms (pJ)", "E_c at T_neu~min (pJ)"]);
    for &v in &[0.8, 1.0, 1.2] {
        let c = base.clone().with_vdd(v);
        // long-window (low current) point: I_max^z with f(I_sat) small
        let slow_i = 0.05 * c.i_rst();
        let fast_grid: Vec<f64> = (1..=60).map(|k| k as f64 / 60.0 * 1.3 * c.i_rst()).collect();
        let e_min = fast_grid
            .iter()
            .map(|&i| energy::e_c(i, &c))
            .fold(f64::MAX, f64::min);
        t.row(&[
            format!("{v:.1}"),
            format!("{:.2}", energy::e_c(slow_i, &c) * 1e12),
            format!("{:.2}", e_min * 1e12),
        ]);
    }
    t.print();
    println!("slow (long T_neu) operation costs several x the optimum — the Section IV-C rule.");
}
