//! Fig. 16: sinc regression through the full behavioural chip
//! (5000 noisy training samples, sigma = 0.2, L = 128).
//!
//!     cargo bench --bench fig16_regression
//!
//! Paper: hardware error 0.021; software ELM ~0.01.

use velm::bench::{bench, section};
use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, softelm::SoftElm, ChipHidden};

fn main() {
    section("Fig 16: sinc(x) regression, chip vs software");
    let ds = synth::sinc(5000, 500, 0.2, 3);
    let cfg = ChipConfig::default().with_dims(1, 128).with_b(12);
    let mut hw = ChipHidden::new(ChipModel::fabricate(cfg, 11));
    let (model, _) = elm::train_model(&mut hw, &ds.train_x, &ds.train_y, 1e-4, 14, false)
        .expect("train");
    let hw_err = elm::eval_regression(&mut hw, &model, &ds.test_x, &ds.test_y);
    let mut soft = SoftElm::with_scale(1, 128, 10.0, 12);
    let (sw_model, _) = elm::train_model(&mut soft, &ds.train_x, &ds.train_y, 1e-4, 32, false)
        .expect("train sw");
    let sw_err = elm::eval_regression(&mut soft, &sw_model, &ds.test_x, &ds.test_y);
    println!("hardware RMSE {hw_err:.4} (paper 0.021); software RMSE {sw_err:.4} (paper ~0.01)");
    println!(
        "hw/sw ratio {:.2} (paper {:.2}) — hardware within ~2-3x of software, same as the paper",
        hw_err / sw_err,
        0.021 / 0.01
    );
    // trial spread across dies
    let mut errs = Vec::new();
    for die in 0..5u64 {
        let cfg = ChipConfig::default().with_dims(1, 128).with_b(12);
        let mut hw = ChipHidden::new(ChipModel::fabricate(cfg, 100 + die));
        let (m, _) = elm::train_model(&mut hw, &ds.train_x, &ds.train_y, 1e-4, 14, false)
            .expect("train");
        errs.push(elm::eval_regression(&mut hw, &m, &ds.test_x, &ds.test_y));
    }
    println!(
        "across 5 dies: mean {:.4}, min {:.4}, max {:.4}",
        velm::util::stats::mean(&errs),
        errs.iter().cloned().fold(f64::MAX, f64::min),
        errs.iter().cloned().fold(f64::MIN, f64::max)
    );

    section("timing");
    bench("one chip conversion (d=1, L=128)", 0.3, || {
        let _ = std::hint::black_box(
            velm::elm::train::HiddenLayer::transform(&mut hw, &[0.37]),
        );
    });
}
