//! Fig. 5 + Fig. 6: neuron transfer function — closed-form theory
//! (eq. 8) vs the transient circuit simulation, across VDD.
//!
//!     cargo bench --bench fig5_6_neuron

use velm::bench::{bench, section, Table};
use velm::chip::{counter, neuron};
use velm::config::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();

    section("Fig 5(a): f_sp vs I^z — quadratic with peak at I_flx");
    let mut t = Table::new(&["I^z / I_rst", "f_sp theory (kHz)", "H (counts, b=14)"]);
    for k in [0.05, 0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.95, 1.0] {
        let i = k * cfg.i_rst();
        let f = neuron::f_sp(i, &cfg);
        t.row(&[
            format!("{k:.2}"),
            format!("{:.1}", f / 1e3),
            format!("{}", counter::count(f, &cfg)),
        ]);
    }
    t.print();
    println!(
        "peak at I_flx = I_rst/2 = {:.1} nA, f_max = {:.1} kHz; counter caps at {}",
        cfg.i_flx() * 1e9,
        neuron::f_max(&cfg) / 1e3,
        cfg.cap()
    );

    section("Fig 6(a): theory (eq. 8) vs transient simulation (log sweep)");
    let mut t = Table::new(&["I^z (nA)", "theory (kHz)", "transient (kHz)", "dev %"]);
    let mut worst: f64 = 0.0;
    for k in 0..10 {
        let i = cfg.i_rst() * (0.02 * 1.55f64.powi(k)).min(0.98);
        let theory = neuron::f_sp(i, &cfg);
        let sim = neuron::transient(i, 60.0 / theory, &cfg, 200);
        let dev = (sim.freq - theory).abs() / theory * 100.0;
        worst = worst.max(dev);
        t.row(&[
            format!("{:.2}", i * 1e9),
            format!("{:.2}", theory / 1e3),
            format!("{:.2}", sim.freq / 1e3),
            format!("{dev:.2}"),
        ]);
    }
    t.print();
    println!("worst deviation {worst:.2}% — paper: 'close match' (Fig 6a)");

    section("Fig 6(b): f_sp vs I^z for VDD in {0.8, 1.0, 1.2} V");
    let mut t = Table::new(&["VDD (V)", "K_neu (kHz/nA)", "I_flx (nA)", "f_max (kHz)"]);
    for vdd in [0.8, 1.0, 1.2] {
        let c = cfg.clone().with_vdd(vdd);
        t.row(&[
            format!("{vdd:.1}"),
            format!("{:.1}", c.k_neu() * 1e-12),
            format!("{:.1}", c.i_flx() * 1e9),
            format!("{:.1}", neuron::f_max(&c) / 1e3),
        ]);
    }
    t.print();
    println!("paper shape: higher VDD -> larger I_flx and f_max; lower VDD -> higher small-signal gain");

    section("timing");
    bench("transient 60 cycles @200 steps", 0.3, || {
        let i = 0.3 * cfg.i_rst();
        let f = neuron::f_sp(i, &cfg);
        std::hint::black_box(neuron::transient(i, 60.0 / f, &cfg, 200));
    });
}
