//! Fig. 9: speed trade-offs — active-mirror bandwidth boost, T_cm vs
//! T_neu as functions of I_max and b, and the eq. 20 crossover contours.
//!
//!     cargo bench --bench fig9_speed

use velm::bench::{section, Table};
use velm::chip::{mirror, timing};
use velm::config::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();

    section("Fig 9(a): active current mirror bandwidth boost");
    let code_small = 32u16; // 4 MSBs zero -> S1 engages
    let bw_plain = {
        let mut c = cfg.clone();
        c.active_mirror = false;
        mirror::bandwidth_effective(code_small, &c)
    };
    let bw_active = mirror::bandwidth_effective(code_small, &cfg);
    println!(
        "code {code_small}: passive {:.1} kHz -> active {:.1} kHz = {:.2}x \
         (paper SPICE: 5.84x)",
        bw_plain / 1e3,
        bw_active / 1e3,
        bw_active / bw_plain
    );

    section("Fig 9(b): T_cm and T_neu vs I_max (d = 10)");
    let mut t = Table::new(&[
        "I_max (nA)", "T_cm passive (us)", "T_cm active (us)",
        "T_neu b=8 (us)", "T_neu b=12 (us)",
    ]);
    for &i_max_na in &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let mut c = cfg.clone().with_dims(10, 128).with_i_max(i_max_na * 1e-9);
        let i_max_z = c.i_max_z();
        c.active_mirror = false;
        let tcm_passive = timing::t_cm_mid(&c);
        c.active_mirror = true;
        let tcm_active = 0.5 * (mirror::t_cm_max(&c) + mirror::t_cm_min(&c));
        let tneu8 = {
            let c8 = c.clone().with_b(8);
            timing::t_neu_for(i_max_z, &c8)
        };
        let tneu12 = {
            let c12 = c.clone().with_b(12);
            timing::t_neu_for(i_max_z, &c12)
        };
        t.row(&[
            format!("{i_max_na:.2}"),
            format!("{:.2}", tcm_passive * 1e6),
            format!("{:.2}", tcm_active * 1e6),
            format!("{:.2}", tneu8 * 1e6),
            format!("{:.2}", tneu12 * 1e6),
        ]);
    }
    t.print();
    println!("paper shape: all fall with I_max; T_neu grows 16x from b=8 to b=12");

    section("Fig 9(c): eq. 20 contours (2^b where T_cm = T_neu) per VDD");
    let mut t = Table::new(&["d", "b* @0.8V", "b* @1.0V", "b* @1.2V"]);
    for &d in &[2usize, 8, 32, 128] {
        let row: Vec<String> = [0.8, 1.0, 1.2]
            .iter()
            .map(|&v| format!("{:.1}", timing::contour_bits(d, &cfg.clone().with_vdd(v))))
            .collect();
        t.row(&[format!("{d}"), row[0].clone(), row[1].clone(), row[2].clone()]);
    }
    t.print();
    println!(
        "operating regime at (d=128, b=10, VDD=1): {:?} — paper: T_neu dominates",
        timing::regime(&cfg.clone().with_b(10))
    );
}
