//! Ablations over the design choices DESIGN.md calls out: what each
//! mechanism buys, measured on the behavioural stack.
//!
//!     cargo bench --bench ablations
//!
//!  A1  active current mirror on/off        -> conversion time
//!  A2  quadratic vs linear neuron transfer -> accuracy
//!  A3  thermal-noise injection on/off      -> accuracy
//!  A4  eq. 26 normalisation on/off         -> nominal-corner accuracy cost
//!  A5  batcher max_wait                    -> serving latency/throughput
//!  A6  router least-loaded vs single die   -> saturation throughput

use std::time::Duration;

use velm::bench::{section, Table};
use velm::chip::{timing, ChipModel};
use velm::config::{ChipConfig, SystemConfig, Transfer};
use velm::coordinator::{workload, Coordinator};
use velm::datasets::synth;
use velm::elm::{self, ChipHidden};

fn accuracy(cfg: &ChipConfig, normalize: bool, seed: u64) -> f64 {
    let ds = synth::australian(3).with_test_subsample(230, 3);
    let mut cfg = cfg.clone();
    cfg.d = ds.d();
    let chip = ChipModel::fabricate(cfg, seed);
    let mut hidden = if normalize {
        ChipHidden::normalized(chip)
    } else {
        ChipHidden::new(chip)
    };
    let (model, _) = elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, normalize)
        .expect("train");
    elm::eval_classification(&mut hidden, &model, &ds.test_x, &ds.test_y)
}

fn main() {
    section("A1: active current mirror -> worst-case conversion time");
    let mut t = Table::new(&["config", "T_c worst small-code (us)", "T_c full-scale (us)"]);
    for active in [false, true] {
        let mut cfg = ChipConfig::default();
        cfg.active_mirror = active;
        let small = vec![1u16; cfg.d]; // LSB codes: worst settling
        let big = vec![1023u16; cfg.d];
        t.row(&[
            if active { "active mirror ON" } else { "passive only" }.into(),
            format!("{:.1}", timing::t_c(&small, &cfg) * 1e6),
            format!("{:.1}", timing::t_c(&big, &cfg) * 1e6),
        ]);
    }
    t.print();
    println!("the 5.84x boost bounds worst-case settling (Fig 9a rationale)");

    section("A2: neuron transfer shape -> classification error");
    let quad = accuracy(&ChipConfig::default().with_b(10), false, 9);
    let lin = accuracy(
        &ChipConfig::default().with_b(10).with_mode(Transfer::Linear),
        false,
        9,
    );
    println!("quadratic (eq. 8): {:.2}%   linear (eq. 9): {:.2}%", quad * 100.0, lin * 100.0);
    println!("both work — the counter saturation supplies the essential nonlinearity");

    section("A3: thermal-noise injection -> classification error");
    let clean = accuracy(&ChipConfig::default().with_b(10), false, 10);
    let noisy = accuracy(&ChipConfig::default().with_b(10).with_noise(true), false, 10);
    println!("noise off: {:.2}%   noise on (eq. 14): {:.2}%", clean * 100.0, noisy * 100.0);
    println!("C = 0.4 pF SNR sizing keeps the penalty negligible (Section IV-A)");

    section("A4: eq. 26 normalisation -> nominal-corner cost");
    let raw = accuracy(&ChipConfig::default().with_b(10), false, 11);
    let norm = accuracy(&ChipConfig::default().with_b(10), true, 11);
    println!("raw: {:.2}%   normalised: {:.2}%", raw * 100.0, norm * 100.0);
    println!("normalisation costs ~nothing at nominal; it pays off off-corner (Fig 17/18)");

    section("A5: batcher max_wait -> latency vs batch occupancy");
    let ds = synth::brightdata(1).with_test_subsample(100, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let mut t = Table::new(&["max_wait", "p50 (us)", "p99 (us)", "mean batch", "req/s"]);
    for wait_ms in [0u64, 1, 5, 20] {
        let sys = SystemConfig {
            n_chips: 2,
            max_wait: Duration::from_millis(wait_ms),
            artifact_dir: "/nonexistent".into(),
            ..Default::default()
        };
        let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)
            .expect("coord");
        let lp = workload::closed_loop(&coord, &ds.test_x, 8, 50);
        t.row(&[
            format!("{wait_ms} ms"),
            format!("{}", lp.p50_us),
            format!("{}", lp.p99_us),
            format!("{:.1}", lp.mean_batch),
            format!("{:.0}", lp.achieved_rps),
        ]);
        coord.shutdown();
    }
    t.print();
    println!("longer holds grow batches (good for the PJRT path) at a latency cost");

    section("A6: die pool size -> saturation throughput");
    let mut t = Table::new(&["dies", "req/s closed-loop (8 clients)"]);
    for n_chips in [1usize, 2, 4] {
        let sys = SystemConfig {
            n_chips,
            max_wait: Duration::ZERO, // isolate compute scaling from batching holds
            artifact_dir: "/nonexistent".into(),
            ..Default::default()
        };
        let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)
            .expect("coord");
        let lp = workload::closed_loop(&coord, &ds.test_x, 8, 60);
        t.row(&[format!("{n_chips}"), format!("{:.0}", lp.achieved_rps)]);
        coord.shutdown();
    }
    t.print();
}
