//! Table III: speed/power operating points and the energy-efficiency
//! headline — 0.47 pJ/MAC at a 31.6 kHz classification rate, 404.5
//! MMAC/s, plus the 0.7 V low-power point and the 1 V maximum-speed
//! point, all derived from the Section IV models on the behavioural die.
//!
//!     cargo bench --bench table3_comparison

use velm::bench::{section, Table};
use velm::chip::{timing, ChipModel};
use velm::config::ChipConfig;
use velm::util::prng::Prng;

/// Measure one operating point on the behavioural die (the Keithley
/// stand-in): conversion rate, supply power, energy efficiency, and the
/// fraction of neurons actually oscillating (not stalled / not starved).
fn measure(cfg: &ChipConfig, seed: u64, data_in: u16) -> (f64, f64, f64, f64) {
    let mut chip = ChipModel::fabricate(cfg.clone(), seed);
    let codes = vec![data_in; cfg.d];
    chip.reset_ledger();
    let mut active = 0usize;
    let mut total = 0usize;
    for _ in 0..20 {
        let h = chip.forward(&codes);
        active += h.iter().filter(|&&c| c > 0).count();
        total += h.len();
    }
    let rate = chip.ledger.rate();
    let power = chip.ledger.energy / chip.ledger.sim_time;
    (rate, power, chip.ledger.pj_per_mac(), active as f64 / total as f64)
}

/// Configure the die like the Section VI-B measurement: d=128, L=100
/// active, 2^b = 128 (b=7), Data_in = 1000, and pick I_max so the
/// conversion rate lands near `target_rate` — considering only *valid*
/// points where at least half the neurons are actually spiking (the
/// paper's measurements obviously had working neurons).
fn operating_point(vdd: f64, target_rate: f64, seed: u64) -> (ChipConfig, f64, f64, f64) {
    let mut best: Option<(ChipConfig, f64, f64, f64)> = None;
    let mut rng = Prng::new(seed);
    let _ = &mut rng;
    for k in 1..=60 {
        let i_max = 0.02e-9 * 1.15f64.powi(k);
        let cfg = ChipConfig::default()
            .with_dims(128, 100)
            .with_b(7)
            .with_vdd(vdd)
            .with_i_max(i_max);
        let (rate, power, pj, active) = measure(&cfg, seed, 1000);
        if active < 0.5 {
            continue; // stalled or starved array: not a usable point
        }
        let better = match &best {
            None => true,
            Some((_, r, _, _)) => (rate - target_rate).abs() < (r - target_rate).abs(),
        };
        if better {
            best = Some((cfg, rate, power, pj));
        }
    }
    best.expect("no valid operating point found")
}

fn main() {
    section("Table III operating points (d=128, L=100, b=7, Data_in=1000)");
    let mut t = Table::new(&[
        "point", "VDD", "rate (kHz)", "power (uW)", "pJ/MAC", "MMAC/s",
        "paper rate", "paper power", "paper pJ/MAC",
    ]);
    // 0.7 V low-power point (paper: 4.5 kHz, 17.85 uW)
    let (cfg, rate, power, pj) = operating_point(0.7, 4.5e3, 1);
    t.row(&[
        "low-power".into(),
        "0.7".into(),
        format!("{:.1}", rate / 1e3),
        format!("{:.1}", power * 1e6),
        format!("{pj:.2}"),
        format!("{:.1}", rate * (cfg.d * cfg.l) as f64 / 1e6),
        "4.5 kHz".into(),
        "17.85 uW".into(),
        "-".into(),
    ]);
    // 1 V energy-optimal point (paper headline: 31.6 kHz, 188.8 uW, 0.47)
    let (cfg, rate, power, pj) = operating_point(1.0, 31.6e3, 2);
    let headline_pj = pj;
    t.row(&[
        "optimal".into(),
        "1.0".into(),
        format!("{:.1}", rate / 1e3),
        format!("{:.1}", power * 1e6),
        format!("{pj:.2}"),
        format!("{:.1}", rate * (cfg.d * cfg.l) as f64 / 1e6),
        "31.6 kHz".into(),
        "188.8 uW".into(),
        "0.47".into(),
    ]);
    // 1 V maximum-speed point (paper: 146.25 kHz, 2.2 mW)
    let (cfg, rate, power, pj) = operating_point(1.0, 146.25e3, 3);
    t.row(&[
        "max-speed".into(),
        "1.0".into(),
        format!("{:.1}", rate / 1e3),
        format!("{:.1}", power * 1e6),
        format!("{pj:.2}"),
        format!("{:.1}", rate * (cfg.d * cfg.l) as f64 / 1e6),
        "146.25 kHz".into(),
        "2200 uW".into(),
        "-".into(),
    ]);
    t.print();

    section("whole-system estimate (with digital second stage)");
    // Section VI-B: 7.1 pJ per 14x10-bit multiply at 1.5 V, L multiplies
    let e_mult = 7.1e-12;
    let l = 100usize;
    let d = 128usize;
    let e_first = headline_pj * 1e-12 * (d * l) as f64;
    let e_total = e_first + velm::elm::secondstage::second_stage_energy(l, e_mult);
    println!(
        "first stage {:.3} pJ/MAC + second stage {} x 7.1 pJ => system {:.2} pJ/MAC \
         (paper: 0.47 -> 0.54 pJ/MAC)",
        headline_pj,
        l,
        e_total / (d * l) as f64 * 1e12
    );

    section("comparison-table context (fixed numbers from the paper)");
    let mut t = Table::new(&["work", "tech", "algorithm", "pJ/MAC", "rate"]);
    t.rowf(&["JSSC'13 [27]", "0.13 um digital", "SVM", "631", "0.5-2 Hz"]);
    t.rowf(&["JSSC'07 [25]", "0.5 um FG analog", "SVM", "0.8", "40 Hz"]);
    t.rowf(&["ISCAS'15 [18]", "0.35 um mixed", "ELM", "3.4", "50 Hz"]);
    t.row(&[
        "this work (model)".into(),
        "0.35 um mixed".into(),
        "ELM".into(),
        format!("{headline_pj:.2}"),
        "31.6 kHz".into(),
    ]);
    t.print();

    section("eq. 20 sanity at the measured point");
    let cfg = ChipConfig::default().with_dims(128, 100).with_b(7);
    println!(
        "regime at (d=128, b=7): {:?}; contour b* = {:.1} bits",
        timing::regime(&cfg),
        timing::contour_bits(128, &cfg)
    );
}
