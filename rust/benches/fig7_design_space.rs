//! Fig. 7 — the paper's central design-space exploration:
//!  (a) L_min vs I_sat^z/I_max^z across sigma_VT (optimum ratio ~0.75,
//!      best sigma_VT 15-25 mV);
//!  (b) classification accuracy vs beta resolution (10 bits suffice);
//!  (c) classification accuracy vs counter bits (b ~ 6 suffices).
//!
//!     cargo bench --bench fig7_design_space [-- --quick]

use velm::bench::{section, Table};
use velm::dse::{self, lmin, FastSim};
use velm::elm::secondstage::QuantBeta;
use velm::util::mat::{ridge_solve, Mat};
use velm::util::prng::Prng;

/// Classification error on a synthetic brightdata-style task through the
/// FastSim first stage, with beta quantised to `beta_bits`.
fn classify_error(sim: &FastSim, l: usize, beta_bits: u32, seed: u64) -> f64 {
    let ds = velm::datasets::synth::brightdata(seed).with_test_subsample(500, seed);
    let mut rng = Prng::new(seed ^ 0xF17);
    let w = sim.sample_weights(ds.d(), l, &mut rng);
    let scale = 1.0 / sim.cap();
    let mut h_tr = sim.hidden(&ds.train_x, &w);
    h_tr.scale(scale);
    let t = Mat { rows: ds.train_y.len(), cols: 1, data: ds.train_y.clone() };
    let beta = match ridge_solve(&h_tr, &t, 1e-4) {
        Ok(b) => b,
        Err(_) => return 1.0,
    };
    let q = QuantBeta::quantize(&beta.data, beta_bits);
    let bq = q.dequantize();
    let mut h_te = sim.hidden(&ds.test_x, &w);
    h_te.scale(scale);
    let scores = h_te.matvec(&bq);
    velm::elm::train::misclassification(&scores, &ds.test_y)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads = dse::default_threads();
    let trials = if quick { 2 } else { 5 };

    section("Fig 7(a): L_min (error <= 0.08 on sinc regression) vs ratio x sigma_VT");
    let ratios = [0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.5];
    let sigmas = [0.005, 0.015, 0.025, 0.045];
    let mut t = Table::new(&["ratio \\ sigma_VT", "5 mV", "15 mV", "25 mV", "45 mV"]);
    let jobs: Vec<(f64, f64)> = ratios
        .iter()
        .flat_map(|&r| sigmas.iter().map(move |&s| (r, s)))
        .collect();
    let res = dse::par_map(jobs, threads, |(r, s)| {
        let sim = FastSim { ratio: r, sigma_vt: s, ..Default::default() };
        lmin::l_min(&sim, &lmin::default_l_grid(), 0.08, 600, trials, 41)
    });
    for (ri, &r) in ratios.iter().enumerate() {
        let mut cells = vec![format!("{r:.2}")];
        for si in 0..sigmas.len() {
            cells.push(
                res[ri * sigmas.len() + si]
                    .map_or(">256".to_string(), |v| v.to_string()),
            );
        }
        t.row(&cells);
    }
    t.print();
    println!("paper: optimum ratio ~0.75; L_min smallest for sigma_VT in 15-25 mV;");
    println!("small sigma degrades sharply away from the optimum, large sigma is flat.");

    section("Fig 7(b): classification error vs beta resolution (L = 128)");
    let sim = FastSim::default();
    let bits: Vec<u32> = vec![2, 3, 4, 6, 8, 10, 12, 16];
    let errs = dse::par_map(bits.clone(), threads, |b| {
        let e: f64 = (0..trials as u64)
            .map(|k| classify_error(&sim, 128, b, 50 + k))
            .sum::<f64>()
            / trials as f64;
        e
    });
    let mut t = Table::new(&["beta bits", "error %"]);
    for (b, e) in bits.iter().zip(&errs) {
        t.row(&[format!("{b}"), format!("{:.2}", e * 100.0)]);
    }
    t.print();
    println!("paper: 10 bits is sufficient (error flat beyond ~10 bits).");

    section("Fig 7(c): classification error vs counter bits b (ratio 0.75, beta 10b)");
    let cbits: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 8, 10];
    let errs = dse::par_map(cbits.clone(), threads, |b| {
        let sim = FastSim { b, ..Default::default() };
        let e: f64 = (0..trials as u64)
            .map(|k| classify_error(&sim, 128, 10, 60 + k))
            .sum::<f64>()
            / trials as f64;
        e
    });
    let mut t = Table::new(&["counter bits", "error %"]);
    for (b, e) in cbits.iter().zip(&errs) {
        t.row(&[format!("{b}"), format!("{:.2}", e * 100.0)]);
    }
    t.print();
    println!("paper: b ~ 6 is enough for classification.");
}
