//! Table II: misclassification on the UCI-shaped binary tasks — hardware
//! ELM (chip, L = 128, 10-bit beta) vs software float ELM (sigmoid,
//! L = 1000) — plus the Section VI-D dimension-extension measurements
//! (leukemia d = 7129; diabetes L = 16 -> 128 by weight reuse).
//!
//!     cargo bench --bench table2_uci [-- --full]

use velm::bench::{section, Table};
use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, softelm::SoftElm, ChipHidden};
use velm::extension::VirtualChip;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let seed = 1u64;
    section("Table II: hardware (L=128) vs software (L=1000) misclassification");
    let paper: &[(&str, f64, f64)] = &[
        ("diabetes", 22.05, 22.91),
        ("australian", 13.82, 12.11),
        ("brightdata", 0.69, 1.26),
        ("adult", 15.41, 15.57),
    ];
    let mut table = Table::new(&[
        "Dataset", "d", "Ntr", "Nte",
        "SW% paper", "SW% ours", "HW% paper", "HW% ours", "gap paper", "gap ours",
    ]);
    for &(name, swp, hwp) in paper {
        let mut ds = synth::by_name(name, seed).unwrap();
        if !full {
            ds = ds.with_test_subsample(800, seed);
        }
        let mut soft = SoftElm::new(ds.d(), 1000, seed + 10);
        let (swm, _) = elm::train_model(&mut soft, &ds.train_x, &ds.train_y, 50.0, 32, false)
            .expect("sw train");
        let sw = elm::eval_classification(&mut soft, &swm, &ds.test_x, &ds.test_y) * 100.0;
        let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
        let mut hw = ChipHidden::new(ChipModel::fabricate(cfg, seed + 20));
        let (hwm, _) = elm::train_model(&mut hw, &ds.train_x, &ds.train_y, 0.1, 10, false)
            .expect("hw train");
        let hwv =
            elm::eval_classification_fixed(&mut hw, &hwm, &ds.test_x, &ds.test_y) * 100.0;
        table.row(&[
            name.to_string(),
            format!("{}", ds.d()),
            format!("{}", ds.n_train()),
            format!("{}", ds.n_test()),
            format!("{swp:.2}"),
            format!("{sw:.2}"),
            format!("{hwp:.2}"),
            format!("{hwv:.2}"),
            format!("{:+.2}", hwp - swp),
            format!("{:+.2}", hwv - sw),
        ]);
    }
    table.print();
    println!("claim under test: HW tracks SW within a couple of points on every set.");

    section("Section VI-D: leukemia (d = 7129) via input-dimension extension");
    let ds = synth::leukemia(5);
    let cfg = ChipConfig::default().with_dims(128, 128).with_b(10);
    let mut vchip = VirtualChip::new(ChipModel::fabricate(cfg, 21), ds.d(), 128).unwrap();
    let (m, _) = elm::train_model(&mut vchip, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .expect("leukemia train");
    let err = elm::eval_classification(&mut vchip, &m, &ds.test_x, &ds.test_y) * 100.0;
    println!(
        "leukemia: {err:.1}% over {} passes/sample (paper HW 20.59%, SW 19.92%)",
        vchip.plan.passes()
    );

    section("Section VI-D: diabetes hidden extension L = 16 -> 128");
    let ds = synth::diabetes(6);
    let small = ChipConfig::default().with_dims(ds.d(), 16).with_b(10);
    let mut s16 = ChipHidden::new(ChipModel::fabricate(small.clone(), 22));
    let (m16, _) = elm::train_model(&mut s16, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .expect("L16 train");
    let e16 = elm::eval_classification(&mut s16, &m16, &ds.test_x, &ds.test_y) * 100.0;
    let mut v128 = VirtualChip::new(ChipModel::fabricate(small, 22), ds.d(), 128).unwrap();
    let (m128, _) = elm::train_model(&mut v128, &ds.train_x, &ds.train_y, 0.1, 10, false)
        .expect("L128 train");
    let e128 = elm::eval_classification(&mut v128, &m128, &ds.test_x, &ds.test_y) * 100.0;
    println!("diabetes: L=16 {e16:.1}% -> virtual L=128 {e128:.1}% (paper: 27.1% -> 22.4%)");
    // our calibrated small-die starting point is better than the paper's
    // (27.1%); the claim that survives is "expansion never hurts and
    // recovers the large-die error"
    assert!(e128 <= e16 + 3.0, "hidden extension degraded accuracy (pct points)");
}
