//! Table IV: sinc regression error across VDD with weights trained at
//! the nominal 1 V — raw vs eq. 26 normalised hidden outputs.
//!
//!     cargo bench --bench table4_normalization
//!
//! Paper: raw errors {0.59, 0.045, 0.15} at {0.8, 1.0, 1.2} V collapse
//! to {0.076, 0.063, 0.065} with normalisation.

use velm::bench::{section, Table};
use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::{self, train::HiddenLayer, ChipHidden};

fn run(normalize: bool, vdds: &[f64]) -> Vec<f64> {
    let ds = synth::sinc(2000, 300, 0.2, 3);
    let cfg = ChipConfig::default().with_dims(1, 128).with_b(12);
    let chip = ChipModel::fabricate(cfg, 11);
    let mut hidden = if normalize {
        ChipHidden::normalized(chip)
    } else {
        ChipHidden::new(chip)
    };
    // train at nominal VDD = 1 V
    hidden.chip.set_vdd(1.0);
    let (model, _) = elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 1e-4, 14, normalize)
        .expect("train");
    vdds.iter()
        .map(|&v| {
            hidden.chip.set_vdd(v);
            let h = velm::elm::train::assemble_h(&mut hidden, &ds.test_x);
            velm::util::stats::rmse(
                &velm::elm::train::predict(&h, &model.head),
                &ds.test_y,
            )
        })
        .collect()
}

fn main() {
    section("Table IV: sinc regression error vs VDD (trained at 1 V)");
    let vdds = [0.8, 1.0, 1.2];
    let raw = run(false, &vdds);
    let norm = run(true, &vdds);
    let paper_raw = [0.5924, 0.045, 0.1538];
    let paper_norm = [0.076, 0.0629, 0.065];
    let mut t = Table::new(&[
        "VDD (V)", "raw err (ours)", "raw err (paper)", "norm err (ours)", "norm err (paper)",
    ]);
    for i in 0..3 {
        t.row(&[
            format!("{:.1}", vdds[i]),
            format!("{:.4}", raw[i]),
            format!("{:.4}", paper_raw[i]),
            format!("{:.4}", norm[i]),
            format!("{:.4}", paper_norm[i]),
        ]);
    }
    t.print();
    let raw_spread = raw.iter().cloned().fold(f64::MIN, f64::max)
        / raw.iter().cloned().fold(f64::MAX, f64::min);
    let norm_spread = norm.iter().cloned().fold(f64::MIN, f64::max)
        / norm.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "spread across VDD: raw {raw_spread:.1}x vs normalised {norm_spread:.1}x — \
         normalisation flattens the VDD dependence (the Table IV claim)"
    );
    let _ = |h: &mut ChipHidden| h.hidden_dim(); // keep trait import used
}
