//! §Perf hot-path benchmarks (EXPERIMENTS.md §Perf): the scalar chip
//! conversion, the training-path linear algebra, the PJRT batched hidden
//! stage (when artifacts are built), and coordinator overhead.
//!
//!     make artifacts && cargo bench --bench perf_hotpath

use std::path::Path;
use std::time::Instant;

use velm::bench::{bench, section};
use velm::chip::ChipModel;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::runtime::PjrtEngine;
use velm::util::mat::{ridge_solve, Mat};
use velm::util::prng::Prng;

fn main() {
    let cfg = ChipConfig::default();
    let mut rng = Prng::new(1);

    section("L3 scalar chip conversion (d=128, L=128)");
    let mut chip = ChipModel::fabricate(cfg.clone(), 1);
    let codes: Vec<u16> = (0..cfg.d).map(|_| rng.usize(1024) as u16).collect();
    let t = bench("chip.forward 128x128", 0.5, || {
        std::hint::black_box(chip.forward(&codes));
    });
    println!(
        "  => {:.1} MMAC/s scalar-sim throughput",
        (cfg.d * cfg.l) as f64 / t.median_s / 1e6
    );
    let mut noisy_chip = ChipModel::fabricate(cfg.clone().with_noise(true), 1);
    bench("chip.forward 128x128 (noise on)", 0.5, || {
        std::hint::black_box(noisy_chip.forward(&codes));
    });

    section("training-path linear algebra");
    let h = Mat::from_fn(1000, 128, |i, j| ((i * 31 + j * 17) % 97) as f64 / 97.0);
    let t_mat = Mat::from_fn(1000, 1, |i, _| if i % 2 == 0 { 1.0 } else { -1.0 });
    bench("gram 1000x128", 0.5, || {
        std::hint::black_box(h.gram());
    });
    bench("ridge_solve 1000x128", 0.5, || {
        std::hint::black_box(ridge_solve(&h, &t_mat, 1e-2).unwrap());
    });
    let a = Mat::from_fn(256, 256, |i, j| ((i * 7 + j * 13) % 101) as f64 / 101.0);
    let b = Mat::from_fn(256, 256, |i, j| ((i * 11 + j * 3) % 103) as f64 / 103.0);
    bench("matmul 256^3", 0.5, || {
        std::hint::black_box(a.matmul(&b));
    });

    section("L1/L2 PJRT batched hidden stage");
    let dir = Path::new("artifacts");
    // artifacts may exist while the engine doesn't (default build has
    // the stub behind the `pjrt` feature): skip the section either way
    let engine = if velm::runtime::artifacts_available(dir) {
        match PjrtEngine::new(dir) {
            Ok(e) => Some(e),
            Err(e) => {
                println!("PJRT engine unavailable ({e:#})");
                None
            }
        }
    } else {
        None
    };
    if let Some(mut engine) = engine {
        println!("platform: {}", engine.platform());
        let mut chip = ChipModel::fabricate(cfg.clone(), 1);
        let w: Vec<f32> = chip.weights().to_f32();
        for &bsz in &[1usize, 32, 128, 512] {
            let codes: Vec<f32> = (0..bsz * cfg.d)
                .map(|k| ((k * 37) % 1024) as f32)
                .collect();
            // warm the executable cache before timing
            let _ = engine
                .hidden(&codes, bsz, cfg.d, cfg.l, &w, false)
                .expect("hidden");
            let t = bench(&format!("pjrt hidden b={bsz}"), 0.5, || {
                std::hint::black_box(
                    engine.hidden(&codes, bsz, cfg.d, cfg.l, &w, false).unwrap(),
                );
            });
            println!(
                "  => {:.1} MMAC/s batched",
                (bsz * cfg.d * cfg.l) as f64 / t.median_s / 1e6
            );
        }
    } else {
        println!(
            "PJRT path skipped (artifacts not built, or engine needs `--features pjrt`)"
        );
    }

    section("coordinator end-to-end (2 dies, in-proc)");
    let ds = velm::datasets::synth::brightdata(1);
    let mut chip_cfg = cfg.clone();
    chip_cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 2,
        artifact_dir: "/nonexistent".into(), // isolate coordinator overhead
        ..Default::default()
    };
    let train: Vec<Vec<f64>> = ds.train_x.iter().take(200).cloned().collect();
    let ty: Vec<f64> = ds.train_y.iter().take(200).cloned().collect();
    let coord = Coordinator::start(&sys, &chip_cfg, &train, &ty, 0.1, 10).expect("coord");
    let t0 = Instant::now();
    let n = 2000;
    let rxs: Vec<_> = (0..n)
        .map(|i| coord.submit(ds.test_x[i % ds.test_x.len()].clone()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "coordinator: {n} requests in {dt:.3} s = {:.0} req/s; {}",
        n as f64 / dt,
        coord.metrics.report()
    );
    coord.shutdown();
}
