//! Figs. 17 + 18 and Table IV context: robustness of the hidden layer to
//! VDD and temperature variations, with and without the eq. 26
//! normalisation (Section VI-F).
//!
//!     cargo bench --bench fig17_18_robustness
//!
//! Paper: VDD variation of h_j 22.7% raw -> 4.2% normalised; temperature
//! error grows fast raw, slowly normalised.

use velm::bench::{section, Table};
use velm::chip::ChipModel;
use velm::config::ChipConfig;
use velm::datasets::synth;
use velm::elm::secondstage::{codes_sum, normalize_h};
#[allow(unused_imports)]
use velm::elm::{self, ChipHidden};
use velm::util::stats;

/// Hidden outputs of neuron j for a probe input at several VDDs.
fn vdd_sweep(cfg: &ChipConfig, seed: u64, code: u16, vdds: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut raw_spread = Vec::new();
    let mut norm_spread = Vec::new();
    let mut chip = ChipModel::fabricate(cfg.clone(), seed);
    let codes = vec![code; cfg.d];
    // collect per-neuron outputs at each VDD
    let mut raw: Vec<Vec<f64>> = vec![Vec::new(); cfg.l];
    let mut nrm: Vec<Vec<f64>> = vec![Vec::new(); cfg.l];
    for &v in vdds {
        chip.set_vdd(v);
        let h = chip.forward(&codes);
        let hn = normalize_h(&h, codes_sum(&codes));
        for j in 0..cfg.l {
            raw[j].push(h[j] as f64);
            nrm[j].push(hn[j]);
        }
    }
    for j in 0..cfg.l {
        if raw[j].iter().any(|&x| x > 10.0) {
            raw_spread.push(stats::max_rel_spread_pct(&raw[j]));
            norm_spread.push(stats::max_rel_spread_pct(&nrm[j]));
        }
    }
    (raw_spread, norm_spread)
}

/// A hidden layer with an appended constant feature: the second stage's
/// trained intercept. With an intercept, a common-mode count gain (PTAT
/// bias drift) moves raw scores off their operating point — which is why
/// the paper's raw error climbs with temperature — while the eq. 26
/// normalisation cancels the gain before the MAC.
struct WithBias<T>(T);

impl<T: velm::elm::train::HiddenLayer> velm::elm::train::HiddenLayer for WithBias<T> {
    fn input_dim(&self) -> usize {
        self.0.input_dim()
    }
    fn hidden_dim(&self) -> usize {
        self.0.hidden_dim() + 1
    }
    fn transform(&mut self, x: &[f64]) -> Vec<f64> {
        let mut h = self.0.transform(x);
        h.push(1.0);
        h
    }
}

fn temperature_error(name: &str, normalize: bool, temps: &[f64]) -> Vec<f64> {
    let ds = synth::by_name(name, 7).unwrap().with_test_subsample(400, 7);
    let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
    // train at nominal temperature (with intercept)
    let chip = ChipModel::fabricate(cfg.clone(), 33);
    let mut hidden = WithBias(if normalize {
        ChipHidden::normalized(chip)
    } else {
        ChipHidden::new(chip)
    });
    let (model, _) =
        elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, normalize)
            .expect("train");
    // test across temperatures (float head; the intercept is the last beta)
    temps
        .iter()
        .map(|&t| {
            hidden.0.chip.set_temp(t);
            elm::eval_classification(&mut hidden, &model, &ds.test_x, &ds.test_y) * 100.0
        })
        .collect()
}

fn main() {
    let cfg = ChipConfig::default();
    let vdds = [0.8, 1.0, 1.2];

    section("Fig 17: hidden-output variation across VDD {0.8, 1.0, 1.2} V");
    let (raw, norm) = vdd_sweep(&cfg, 13, 700, &vdds);
    println!(
        "raw h_j:        max spread {:.1}% (mean {:.1}%)   [paper: max 22.7%]",
        raw.iter().cloned().fold(f64::MIN, f64::max),
        stats::mean(&raw)
    );
    println!(
        "normalised h_j: max spread {:.1}% (mean {:.1}%)   [paper: max 4.2%]",
        norm.iter().cloned().fold(f64::MIN, f64::max),
        stats::mean(&norm)
    );

    section("Fig 18: classification error vs temperature (train at 300 K)");
    let temps = [280.0, 290.0, 300.0, 310.0, 320.0];
    for name in ["australian", "brightdata"] {
        let raw = temperature_error(name, false, &temps);
        let nrm = temperature_error(name, true, &temps);
        let mut t = Table::new(&["T (K)", "raw err %", "normalised err %"]);
        for (i, &tk) in temps.iter().enumerate() {
            t.row(&[format!("{tk:.0}"), format!("{:.2}", raw[i]), format!("{:.2}", nrm[i])]);
        }
        println!("\n{name}:");
        t.print();
        let raw_growth = (raw[0] - raw[2]).max(raw[4] - raw[2]);
        let nrm_growth = (nrm[0] - nrm[2]).max(nrm[4] - nrm[2]);
        println!(
            "error growth at +-20K: raw {raw_growth:+.2} pts vs normalised {nrm_growth:+.2} pts \
             (paper: raw grows rapidly, normalised slowly)"
        );
    }
}
