//! Autotuner performance: cost of one design-point evaluation, one
//! refinement round, and the cache's effect on repeated tunes — the
//! numbers that set how often a fleet can re-tune per workload shift.
//!
//!     cargo bench --bench autotune_explorer [-- --quick]

use velm::bench::{bench, section, Table};
use velm::datasets::synth;
use velm::dse::{self, EvalCache, Explorer, Objective, OperatingPoint, SearchSpace};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ds = synth::sinc(600, 256, 0.2, 1);
    let trials = if quick { 1 } else { 2 };

    section("single-point evaluation (FastSim fit + energy/timing models)");
    let paper_point = OperatingPoint {
        sigma_vt: 0.016,
        ratio: 0.75,
        b: 14,
        l: 64,
        batch: 16,
    };
    let mut objective = Objective::new(&ds, trials, 3);
    objective.max_train = if quick { 200 } else { 400 };
    bench("objective.evaluate (L=64)", 0.5, || {
        std::hint::black_box(objective.evaluate(&paper_point));
    });

    section("one refinement round vs cached re-tune");
    let space = SearchSpace {
        sigma_vt: (0.005, 0.045),
        ratio: (0.75, 0.75),
        sigma_steps: if quick { 3 } else { 5 },
        ratio_steps: 1,
        b: vec![10, 14],
        l: vec![32, 64],
        batch: vec![1, 16],
    };
    let threads = dse::default_threads();
    let explorer = Explorer {
        space,
        objective: Objective::new(&ds, trials, 3),
        rounds: 1,
        threads,
    };
    let cache = EvalCache::new();
    let t0 = std::time::Instant::now();
    let result = explorer.run_with_cache(&cache);
    let cold = t0.elapsed().as_secs_f64();
    println!(
        "cold tune: {} points in {:.2} s on {threads} threads",
        result.evals.len(),
        cold
    );

    // warm: the whole tune again through the shared cache
    let t1 = std::time::Instant::now();
    let warm_result = explorer.run_with_cache(&cache);
    let warm = t1.elapsed().as_secs_f64();
    println!(
        "warm tune: {} points in {:.4} s ({} cumulative hits) — {:.0}x faster",
        warm_result.evals.len(),
        warm,
        cache.hits(),
        if warm > 0.0 { cold / warm } else { f64::INFINITY }
    );

    section("front summary");
    let mut t = Table::new(&["sigma_VT (mV)", "ratio", "b", "L", "batch", "error", "pJ/MAC"]);
    for e in result.front.iter().take(8) {
        t.row(&[
            format!("{:.1}", e.point.sigma_vt * 1e3),
            format!("{:.2}", e.point.ratio),
            format!("{}", e.point.b),
            format!("{}", e.point.l),
            format!("{}", e.point.batch),
            format!("{:.4}", e.error),
            format!("{:.3}", e.energy_pj_per_mac),
        ]);
    }
    t.print();
    if let Some(k) = result.knee {
        println!("knee: {}", k.point);
    }
}
