//! Integration: the typed serving protocol (DESIGN.md §15).
//!
//!   * identical tenant traffic through the v0 line protocol, the v1
//!     framed protocol and the in-process `Client` answers
//!     bit-identically (at each wire's own precision);
//!   * a v1 `BatchPredict` of B rows enters the batcher as ONE
//!     submission (observed via `Metrics`), not B;
//!   * golden strings pin the v0 line grammar so the protocol redesign
//!     cannot silently break pre-protocol clients;
//!   * idle connections are reaped by `SystemConfig::read_timeout`;
//!   * the multiplexed connection reactor (DESIGN.md §20): 64
//!     concurrent v1 connections with 4 correlated requests in flight
//!     each answer bit-identically to the blocking path from a thread
//!     pool that does not grow with the connection count; streamed
//!     batch replies reassemble bit-exactly and start before the full
//!     batch lands; live `TenantUpdate` rows move a registered head and
//!     are refused outside the connection's HELLO scope; in-flight
//!     requests keep a connection alive across the read timeout.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{reactor, server, Coordinator};
use velm::datasets::synth;
use velm::protocol::{PredictRow, Prediction, Request, Response};
use velm::registry::TenantSpec;

/// One-die fleet (deterministic scores across paths) on brightdata,
/// plus a regression tenant so the traffic is multi-tenant.
fn start_system() -> (Arc<Coordinator>, velm::datasets::Dataset) {
    let ds = synth::brightdata(1).with_test_subsample(40, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let coord =
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start");
    let reg_y: Vec<f64> = ds.train_x.iter().map(|x| 0.5 * x[0] - 0.25 * x[1]).collect();
    coord
        .register_tenant(
            TenantSpec::regression("slope", ds.train_x.clone(), &reg_y, 1e-3, 12).unwrap(),
        )
        .unwrap();
    (Arc::new(coord), ds)
}

#[test]
fn v0_v1_and_in_process_answer_bit_identically() {
    let (coord, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 2).expect("serve");
    // identical tenant traffic: default and tenant rows interleaved
    let rows: Vec<PredictRow> = ds
        .test_x
        .iter()
        .take(12)
        .enumerate()
        .map(|(i, x)| PredictRow {
            tenant: if i % 3 == 0 { Some("slope".into()) } else { None },
            features: x.clone(),
        })
        .collect();

    // v0: the ASCII line grammar, one round-trip per row
    let mut v0 = Client::connect_v0(addr).expect("v0 connect");
    assert_eq!(v0.wire_version(), Some(0));
    let p0 = v0.predict_batch(&rows).expect("v0 predict");

    // v1: ONE framed BatchPredict carrying every row
    let subs0 = coord.metrics.submissions.load(Ordering::Relaxed);
    let resp0 = coord.metrics.responses.load(Ordering::Relaxed);
    let mut v1 = Client::connect(addr).expect("v1 connect");
    assert_eq!(v1.wire_version(), Some(1));
    let p1 = v1.predict_batch(&rows).expect("v1 predict");
    assert_eq!(
        coord.metrics.submissions.load(Ordering::Relaxed) - subs0,
        1,
        "a v1 BatchPredict of {} rows must be ONE batcher submission",
        rows.len()
    );
    assert_eq!(
        coord.metrics.responses.load(Ordering::Relaxed) - resp0,
        rows.len() as u64,
        "every batch row must still be answered"
    );

    // in-process: the same typed dispatcher, no sockets
    let mut local = Client::in_process(Arc::clone(&coord));
    assert_eq!(local.wire_version(), None);
    let pl = local.predict_batch(&rows).expect("in-process predict");

    assert_eq!(p0.len(), rows.len());
    assert_eq!(p1.len(), rows.len());
    assert_eq!(pl.len(), rows.len());
    for i in 0..rows.len() {
        assert_eq!(p0[i].label, p1[i].label, "row {i}: label diverged v0/v1");
        assert_eq!(p1[i].label, pl[i].label, "row {i}: label diverged v1/in-process");
        assert_eq!(p0[i].tenant, p1[i].tenant, "row {i}: tenant diverged v0/v1");
        assert_eq!(p1[i].tenant, pl[i].tenant, "row {i}: tenant diverged v1/in-process");
        // v1 frames and the in-process path carry full f64 bits
        assert_eq!(
            p1[i].score.to_bits(),
            pl[i].score.to_bits(),
            "row {i}: score bits diverged v1/in-process"
        );
        // the v0 wire prints 6 decimals; compare at the wire's precision
        assert_eq!(
            format!("{:.6}", p0[i].score),
            format!("{:.6}", p1[i].score),
            "row {i}: score diverged v0/v1"
        );
    }
    drop(v0); // sends QUIT so serve_n's bounded accept loop can join
    drop(v1);
    srv.join();
}

#[test]
fn v1_framed_protocol_covers_the_full_surface() {
    let (coord, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let mut c = Client::connect(addr).expect("connect");
    c.ping().expect("ping");
    let stats = c.stats().expect("stats");
    assert!(stats.contains("requests=") && stats.contains("submissions="), "{stats}");
    let health = c.health().expect("health");
    assert!(health.contains("die0="), "{health}");
    let models = c.models().expect("models");
    assert!(models.contains("slope"), "{models}");
    // register/unregister through the framed path ("brightdata" rides
    // the binary-classification fallback of TenantSpec::from_dataset)
    let (task, score) = c.register("bin2", "brightdata", 9).expect("register");
    assert_eq!(task, "classification/2");
    assert!(score.is_finite(), "train score {score}");
    let p = c.predict(Some("bin2"), &ds.test_x[0]).expect("tenant predict");
    assert!(p.label == 1 || p.label == -1);
    assert_eq!(p.tenant.as_deref(), Some("bin2"));
    c.unregister("bin2").expect("unregister");
    // server-side failures come back as typed errors, not hangups
    let err = c.predict(Some("nosuch"), &ds.test_x[0]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown tenant"), "{err:#}");
    let err = c.predict(None, &[0.0; 2]).unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // the connection survived every error above
    c.ping().expect("ping after errors");
    // drain flows through the same surface
    c.drain(0).expect("drain");
    assert!(c.drain(0).is_err(), "double drain must be refused");
    drop(c);
    srv.join();
}

#[test]
fn golden_v0_line_grammar() {
    let (coord, ds) = start_system();
    // happy-path replies: exactly the historic strings
    assert_eq!(server::handle_line(&coord, "PING"), Some("OK pong".into()));
    assert_eq!(server::handle_line(&coord, "ping"), Some("OK pong".into()));
    assert_eq!(server::handle_line(&coord, "QUIT"), None);
    let feats: Vec<String> = ds.test_x[0].iter().map(|v| v.to_string()).collect();
    let line = server::handle_line(&coord, &format!("CLASSIFY {}", feats.join(","))).unwrap();
    let mut it = line.split_whitespace();
    assert_eq!(it.next(), Some("OK"));
    let label: i32 = it.next().expect("label").parse().expect("numeric label");
    assert!(label == 1 || label == -1);
    let score = it.next().expect("score");
    assert_eq!(
        score.split('.').nth(1).map(str::len),
        Some(6),
        "v0 scores carry exactly 6 decimals: {line}"
    );
    assert_eq!(it.next(), None, "nothing after the score: {line}");
    let stats = server::handle_line(&coord, "STATS").unwrap();
    assert!(stats.starts_with("OK requests="), "{stats}");
    let models = server::handle_line(&coord, "MODELS").unwrap();
    assert!(models.starts_with("OK default task="), "{models}");

    // error replies: exactly the historic strings
    assert_eq!(server::handle_line(&coord, ""), Some("ERR empty command".into()));
    assert_eq!(
        server::handle_line(&coord, "NOSUCH x"),
        Some("ERR unknown command NOSUCH".into())
    );
    assert_eq!(
        server::handle_line(&coord, "DRAIN abc"),
        Some("ERR DRAIN wants a die index, got 'abc'".into())
    );
    assert_eq!(
        server::handle_line(&coord, "DRAIN"),
        Some("ERR DRAIN wants a die index, got ''".into())
    );
    assert_eq!(
        server::handle_line(&coord, "UNREGISTER"),
        Some("ERR UNREGISTER wants a tenant name".into())
    );
    assert_eq!(
        server::handle_line(&coord, "REGISTER onlyname"),
        Some("ERR REGISTER wants: REGISTER <name> <dataset> [seed]".into())
    );
    assert_eq!(
        server::handle_line(&coord, "PREDICT slope"),
        Some("ERR PREDICT wants: PREDICT <tenant> x1,x2,...".into())
    );
    // the bugfix: an empty feature list answers with the usage line,
    // not the raw float-parse error it used to leak
    assert_eq!(
        server::handle_line(&coord, "CLASSIFY"),
        Some("ERR CLASSIFY wants: CLASSIFY x1,x2,...".into())
    );
    assert_eq!(
        server::handle_line(&coord, "PREDICT slope "),
        Some("ERR PREDICT wants: PREDICT <tenant> x1,x2,...".into())
    );
    // genuinely bad features keep the parse diagnostic
    let bad = server::handle_line(&coord, "CLASSIFY 0.1,bogus").unwrap();
    assert!(bad.starts_with("ERR bad features:"), "{bad}");
    // dispatch-level errors still read "ERR <context chain>"
    let wrong_dim = server::handle_line(&coord, "CLASSIFY 1,2").unwrap();
    assert!(wrong_dim.starts_with("ERR expected"), "{wrong_dim}");
}

#[test]
fn sixty_four_multiplexed_connections_share_the_reactor_pool() {
    let (coord, ds) = start_system();
    let rcfg = reactor::ReactorConfig {
        workers: coord.reactor_workers,
        read_timeout: coord.read_timeout,
        max_conns: Some(65),
    };
    let handle = reactor::spawn(Arc::clone(&coord), "127.0.0.1:0", rcfg).expect("reactor");
    let addr = handle.addr;
    let gauges = Arc::clone(&handle.gauges);
    // the server's thread count is fixed before any client dials in,
    // and 65 connections will not grow it
    assert_eq!(handle.thread_count(), coord.reactor_workers + 2);

    // interleaved multi-tenant traffic, answered first over a blocking
    // single-in-flight connection as the bit-exact reference
    let rows: Vec<PredictRow> = ds
        .test_x
        .iter()
        .take(4)
        .enumerate()
        .map(|(i, x)| PredictRow {
            tenant: if i % 2 == 0 { Some("slope".into()) } else { None },
            features: x.clone(),
        })
        .collect();
    let mut reference = Client::connect(addr).expect("reference connect");
    let expected: Vec<Prediction> = rows
        .iter()
        .map(|r| reference.predict(r.tenant.as_deref(), &r.features).expect("reference"))
        .collect();

    // 64 concurrent connections, each with all 4 correlated requests
    // in flight before it reads a single reply. The first barrier holds
    // every connection open at once; the second keeps them open until
    // the slowest has been fully answered, so the peak gauges must see
    // the whole fleet of connections simultaneously.
    let barrier = Barrier::new(64);
    let results: Vec<Vec<Prediction>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for _ in 0..64 {
            let (rows, barrier) = (&rows, &barrier);
            joins.push(scope.spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                barrier.wait();
                let corrs: Vec<u64> = rows
                    .iter()
                    .map(|r| {
                        c.send_pipelined(&Request::Predict {
                            tenant: r.tenant.clone(),
                            features: r.features.clone(),
                        })
                        .expect("send")
                    })
                    .collect();
                // replies arrive in completion order — match by id
                let mut by_corr = HashMap::new();
                for _ in 0..corrs.len() {
                    let (id, resp) = c.recv_pipelined().expect("recv");
                    match resp {
                        Response::Predict(p) => {
                            assert!(by_corr.insert(id, p).is_none(), "duplicate id {id}")
                        }
                        other => panic!("unexpected reply: {other:?}"),
                    }
                }
                barrier.wait();
                corrs
                    .iter()
                    .map(|id| by_corr.remove(id).expect("every id answered exactly once"))
                    .collect::<Vec<_>>()
            }));
        }
        joins.into_iter().map(|j| j.join().expect("client thread")).collect()
    });
    for preds in &results {
        for (i, (p, e)) in preds.iter().zip(&expected).enumerate() {
            // bit-exact against the blocking single-in-flight path
            assert_eq!(p.score.to_bits(), e.score.to_bits(), "row {i}: score diverged");
            assert_eq!(p.label, e.label, "row {i}: label diverged");
            assert_eq!(p.tenant, e.tenant, "row {i}: tenant diverged");
        }
    }
    drop(reference);
    handle.join();
    // the reactor's own gauges agree: the whole fleet of connections
    // was open at once, requests were genuinely in flight together,
    // and no connection fell back to a legacy v0 thread
    assert!(
        gauges.peak_conns.load(Ordering::Relaxed) >= 65,
        "expected 65 simultaneous connections, saw peak {}",
        gauges.peak_conns.load(Ordering::Relaxed)
    );
    assert!(
        gauges.peak_in_flight.load(Ordering::Relaxed) >= 4,
        "expected pipelined requests in flight, saw peak {}",
        gauges.peak_in_flight.load(Ordering::Relaxed)
    );
    assert_eq!(gauges.legacy_conns.load(Ordering::Relaxed), 0);
}

#[test]
fn streamed_batch_replies_reassemble_bit_exactly_and_start_early() {
    let (coord, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 2).expect("serve");
    let rows: Vec<PredictRow> = ds
        .test_x
        .iter()
        .take(10)
        .enumerate()
        .map(|(i, x)| PredictRow {
            tenant: if i % 3 == 0 { Some("slope".into()) } else { None },
            features: x.clone(),
        })
        .collect();
    // the buffered reply is the reference
    let mut blocking = Client::connect(addr).expect("connect");
    let buffered = blocking.predict_batch(&rows).expect("batch");
    // the streamed reply: per-row frames as dies finish, then an
    // end-of-stream frame carrying the row count and total passes
    let mut streaming = Client::connect(addr).expect("connect");
    let t0 = Instant::now();
    let mut first_row_at = None;
    let mut streamed_order = Vec::new();
    let (streamed, passes) = streaming
        .predict_stream(&rows, |i, _| {
            first_row_at.get_or_insert_with(|| t0.elapsed());
            streamed_order.push(i);
        })
        .expect("stream");
    let total = t0.elapsed();
    assert_eq!(streamed_order.len(), rows.len(), "one callback per row");
    assert!(passes >= rows.len() as u64, "every row costs at least one pass");
    assert_eq!(streamed.len(), buffered.len());
    for (i, (s, b)) in streamed.iter().zip(&buffered).enumerate() {
        assert_eq!(s.score.to_bits(), b.score.to_bits(), "row {i}: score diverged");
        assert_eq!(s.label, b.label, "row {i}: label diverged");
        assert_eq!(s.tenant, b.tenant, "row {i}: tenant diverged");
    }
    let first = first_row_at.expect("at least one streamed row");
    assert!(
        first < total,
        "first streamed row ({first:?}) must land before the full batch ({total:?})"
    );
    drop(blocking);
    drop(streaming);
    srv.join();
}

#[test]
fn tenant_updates_stream_in_scope_and_are_refused_outside_it() {
    // a fleet with auth tokens: "root" unrestricted, "viewer" scoped to
    // a tenant that is NOT the one under test
    let ds = synth::brightdata(1).with_test_subsample(20, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        auth_tokens: vec!["root=*".into(), "viewer=aux".into()],
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start"),
    );
    let reg_y: Vec<f64> = ds.train_x.iter().map(|x| 0.5 * x[0] - 0.25 * x[1]).collect();
    coord
        .register_tenant(
            TenantSpec::regression("slope", ds.train_x.clone(), &reg_y, 1e-3, 12).unwrap(),
        )
        .unwrap();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 3).expect("serve");

    let mut admin = Client::connect(addr).expect("connect");
    assert_eq!(admin.hello("root").expect("hello"), vec!["*".to_string()]);
    let x = ds.train_x[0].clone();
    let before = admin.predict(Some("slope"), &x).expect("predict").score;
    let target = before + 4.0;
    // live traffic: labelled OS-ELM rows stream into the registered
    // head over the same connection and measurably move it
    for _ in 0..30 {
        admin.tenant_update("slope", &x, &[target]).expect("update");
    }
    let after = admin.predict(Some("slope"), &x).expect("predict").score;
    assert!(
        (target - after).abs() < (target - before).abs(),
        "updates must move the head toward the target: \
         before {before}, after {after}, target {target}"
    );

    // an out-of-scope connection's update is refused — and the refusal
    // does not disturb the head
    let mut viewer = Client::connect(addr).expect("connect");
    assert_eq!(viewer.hello("viewer").expect("hello"), vec!["aux".to_string()]);
    let err = viewer.tenant_update("slope", &x, &[0.0]).unwrap_err();
    assert!(
        format!("{err:#}").contains("outside this connection's scope"),
        "{err:#}"
    );
    let unmoved = admin.predict(Some("slope"), &x).expect("predict").score;
    assert_eq!(unmoved.to_bits(), after.to_bits(), "a refused update must not touch the head");

    // an unknown token is a typed error, not a hangup
    let mut nobody = Client::connect(addr).expect("connect");
    let err = nobody.hello("wrong").unwrap_err();
    assert!(format!("{err:#}").contains("unknown auth token"), "{err:#}");
    nobody.ping().expect("the connection survives a refused handshake");

    drop(admin);
    drop(viewer);
    drop(nobody);
    srv.join();
}

#[test]
fn in_flight_requests_keep_a_connection_alive_past_the_read_timeout() {
    let ds = synth::brightdata(1).with_test_subsample(5, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        // a batch window far past the read timeout: the lone correlated
        // request waits in the batcher while the socket sits quiet —
        // the regression was counting that wait as "idle"
        max_wait: Duration::from_millis(250),
        read_timeout: Some(Duration::from_millis(80)),
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start"),
    );
    let rcfg = reactor::ReactorConfig {
        workers: 2,
        read_timeout: coord.read_timeout,
        max_conns: Some(1),
    };
    let handle = reactor::spawn(Arc::clone(&coord), "127.0.0.1:0", rcfg).expect("reactor");
    let gauges = Arc::clone(&handle.gauges);
    let mut c = Client::connect(handle.addr).expect("connect");
    let t0 = Instant::now();
    let corr = c
        .send_pipelined(&Request::Predict { tenant: None, features: ds.test_x[0].clone() })
        .expect("send");
    let (id, resp) = c
        .recv_pipelined()
        .expect("an in-flight request must be answered, not reaped");
    assert_eq!(id, corr);
    assert!(matches!(resp, Response::Predict(_)), "{resp:?}");
    assert!(
        t0.elapsed() >= Duration::from_millis(150),
        "the batch window must actually have straddled the read timeout: {:?}",
        t0.elapsed()
    );
    assert_eq!(
        gauges.reaped.load(Ordering::Relaxed),
        0,
        "a connection with an in-flight request must not be reaped"
    );
    // ...and once truly idle, the same connection reaps on schedule
    let t1 = Instant::now();
    assert!(
        c.recv_pipelined().is_err(),
        "an idle connection must be closed by the server"
    );
    assert!(
        t1.elapsed() >= Duration::from_millis(50),
        "hung up before the timeout: {:?}",
        t1.elapsed()
    );
    drop(c);
    handle.join();
    assert_eq!(gauges.reaped.load(Ordering::Relaxed), 1, "the idle connection reaps");
}

#[test]
fn idle_connections_drain_after_the_read_timeout() {
    let ds = synth::brightdata(1).with_test_subsample(5, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        read_timeout: Some(Duration::from_millis(80)),
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start"),
    );
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().expect("clone");
    let mut r = BufReader::new(stream);
    // one good exchange first: the timeout is per-read, not per-connection
    writeln!(w, "PING").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "OK pong");
    // ...then go idle: the server must hang up on its own (the old
    // server blocked in read_line forever, pinning the thread)
    line.clear();
    let t0 = Instant::now();
    let n = r.read_line(&mut line).unwrap_or(0);
    assert_eq!(n, 0, "idle connection must be closed by the server, got {line:?}");
    assert!(
        t0.elapsed() >= Duration::from_millis(50),
        "hung up before the timeout: {:?}",
        t0.elapsed()
    );
    srv.join(); // the reaped connection lets the bounded server finish
}
