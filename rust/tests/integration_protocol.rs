//! Integration: the typed serving protocol (DESIGN.md §15).
//!
//!   * identical tenant traffic through the v0 line protocol, the v1
//!     framed protocol and the in-process `Client` answers
//!     bit-identically (at each wire's own precision);
//!   * a v1 `BatchPredict` of B rows enters the batcher as ONE
//!     submission (observed via `Metrics`), not B;
//!   * golden strings pin the v0 line grammar so the protocol redesign
//!     cannot silently break pre-protocol clients;
//!   * idle connections are reaped by `SystemConfig::read_timeout`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;
use velm::protocol::PredictRow;
use velm::registry::TenantSpec;

/// One-die fleet (deterministic scores across paths) on brightdata,
/// plus a regression tenant so the traffic is multi-tenant.
fn start_system() -> (Arc<Coordinator>, velm::datasets::Dataset) {
    let ds = synth::brightdata(1).with_test_subsample(40, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let coord =
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start");
    let reg_y: Vec<f64> = ds.train_x.iter().map(|x| 0.5 * x[0] - 0.25 * x[1]).collect();
    coord
        .register_tenant(
            TenantSpec::regression("slope", ds.train_x.clone(), &reg_y, 1e-3, 12).unwrap(),
        )
        .unwrap();
    (Arc::new(coord), ds)
}

#[test]
fn v0_v1_and_in_process_answer_bit_identically() {
    let (coord, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 2).expect("serve");
    // identical tenant traffic: default and tenant rows interleaved
    let rows: Vec<PredictRow> = ds
        .test_x
        .iter()
        .take(12)
        .enumerate()
        .map(|(i, x)| PredictRow {
            tenant: if i % 3 == 0 { Some("slope".into()) } else { None },
            features: x.clone(),
        })
        .collect();

    // v0: the ASCII line grammar, one round-trip per row
    let mut v0 = Client::connect_v0(addr).expect("v0 connect");
    assert_eq!(v0.wire_version(), Some(0));
    let p0 = v0.predict_batch(&rows).expect("v0 predict");

    // v1: ONE framed BatchPredict carrying every row
    let subs0 = coord.metrics.submissions.load(Ordering::Relaxed);
    let resp0 = coord.metrics.responses.load(Ordering::Relaxed);
    let mut v1 = Client::connect(addr).expect("v1 connect");
    assert_eq!(v1.wire_version(), Some(1));
    let p1 = v1.predict_batch(&rows).expect("v1 predict");
    assert_eq!(
        coord.metrics.submissions.load(Ordering::Relaxed) - subs0,
        1,
        "a v1 BatchPredict of {} rows must be ONE batcher submission",
        rows.len()
    );
    assert_eq!(
        coord.metrics.responses.load(Ordering::Relaxed) - resp0,
        rows.len() as u64,
        "every batch row must still be answered"
    );

    // in-process: the same typed dispatcher, no sockets
    let mut local = Client::in_process(Arc::clone(&coord));
    assert_eq!(local.wire_version(), None);
    let pl = local.predict_batch(&rows).expect("in-process predict");

    assert_eq!(p0.len(), rows.len());
    assert_eq!(p1.len(), rows.len());
    assert_eq!(pl.len(), rows.len());
    for i in 0..rows.len() {
        assert_eq!(p0[i].label, p1[i].label, "row {i}: label diverged v0/v1");
        assert_eq!(p1[i].label, pl[i].label, "row {i}: label diverged v1/in-process");
        assert_eq!(p0[i].tenant, p1[i].tenant, "row {i}: tenant diverged v0/v1");
        assert_eq!(p1[i].tenant, pl[i].tenant, "row {i}: tenant diverged v1/in-process");
        // v1 frames and the in-process path carry full f64 bits
        assert_eq!(
            p1[i].score.to_bits(),
            pl[i].score.to_bits(),
            "row {i}: score bits diverged v1/in-process"
        );
        // the v0 wire prints 6 decimals; compare at the wire's precision
        assert_eq!(
            format!("{:.6}", p0[i].score),
            format!("{:.6}", p1[i].score),
            "row {i}: score diverged v0/v1"
        );
    }
    drop(v0); // sends QUIT so serve_n's bounded accept loop can join
    drop(v1);
    srv.join();
}

#[test]
fn v1_framed_protocol_covers_the_full_surface() {
    let (coord, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let mut c = Client::connect(addr).expect("connect");
    c.ping().expect("ping");
    let stats = c.stats().expect("stats");
    assert!(stats.contains("requests=") && stats.contains("submissions="), "{stats}");
    let health = c.health().expect("health");
    assert!(health.contains("die0="), "{health}");
    let models = c.models().expect("models");
    assert!(models.contains("slope"), "{models}");
    // register/unregister through the framed path ("brightdata" rides
    // the binary-classification fallback of TenantSpec::from_dataset)
    let (task, score) = c.register("bin2", "brightdata", 9).expect("register");
    assert_eq!(task, "classification/2");
    assert!(score.is_finite(), "train score {score}");
    let p = c.predict(Some("bin2"), &ds.test_x[0]).expect("tenant predict");
    assert!(p.label == 1 || p.label == -1);
    assert_eq!(p.tenant.as_deref(), Some("bin2"));
    c.unregister("bin2").expect("unregister");
    // server-side failures come back as typed errors, not hangups
    let err = c.predict(Some("nosuch"), &ds.test_x[0]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown tenant"), "{err:#}");
    let err = c.predict(None, &[0.0; 2]).unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // the connection survived every error above
    c.ping().expect("ping after errors");
    // drain flows through the same surface
    c.drain(0).expect("drain");
    assert!(c.drain(0).is_err(), "double drain must be refused");
    drop(c);
    srv.join();
}

#[test]
fn golden_v0_line_grammar() {
    let (coord, ds) = start_system();
    // happy-path replies: exactly the historic strings
    assert_eq!(server::handle_line(&coord, "PING"), Some("OK pong".into()));
    assert_eq!(server::handle_line(&coord, "ping"), Some("OK pong".into()));
    assert_eq!(server::handle_line(&coord, "QUIT"), None);
    let feats: Vec<String> = ds.test_x[0].iter().map(|v| v.to_string()).collect();
    let line = server::handle_line(&coord, &format!("CLASSIFY {}", feats.join(","))).unwrap();
    let mut it = line.split_whitespace();
    assert_eq!(it.next(), Some("OK"));
    let label: i32 = it.next().expect("label").parse().expect("numeric label");
    assert!(label == 1 || label == -1);
    let score = it.next().expect("score");
    assert_eq!(
        score.split('.').nth(1).map(str::len),
        Some(6),
        "v0 scores carry exactly 6 decimals: {line}"
    );
    assert_eq!(it.next(), None, "nothing after the score: {line}");
    let stats = server::handle_line(&coord, "STATS").unwrap();
    assert!(stats.starts_with("OK requests="), "{stats}");
    let models = server::handle_line(&coord, "MODELS").unwrap();
    assert!(models.starts_with("OK default task="), "{models}");

    // error replies: exactly the historic strings
    assert_eq!(server::handle_line(&coord, ""), Some("ERR empty command".into()));
    assert_eq!(
        server::handle_line(&coord, "NOSUCH x"),
        Some("ERR unknown command NOSUCH".into())
    );
    assert_eq!(
        server::handle_line(&coord, "DRAIN abc"),
        Some("ERR DRAIN wants a die index, got 'abc'".into())
    );
    assert_eq!(
        server::handle_line(&coord, "DRAIN"),
        Some("ERR DRAIN wants a die index, got ''".into())
    );
    assert_eq!(
        server::handle_line(&coord, "UNREGISTER"),
        Some("ERR UNREGISTER wants a tenant name".into())
    );
    assert_eq!(
        server::handle_line(&coord, "REGISTER onlyname"),
        Some("ERR REGISTER wants: REGISTER <name> <dataset> [seed]".into())
    );
    assert_eq!(
        server::handle_line(&coord, "PREDICT slope"),
        Some("ERR PREDICT wants: PREDICT <tenant> x1,x2,...".into())
    );
    // the bugfix: an empty feature list answers with the usage line,
    // not the raw float-parse error it used to leak
    assert_eq!(
        server::handle_line(&coord, "CLASSIFY"),
        Some("ERR CLASSIFY wants: CLASSIFY x1,x2,...".into())
    );
    assert_eq!(
        server::handle_line(&coord, "PREDICT slope "),
        Some("ERR PREDICT wants: PREDICT <tenant> x1,x2,...".into())
    );
    // genuinely bad features keep the parse diagnostic
    let bad = server::handle_line(&coord, "CLASSIFY 0.1,bogus").unwrap();
    assert!(bad.starts_with("ERR bad features:"), "{bad}");
    // dispatch-level errors still read "ERR <context chain>"
    let wrong_dim = server::handle_line(&coord, "CLASSIFY 1,2").unwrap();
    assert!(wrong_dim.starts_with("ERR expected"), "{wrong_dim}");
}

#[test]
fn idle_connections_drain_after_the_read_timeout() {
    let ds = synth::brightdata(1).with_test_subsample(5, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        read_timeout: Some(Duration::from_millis(80)),
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start"),
    );
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().expect("clone");
    let mut r = BufReader::new(stream);
    // one good exchange first: the timeout is per-read, not per-connection
    writeln!(w, "PING").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "OK pong");
    // ...then go idle: the server must hang up on its own (the old
    // server blocked in read_line forever, pinning the thread)
    line.clear();
    let t0 = Instant::now();
    let n = r.read_line(&mut line).unwrap_or(0);
    assert_eq!(n, 0, "idle connection must be closed by the server, got {line:?}");
    assert!(
        t0.elapsed() >= Duration::from_millis(50),
        "hung up before the timeout: {:?}",
        t0.elapsed()
    );
    srv.join(); // the reaped connection lets the bounded server finish
}
