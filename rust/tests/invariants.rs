//! Exhaustive small-space invariant checks for the two schedulers that
//! are NOT exercised by the concurrency model checker (their state is
//! confined to one thread): the batcher's tenant-fair admission and the
//! governor's per-die move policy. Instead of exploring thread
//! interleavings, these tests enumerate the full *input* space — every
//! tenant assignment of R rows at every window budget, every signal
//! sequence a die can observe — through the same `assignments` helper
//! the model checker uses (DESIGN.md §18).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use velm::coordinator::batcher::collect_batch;
use velm::coordinator::metrics::TenantMetrics;
use velm::coordinator::request::{ClassifyRequest, TenantTag, WorkerMsg};
use velm::governor::{Decision, DiePolicy, GovernorConfig, RejectReason, TickSignals};
use velm::testing::model::assignments;

fn row(id: u64, tenant: Option<&str>) -> WorkerMsg {
    let (tx, _rx) = mpsc::channel();
    WorkerMsg::Classify(ClassifyRequest {
        id,
        features: vec![],
        tenant: tenant.map(|name| TenantTag {
            name: std::sync::Arc::from(name),
            metrics: std::sync::Arc::new(TenantMetrics::default()),
        }),
        submitted: Instant::now(),
        collected: None,
        reply: tx,
    })
}

/// Tenant class `0` is the default head (`None` tag); class `c > 0`
/// is the named tenant `t<c>`.
fn class_name(class: usize) -> Option<String> {
    (class > 0).then(|| format!("t{class}"))
}

/// Drive one full drain of `assign` (row i belongs to tenant class
/// `assign[i]`) through `collect_batch` at the given conversion
/// budget, asserting the fairness invariants window by window.
fn check_admission_case(assign: &[usize], budget: usize) {
    let (tx, rx) = mpsc::channel();
    for (i, &class) in assign.iter().enumerate() {
        tx.send(row(i as u64, class_name(class).as_deref())).unwrap();
    }
    drop(tx);

    // external pending count per class, mirroring carry + channel
    let mut pending: Vec<u64> = Vec::new();
    for &class in assign {
        if class >= pending.len() {
            pending.resize(class + 1, 0);
        }
        pending[class] += 1;
    }

    let mut carry = VecDeque::new();
    let mut seen: Vec<u64> = Vec::new();
    let mut last_per_class: Vec<Option<u64>> = vec![None; pending.len()];
    while let Some(batch) = collect_batch(&rx, &mut carry, budget, Duration::from_millis(1), 1) {
        assert!(
            batch.requests.len() <= budget,
            "window overflow: {} rows admitted at budget {budget} for {assign:?}",
            batch.requests.len()
        );
        // Fairness: when every pending tenant fits one round-robin
        // round, each of them lands at least one row in this window.
        let distinct = pending.iter().filter(|&&n| n > 0).count();
        let mut admitted_per_class = vec![0u64; pending.len()];
        for req in &batch.requests {
            let class = match &req.tenant {
                None => 0,
                Some(tag) => tag.name[1..].parse::<usize>().unwrap(),
            };
            admitted_per_class[class] += 1;
            // exactly-once, in within-tenant arrival order
            assert!(
                last_per_class[class].is_none_or(|prev| req.id > prev),
                "tenant t{class} rows reordered at budget {budget} for {assign:?}"
            );
            last_per_class[class] = Some(req.id);
            seen.push(req.id);
        }
        if distinct <= budget {
            for (class, &n) in pending.iter().enumerate() {
                assert!(
                    n == 0 || admitted_per_class[class] > 0,
                    "tenant class {class} starved out of a window with \
                     {distinct} tenants pending at budget {budget} for {assign:?}"
                );
            }
        }
        for (class, &n) in admitted_per_class.iter().enumerate() {
            assert!(n <= pending[class], "class {class} over-admitted");
            pending[class] -= n;
        }
    }
    assert!(carry.is_empty(), "shutdown left rows in the carry");
    seen.sort_unstable();
    let expect: Vec<u64> = (0..assign.len() as u64).collect();
    assert_eq!(
        seen, expect,
        "row lost or duplicated at budget {budget} for {assign:?}"
    );
}

/// Every tenant assignment of 5 rows over 1-3 tenant classes, at every
/// window budget 1-6: rows are admitted exactly once, in within-tenant
/// order, never above budget, and no pending tenant is starved out of
/// a window that has room for one row from each.
#[test]
fn carry_queue_admits_every_assignment_exactly_once() {
    const ROWS: u32 = if cfg!(miri) { 3 } else { 5 };
    let budgets: &[usize] = if cfg!(miri) { &[1, 3] } else { &[1, 2, 3, 4, 5, 6] };
    let mut cases = 0usize;
    for classes in 1..=3usize {
        for assign in assignments(ROWS, classes) {
            for &budget in budgets {
                check_admission_case(&assign, budget);
                cases += 1;
            }
        }
    }
    let per_budget: usize = (1..=3usize).map(|c| c.pow(ROWS)).sum();
    assert_eq!(cases, per_budget * budgets.len(), "enumeration incomplete");
}

// ---------------------------------------------------------------------------
// Governor DiePolicy: sliding-window move budget over every signal
// sequence.
// ---------------------------------------------------------------------------

/// The four signal classes a die can observe on one tick.
const SIG_CLASSES: usize = 4;

fn signal(class: usize) -> TickSignals {
    match class {
        // idle, accuracy holding: the die wants to step down
        0 => TickSignals { healthy: true, accuracy_ok: true, ..TickSignals::default() },
        // hot: queued traffic, the die wants to escalate to boot
        1 => TickSignals {
            healthy: true,
            accuracy_ok: true,
            requests_delta: 50,
            mean_queue_us: 10_000,
            ..TickSignals::default()
        },
        // unhealthy: lifecycle owns the die, hands off
        2 => TickSignals { healthy: false, ..TickSignals::default() },
        // idle but a tenant is over its accuracy SLO: descent blocked
        _ => TickSignals { healthy: true, accuracy_ok: false, ..TickSignals::default() },
    }
}

/// Replay one signal sequence through `DiePolicy::decide`, mirroring
/// the window bookkeeping externally and asserting the anti-flap
/// contract at every step.
fn check_policy_case(seq: &[usize], cooldown_ticks: u32) {
    const LADDER: usize = 4;
    const BOOT: usize = 3;
    const WINDOW: u32 = 3;
    const MAX_MOVES: u32 = 1;
    let cfg = GovernorConfig {
        cooldown_ticks,
        window_ticks: WINDOW,
        max_moves_per_window: MAX_MOVES,
        ..GovernorConfig::default()
    };
    let mut p = DiePolicy::new(BOOT);
    let mut rung = BOOT;
    // External replica of the window clock: `decide` advances the tick
    // count first and refills the budget when it reaches WINDOW, so the
    // first window spans WINDOW - 1 decisions and every later one WINDOW.
    let mut tick_in_window = 0u32;
    let mut moves_this_window = 0u32;
    let mut healthy_since_move: Option<u32> = None;
    for (step, &class) in seq.iter().enumerate() {
        tick_in_window += 1;
        if tick_in_window >= WINDOW {
            tick_in_window = 0;
            moves_this_window = 0;
        }
        let sig = signal(class);
        let d = p.decide(&cfg, LADDER, BOOT, &sig);
        match d {
            Decision::Raise { from, to } => {
                assert_eq!(from, rung, "step {step} of {seq:?}");
                assert_eq!(to, BOOT, "a raise always escalates to boot");
                assert!(from < to);
                rung = to;
            }
            Decision::Lower { from, to } => {
                assert_eq!(from, rung, "step {step} of {seq:?}");
                assert_eq!(to, from - 1, "descent is one rung at a time");
                rung = to;
            }
            Decision::Hold => {}
            Decision::Rejected(reason) => {
                if class == 2 {
                    assert_eq!(reason, RejectReason::Unhealthy);
                } else {
                    assert_eq!(reason, RejectReason::Hysteresis);
                }
            }
        }
        let moved = matches!(d, Decision::Raise { .. } | Decision::Lower { .. });
        if class == 2 {
            assert_eq!(
                d,
                Decision::Rejected(RejectReason::Unhealthy),
                "unhealthy die touched at step {step} of {seq:?}"
            );
        }
        if class == 3 {
            assert!(
                matches!(d, Decision::Hold),
                "accuracy-blocked idle tick must hold, got {d:?} at step {step} of {seq:?}"
            );
        }
        if moved {
            moves_this_window += 1;
            assert!(
                moves_this_window <= MAX_MOVES,
                "window budget exceeded at step {step} of {seq:?} (cooldown {cooldown_ticks})"
            );
            if let Some(healthy) = healthy_since_move {
                assert!(
                    healthy >= cooldown_ticks,
                    "move after only {healthy} healthy ticks of a \
                     {cooldown_ticks}-tick cooldown at step {step} of {seq:?}"
                );
            }
            healthy_since_move = Some(0);
        } else if sig.healthy {
            if let Some(healthy) = &mut healthy_since_move {
                *healthy += 1;
            }
        }
        assert_eq!(p.rung(), rung, "rung drifted at step {step} of {seq:?}");
        assert!(rung < LADDER, "rung escaped the ladder at step {step} of {seq:?}");
    }
}

/// Every signal sequence a die can observe over six ticks (idle / hot /
/// unhealthy / accuracy-blocked), with and without a cooldown: the
/// per-window move budget holds across the window reset, cooldowns
/// space moves by healthy ticks, unhealthy dies are never moved, and
/// the rung tracks the decision stream exactly.
#[test]
fn die_policy_move_budget_holds_for_every_signal_sequence() {
    const TICKS: u32 = if cfg!(miri) { 4 } else { 6 };
    let mut cases = 0usize;
    for cooldown in [0u32, 1] {
        for seq in assignments(TICKS, SIG_CLASSES) {
            check_policy_case(&seq, cooldown);
            cases += 1;
        }
    }
    assert_eq!(cases, 2 * SIG_CLASSES.pow(TICKS), "enumeration incomplete");
}
