//! Integration: Section V virtual-die serving end to end (DESIGN.md
//! §13). A fleet fabricated at k x N serves a d=3k, L=3N workload:
//! chip-in-the-loop training, per-die heads, TCP serving, fleet-health
//! probe cycles and pass-exact conversion accounting — with the served
//! scores matching an offline rotation-extended chip on the same seed.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use velm::chip::{dac, ChipModel};
use velm::config::{ChipConfig, SystemConfig, Transfer};
use velm::coordinator::{server, Backend, Coordinator};
use velm::elm::secondstage::{codes_sum, SecondStage};
use velm::elm::train::{assemble_h, solve_head};
use velm::extension::{ServeChip, ServeHidden};
use velm::fleet::DieState;
use velm::util::prng::Prng;

const K: usize = 4; // physical input channels
const N: usize = 16; // physical hidden neurons
const D: usize = 3 * K; // served input dimension
const L: usize = 3 * N; // served hidden width
const PASSES: u64 = 9; // ceil(D/K) * ceil(L/N)

fn blobs(seed: u64, n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Prng::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for k in 0..n {
        let y = if k % 2 == 0 { 1.0 } else { -1.0 };
        xs.push(
            (0..D)
                .map(|_| (0.45 * y + rng.normal(0.0, 0.12)).clamp(-1.0, 1.0))
                .collect::<Vec<f64>>(),
        );
        ys.push(y);
    }
    (xs, ys)
}

fn chip_cfg() -> ChipConfig {
    ChipConfig::default()
        .with_dims(K, N)
        .with_b(10)
        .with_mode(Transfer::Quadratic)
}

fn system() -> SystemConfig {
    SystemConfig {
        n_chips: 2,
        virtual_d: Some(D),
        virtual_l: Some(L),
        max_wait: Duration::from_millis(1),
        artifact_dir: "/nonexistent".into(), // chip-sim path
        seed: 808,
        ..Default::default()
    }
}

#[test]
fn virtual_fleet_trains_serves_probes_and_matches_offline_rotation() {
    let (xs, ys) = blobs(51, 240);
    let (xt, yt) = blobs(52, 80);
    let sys = system();
    let coord = Coordinator::start(&sys, &chip_cfg(), &xs, &ys, 1e-2, 10).unwrap();
    assert_eq!(coord.d, D);
    assert_eq!(coord.passes, PASSES as usize);

    // offline twins: same fabrication seeds, same chip-in-the-loop
    // training through the same rotation plan -> identical heads, so
    // the serving path must reproduce their scores exactly
    let mut twins: Vec<(ServeChip, SecondStage)> = (0..sys.n_chips)
        .map(|i| {
            let chip = ChipModel::fabricate(chip_cfg(), sys.seed + i as u64);
            let mut hidden = ServeHidden {
                die: ServeChip::new(chip, D, L).unwrap(),
                normalize: false,
            };
            let h = assemble_h(&mut hidden, &xs);
            let head = solve_head(&h, &ys, 1e-2).unwrap();
            (hidden.die, SecondStage::new(&head.beta, 10, false))
        })
        .collect();

    let mut correct = 0usize;
    for (x, &y) in xt.iter().zip(&yt) {
        let resp = coord.classify(x.clone()).unwrap();
        assert_eq!(resp.backend, Backend::ChipSim);
        assert_eq!(resp.passes, PASSES as usize);
        let (die, second) = &mut twins[resp.worker];
        let codes = dac::features_to_codes(x, &die.chip().cfg);
        let h = die.forward(&codes).unwrap();
        let offline = second.score(&h, codes_sum(&codes));
        assert!(
            (resp.score - offline).abs() < 1e-9,
            "served score {} != offline rotation score {offline} (die {})",
            resp.score,
            resp.worker
        );
        if (resp.label as f64 - y).abs() < 1e-9 {
            correct += 1;
        }
    }
    assert!(correct >= 72, "only {correct}/80 correct on the virtual fleet");

    // the metrics ledger books exactly passes() conversions per request
    let responses = coord.metrics.responses.load(Ordering::Relaxed);
    assert_eq!(responses, 80);
    assert_eq!(
        coord.metrics.conversions.load(Ordering::Relaxed),
        responses * PASSES
    );

    // the fleet-health loop runs through the virtual forward: probe
    // cycles keep the dies healthy and traffic keeps flowing
    for _ in 0..2 {
        coord.fleet_tick();
    }
    assert!(
        coord.health_snapshot().iter().all(|&s| s == DieState::Healthy),
        "{}",
        coord.fleet_status()
    );
    assert!(coord.metrics.probes.load(Ordering::Relaxed) >= 4);
    let resp = coord.classify(xt[0].clone()).unwrap();
    assert!(resp.label == 1 || resp.label == -1);

    // TCP front end: the same virtual fleet behind the line protocol
    let coord = Arc::new(coord);
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).unwrap();
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    writeln!(writer, "HEALTH").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK"), "{line}");
    assert!(line.contains("die0=Healthy"), "{line}");
    let mut tcp_correct = 0usize;
    for (x, &y) in xt.iter().take(40).zip(&yt) {
        let fields: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
        writeln!(writer, "CLASSIFY {}", fields.join(",")).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let label: f64 = line
            .trim()
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or(0.0);
        if (label - y).abs() < 1e-9 {
            tcp_correct += 1;
        }
    }
    writeln!(writer, "QUIT").unwrap();
    srv.join();
    assert!(tcp_correct >= 34, "only {tcp_correct}/40 correct over TCP");

    // pass accounting holds across the TCP traffic too
    let responses = coord.metrics.responses.load(Ordering::Relaxed);
    assert_eq!(
        coord.metrics.conversions.load(Ordering::Relaxed),
        responses * PASSES
    );
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}
