//! Integration: the traffic-adaptive governor (DESIGN.md §17) end to
//! end through an idle -> burst -> idle cycle on a live fleet.
//!
//!   * an idle fleet descends the rung ladder (fewer counter bits,
//!     cheaper conversions) and a traffic burst restores the boot
//!     point — the control loop actually moves the die;
//!   * the energy ledger stays *exact* across the move: every booked
//!     conversion is priced at the operating point that served it, and
//!     the governor's saved-energy ledger equals conversions x the
//!     integer price gap to the boot point — no estimates anywhere;
//!   * the moves land in the flight recorder and the snapshot's
//!     `GovernorStats` (points, move counters, fJ saved) renders to
//!     Prometheus with a per-die operating-point gauge.
//!
//! Ticks are driven by hand (`Coordinator::governor_tick`) with the
//! background thread parked on a huge period, so the transition
//! sequence — and therefore every ledger assertion — is deterministic.

use std::sync::Arc;
use std::time::Duration;

use velm::chip::energy::conversion_price_fj;
use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::datasets::synth;
use velm::governor::GovernorConfig;
use velm::protocol::TraceOutcome;

#[test]
fn idle_burst_idle_moves_the_die_and_keeps_the_energy_ledger_exact() {
    let ds = synth::brightdata(11).with_test_subsample(60, 11);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    // one die so the points vector and the fleet ledger are scalar
    let sys = SystemConfig {
        n_chips: 1,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        governor: GovernorConfig {
            enabled: true,
            // ticks are driven by hand below; park the thread
            tick: Duration::from_secs(3600),
            cooldown_ticks: 0,
            window_ticks: 1000,
            max_moves_per_window: 1000,
            hot_queue_us: 0, // any traffic at all reads as hot
            bits: vec![6],   // ladder: b=6 (low rung) + b=10 (boot)
            ..Default::default()
        },
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 1e-2, 10).expect("start"),
    );
    let mut c = Client::in_process(Arc::clone(&coord));

    // the two rung prices, from the same model the workers price with
    let boot_price = conversion_price_fj(&cfg);
    let mut low_cfg = cfg.clone();
    low_cfg.b = 6;
    let low_price = conversion_price_fj(&low_cfg);
    assert!(low_price < boot_price, "fewer bits must be cheaper");

    // ---- burst at the boot point -------------------------------------
    for x in ds.test_x.iter().take(20) {
        c.predict(None, x).expect("boot-point predict");
    }
    let s1 = c.snapshot().expect("snapshot after boot burst");
    assert_eq!(s1.governor.points, vec![10], "die must boot at b=10");
    assert_eq!(s1.governor.fj_saved, 0, "no savings at the boot point");
    assert_eq!(
        s1.energy_fj,
        s1.conversions * boot_price,
        "boot-point ledger must price every conversion at b=10"
    );

    // ---- go idle: the governor descends to the low rung --------------
    // tick 1 absorbs the burst delta (traffic reads hot, die already at
    // boot); tick 2 sees a quiet interval and steps down one rung
    coord.governor_tick();
    coord.governor_tick();
    let s2 = c.snapshot().expect("snapshot after descent");
    assert_eq!(s2.governor.points, vec![6], "idle die must take the low rung");
    assert!(s2.governor.lowers >= 1, "{:?}", s2.governor);
    let (e2, c2) = (s2.energy_fj, s2.conversions);

    // ---- serve on the low rung: exact deltas -------------------------
    // (the tick blocks on each worker's retune ack, so every row below
    // is already priced at b=6 — no settling wait needed)
    for x in ds.test_x.iter().skip(20).take(20) {
        c.predict(None, x).expect("low-rung predict");
    }
    let s3 = c.snapshot().expect("snapshot after low-rung burst");
    let dconv = s3.conversions - c2;
    assert!(dconv >= 20, "each served row books >= 1 conversion");
    assert_eq!(
        s3.energy_fj - e2,
        dconv * low_price,
        "low-rung conversions must be priced at b=6, exactly"
    );
    assert_eq!(
        s3.governor.fj_saved,
        dconv * (boot_price - low_price),
        "saved fJ must equal conversions x the integer price gap"
    );

    // ---- the burst raises the die back to the boot point -------------
    coord.governor_tick();
    let s4 = c.snapshot().expect("snapshot after restore");
    assert_eq!(s4.governor.points, vec![10], "traffic must restore the boot point");
    assert!(s4.governor.raises >= 1, "{:?}", s4.governor);
    assert!(s4.governor.ticks >= 3, "{:?}", s4.governor);

    // both transitions are on the flight recorder, priced per move
    let traces = c.trace(4096).expect("trace");
    let lowered = traces.iter().find(|t| t.outcome == TraceOutcome::GovernorLowered);
    let raised = traces.iter().find(|t| t.outcome == TraceOutcome::GovernorRaised);
    let lowered = lowered.expect("descent must leave a trace");
    let raised = raised.expect("restore must leave a trace");
    assert_eq!(lowered.passes, 6, "trace carries the new bits");
    assert_eq!(lowered.total_us, low_price, "trace carries the rung price");
    assert_eq!(raised.passes, 10);
    assert_eq!(raised.total_us, boot_price);

    // the governor block reaches Prometheus, gauge included
    let prom = s4.to_prometheus();
    assert!(prom.contains("velm_governor_raises_total"), "{prom}");
    assert!(
        prom.contains(&format!(
            "velm_governor_femtojoules_saved_total {}\n",
            s4.governor.fj_saved
        )),
        "{prom}"
    );
    assert!(prom.contains("velm_governor_point_bits{die=\"0\"} 10\n"), "{prom}");

    // serving still answers correctly after two retunes
    let p = c.predict(None, &ds.test_x[0]).expect("post-cycle predict");
    assert!(p.label == 1 || p.label == -1);
}
