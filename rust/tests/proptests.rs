//! Property-based tests over module invariants, run through the in-house
//! `testing::check` harness (proptest is unavailable offline).

use velm::chip::{counter, dac, mirror, neuron, spi, ChipModel};
use velm::config::{ChipConfig, Transfer};
use velm::extension::RotationPlan;
use velm::protocol::{
    frame, DieOccupancy, PredictRow, Prediction, Request, Response, Segment, StageStats,
    StatsSnapshot, TenantStats, TimelineEvent, TraceEntry, TraceOutcome, SEGMENTS,
};
use velm::testing::{check, close, ensure};
use velm::util::mat::{ridge_solve, Mat};
use velm::util::prng::Prng;

#[test]
fn prop_dac_linear_and_monotone() {
    let cfg = ChipConfig::default();
    check("dac-linear", 200, |rng| {
        let a = rng.usize(1024) as u16;
        let b = rng.usize(1024) as u16;
        let ia = dac::dac_current(a, &cfg);
        let ib = dac::dac_current(b, &cfg);
        ensure((a < b) == (ia < ib) || a == b, "monotonicity")?;
        close(ia + ib, dac::dac_current(a, &cfg) + dac::dac_current(b, &cfg), 1e-24, "determinism")
    });
}

#[test]
fn prop_feature_code_roundtrip_error_bounded() {
    let cfg = ChipConfig::default();
    check("feature-code-roundtrip", 300, |rng| {
        let x = rng.range(-1.0, 1.0);
        let code = dac::feature_to_code(x, &cfg);
        let back = code as f64 / 1023.0 * 2.0 - 1.0;
        close(x, back, 1.01 / 1023.0, "quantisation error > 1 LSB")
    });
}

#[test]
fn prop_counter_never_exceeds_cap_and_is_monotone() {
    check("counter-cap-monotone", 200, |rng| {
        let cap = 1 + rng.usize(1 << 14) as u32;
        let t_neu = rng.range(1e-6, 1e-3);
        let f1 = rng.range(0.0, 1e9);
        let f2 = rng.range(0.0, 1e9);
        let c1 = counter::count_window(f1, t_neu, cap);
        let c2 = counter::count_window(f2, t_neu, cap);
        ensure(c1 <= cap && c2 <= cap, "cap exceeded")?;
        ensure((f1 <= f2) == (c1 <= c2) || c1 == c2, "monotonicity")
    });
}

#[test]
fn prop_neuron_frequency_bounded_by_fmax() {
    let cfg = ChipConfig::default();
    check("f_sp-bounded", 300, |rng| {
        let i = rng.range(-1e-7, 1e-6);
        let f = neuron::f_sp(i, &cfg);
        ensure(f >= 0.0, "negative frequency")?;
        ensure(
            f <= neuron::f_max(&cfg) * (1.0 + 1e-9),
            "above f_max",
        )
    });
}

#[test]
fn prop_settling_time_decreases_with_code() {
    let cfg = ChipConfig {
        active_mirror: false, // boost makes settling non-monotone at the S1 edge
        ..ChipConfig::default()
    };
    check("settling-monotone", 200, |rng| {
        let a = 1 + rng.usize(1023) as u16;
        let b = 1 + rng.usize(1023) as u16;
        let ta = mirror::settling_time(a, &cfg);
        let tb = mirror::settling_time(b, &cfg);
        ensure((a < b) == (ta > tb) || a == b, format!("codes {a},{b}: {ta},{tb}").as_str())
    });
}

#[test]
fn prop_spi_frame_roundtrip() {
    check("spi-frame", 300, |rng| {
        let addr = rng.usize(128) as u8;
        let data = rng.usize(1024) as u16;
        let bits = spi::encode_frame(addr, data, 10);
        let (a2, d2) = spi::decode_frame(&bits, 10);
        ensure(a2 == addr && d2 == data, "frame corrupted")
    });
}

#[test]
fn prop_rotation_plan_covers_all_physical_weights() {
    // at full virtual size (kN x kN), every physical weight must be
    // reachable through the rotation scheme — the Fig. 11 claim
    check("rotation-coverage", 30, |rng| {
        let k = 2 + rng.usize(5);
        let n = 2 + rng.usize(5);
        let plan = RotationPlan::new(k, n, k * n, k * n).map_err(|e| e)?;
        let cfg = ChipConfig::default().with_dims(k, n);
        let chip = ChipModel::fabricate(cfg, rng.next_u64());
        let mut seen = std::collections::HashSet::new();
        for i in 0..plan.d {
            for j in 0..plan.l {
                seen.insert(
                    plan.virtual_weight(&chip.mismatch, i, j, 300.0).to_bits(),
                );
            }
        }
        ensure(
            seen.len() == k * n,
            &format!("covered {} of {} physical weights", seen.len(), k * n),
        )
    });
}

#[test]
fn prop_virtual_chip_deterministic_and_dimension_correct() {
    check("virtual-chip-shape", 20, |rng| {
        let k = 4 + rng.usize(4);
        let n = 4 + rng.usize(4);
        let d = 1 + rng.usize(k * n);
        let l = 1 + rng.usize(k * n);
        let cfg = ChipConfig::default().with_dims(k, n).with_b(10);
        let seed = rng.next_u64();
        let mut a = velm::extension::VirtualChip::new(
            ChipModel::fabricate(cfg.clone(), seed), d, l,
        )
        .map_err(|e| e)?;
        let mut b = velm::extension::VirtualChip::new(
            ChipModel::fabricate(cfg, seed), d, l,
        )
        .map_err(|e| e)?;
        let codes: Vec<u16> = (0..d).map(|_| rng.usize(1024) as u16).collect();
        let ha = a.forward(&codes)?;
        let hb = b.forward(&codes)?;
        ensure(ha.len() == l, "wrong virtual width")?;
        ensure(ha == hb, "nondeterministic virtual forward")
    });
}

#[test]
fn prop_multi_head_solve_matches_independent_solves() {
    // the registry's shared-H solver (one Cholesky, C heads) must be
    // bit-equivalent to solving each head independently on the same H
    check("multi-head-solve", 40, |rng| {
        let n = 20 + rng.usize(30);
        let l = 3 + rng.usize(8);
        let c = 1 + rng.usize(4);
        let h = Mat::from_fn(n, l, |_, _| rng.gaussian());
        let t = Mat::from_fn(n, c, |_, _| rng.gaussian());
        let lam = rng.range(1e-4, 1.0);
        let many = velm::elm::train::solve_heads(&h, &t, lam)?;
        ensure(many.len() == c, "wrong head count")?;
        for (col, head) in many.iter().enumerate() {
            let single = velm::elm::train::solve_head(&h, &t.col(col), lam)?;
            for j in 0..l {
                close(
                    head.beta[j],
                    single.beta[j],
                    1e-9,
                    &format!("head {col} beta {j}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ridge_residual_optimality() {
    // beta from ridge_solve must beat random perturbations of itself on
    // the regularised objective
    check("ridge-optimal", 40, |rng| {
        let n = 20 + rng.usize(30);
        let l = 3 + rng.usize(8);
        let h = Mat::from_fn(n, l, |_, _| rng.gaussian());
        let t = Mat::from_fn(n, 1, |_, _| rng.gaussian());
        let lam = rng.range(1e-4, 1.0);
        let beta = ridge_solve(&h, &t, lam).map_err(|e| e)?;
        let obj = |b: &Mat| {
            let r = h.matmul(b);
            let mut s = 0.0;
            for i in 0..n {
                let d = r.get(i, 0) - t.get(i, 0);
                s += d * d;
            }
            s + lam * b.frob_norm() * b.frob_norm()
        };
        let base = obj(&beta);
        for _ in 0..5 {
            let mut pert = beta.clone();
            let j = rng.usize(l);
            pert.set(j, 0, pert.get(j, 0) + rng.normal(0.0, 0.1));
            ensure(obj(&pert) >= base - 1e-9, "perturbation beat the optimum")?;
        }
        Ok(())
    });
}

#[test]
fn prop_chip_forward_deterministic_without_noise() {
    check("chip-deterministic", 20, |rng| {
        let cfg = ChipConfig::default().with_dims(8, 8);
        let seed = rng.next_u64();
        let codes: Vec<u16> = (0..8).map(|_| rng.usize(1024) as u16).collect();
        let mut a = ChipModel::fabricate(cfg.clone(), seed);
        let mut b = ChipModel::fabricate(cfg, seed);
        ensure(a.forward(&codes) == b.forward(&codes), "nondeterministic forward")
    });
}

#[test]
fn prop_linear_mode_superposition_upper_bound() {
    // in linear mode (pre-saturation), H(x1 + x2) >= H(x1) and the
    // column current is additive: counts can only grow with extra input
    check("linear-superposition", 30, |rng| {
        let cfg = ChipConfig::default()
            .with_dims(8, 8)
            .with_mode(Transfer::Linear)
            .with_b(14);
        let mut chip = ChipModel::fabricate(cfg, rng.next_u64());
        let base: Vec<u16> = (0..8).map(|_| rng.usize(512) as u16).collect();
        let more: Vec<u16> = base.iter().map(|&c| c + rng.usize(511) as u16).collect();
        let h1 = chip.forward(&base);
        let h2 = chip.forward(&more);
        for j in 0..8 {
            ensure(h2[j] >= h1[j], &format!("count shrank at {j}"))?;
        }
        Ok(())
    });
}

// --- v1 frame codec (DESIGN.md §15) ---

/// Random short string over a mixed alphabet (ASCII + a multi-byte
/// UTF-8 char, so string length-prefixing is exercised in bytes).
fn arb_string(rng: &mut Prng) -> String {
    const ALPHABET: [char; 12] =
        ['a', 'b', 'z', 'A', '0', '9', '_', '-', '.', ' ', ':', 'π'];
    (0..1 + rng.usize(8)).map(|_| ALPHABET[rng.usize(ALPHABET.len())]).collect()
}

fn arb_tenant(rng: &mut Prng) -> Option<String> {
    if rng.bool(0.5) {
        Some(arb_string(rng))
    } else {
        None
    }
}

fn arb_features(rng: &mut Prng) -> Vec<f64> {
    (0..rng.usize(6)).map(|_| rng.range(-1.0, 1.0)).collect()
}

fn arb_prediction(rng: &mut Prng) -> Prediction {
    Prediction {
        label: rng.usize(256) as u8 as i8,
        score: rng.range(-100.0, 100.0),
        tenant: arb_tenant(rng),
    }
}

fn arb_stage(rng: &mut Prng) -> StageStats {
    StageStats {
        count: rng.next_u64() % 10_000,
        sum_us: rng.next_u64() % 1_000_000,
        p50_us: rng.next_u64() % 10_000,
        p90_us: rng.next_u64() % 10_000,
        p99_us: rng.next_u64() % 10_000,
    }
}

fn arb_trace_entry(rng: &mut Prng) -> TraceEntry {
    let outcome = TraceOutcome::from_code(rng.usize(5) as u8).unwrap();
    TraceEntry {
        id: rng.next_u64(),
        tenant: arb_tenant(rng),
        die: rng.usize(64) as u32,
        pjrt: rng.bool(0.5),
        passes: 1 + rng.usize(8) as u32,
        queue_us: rng.next_u64() % 1_000_000,
        batch_us: rng.next_u64() % 1_000_000,
        compute_us: rng.next_u64() % 1_000_000,
        total_us: rng.next_u64() % 4_000_000,
        outcome,
    }
}

fn arb_timeline_event(rng: &mut Prng) -> TimelineEvent {
    let start_us = rng.next_u64() % 1_000_000;
    TimelineEvent {
        die: rng.usize(64) as u32,
        seg: Segment::from_code(rng.usize(SEGMENTS) as u8).unwrap(),
        start_us,
        end_us: start_us + rng.next_u64() % 1_000_000,
        req_id: if rng.bool(0.5) { Some(rng.next_u64()) } else { None },
    }
}

fn arb_snapshot(rng: &mut Prng) -> StatsSnapshot {
    StatsSnapshot {
        // the frame codec refuses any other version in-band, so a
        // roundtrip-able snapshot must carry the current stamp
        version: velm::protocol::stats::SNAPSHOT_VERSION,
        uptime_us: rng.next_u64() >> 1,
        requests: rng.next_u64() % 1_000_000,
        submissions: rng.next_u64() % 1_000_000,
        responses: rng.next_u64() % 1_000_000,
        batches: rng.next_u64() % 100_000,
        pjrt_batches: rng.next_u64() % 100_000,
        sim_batches: rng.next_u64() % 100_000,
        batched_requests: rng.next_u64() % 1_000_000,
        conversions: rng.next_u64() % 10_000_000,
        probes: rng.next_u64() % 1_000,
        renorms: rng.next_u64() % 1_000,
        refits: rng.next_u64() % 1_000,
        quarantines: rng.next_u64() % 1_000,
        promotions: rng.next_u64() % 1_000,
        energy_fj: rng.next_u64() >> 1,
        macs: rng.next_u64() >> 1,
        latency: arb_stage(rng),
        queue: arb_stage(rng),
        batch_wait: arb_stage(rng),
        compute: arb_stage(rng),
        governor: velm::protocol::GovernorStats {
            ticks: rng.next_u64() % 1_000_000,
            raises: rng.next_u64() % 1_000,
            lowers: rng.next_u64() % 1_000,
            rejected: rng.next_u64() % 1_000,
            fj_saved: rng.next_u64() >> 1,
            points: (0..rng.usize(5)).map(|_| 1 + rng.usize(30) as u32).collect(),
        },
        tenants: (0..rng.usize(4))
            .map(|_| TenantStats {
                name: arb_string(rng),
                requests: rng.next_u64() % 1_000_000,
                responses: rng.next_u64() % 1_000_000,
                energy_fj: rng.next_u64() >> 1,
                busy_us: rng.next_u64() % 1_000_000,
                train_score: rng.range(0.0, 1.0),
                latency: arb_stage(rng),
            })
            .collect(),
        occupancy: (0..rng.usize(3))
            .map(|die| {
                let mut seg_us = [0u64; SEGMENTS];
                for us in &mut seg_us {
                    *us = rng.next_u64() % 1_000_000;
                }
                DieOccupancy { die: die as u32, seg_us }
            })
            .collect(),
        slo_breaches: rng.next_u64() % 1_000,
    }
}

fn arb_request(rng: &mut Prng) -> Request {
    match rng.usize(16) {
        0 => Request::Ping,
        1 => Request::Stats,
        2 => Request::Health,
        3 => Request::Models,
        4 => Request::Drain { die: rng.usize(64) },
        5 => Request::Predict { tenant: arb_tenant(rng), features: arb_features(rng) },
        6 => Request::BatchPredict {
            rows: (0..rng.usize(5))
                .map(|_| PredictRow { tenant: arb_tenant(rng), features: arb_features(rng) })
                .collect(),
        },
        7 => Request::Register {
            name: arb_string(rng),
            dataset: arb_string(rng),
            seed: rng.next_u64(),
        },
        8 => Request::Unregister { name: arb_string(rng) },
        9 => Request::Trace { last: rng.usize(1024) },
        10 => Request::Governor,
        11 => Request::Timeline { last: rng.usize(4096) },
        12 => Request::Snapshot,
        13 => Request::Hello { token: arb_string(rng) },
        14 => Request::TenantUpdate {
            name: arb_string(rng),
            features: arb_features(rng),
            targets: (0..1 + rng.usize(3)).map(|_| rng.range(-1.0, 1.0)).collect(),
        },
        _ => Request::BatchStream {
            rows: (0..rng.usize(5))
                .map(|_| PredictRow { tenant: arb_tenant(rng), features: arb_features(rng) })
                .collect(),
        },
    }
}

fn arb_response(rng: &mut Prng) -> Response {
    match rng.usize(16) {
        0 => Response::Pong,
        1 => Response::Stats(arb_string(rng)),
        2 => Response::Health(arb_string(rng)),
        3 => Response::Models(arb_string(rng)),
        4 => Response::Draining { die: rng.usize(64) },
        5 => Response::Predict(arb_prediction(rng)),
        6 => Response::Batch((0..rng.usize(5)).map(|_| arb_prediction(rng)).collect()),
        7 => Response::Registered {
            name: arb_string(rng),
            task: arb_string(rng),
            score: rng.range(0.0, 1.0),
        },
        8 => Response::Unregistered { name: arb_string(rng) },
        9 => Response::Trace((0..rng.usize(4)).map(|_| arb_trace_entry(rng)).collect()),
        10 => Response::Snapshot(arb_snapshot(rng)),
        11 => Response::Governor(arb_string(rng)),
        12 => Response::Timeline((0..rng.usize(5)).map(|_| arb_timeline_event(rng)).collect()),
        13 => Response::HelloOk {
            tenants: (0..1 + rng.usize(3)).map(|_| arb_string(rng)).collect(),
        },
        14 => Response::Updated { name: arb_string(rng) },
        _ => Response::Error(arb_string(rng)),
    }
}

#[test]
fn prop_v1_request_frames_roundtrip_exactly() {
    // every request frame type: decode(encode(req)) == req, and a
    // frame with trailing junk is rejected instead of silently trimmed
    check("v1-request-roundtrip", 300, |rng| {
        let req = arb_request(rng);
        let (ty, payload) = frame::encode_request(&req);
        let back = frame::decode_request(ty, &payload)?;
        ensure(back.as_ref() == Some(&req), &format!("corrupted: {req:?} -> {back:?}"))?;
        let mut junk = payload.clone();
        junk.push(rng.usize(256) as u8);
        ensure(
            frame::decode_request(ty, &junk).is_err(),
            "trailing bytes accepted",
        )
    });
}

#[test]
fn prop_v1_response_frames_roundtrip_exactly() {
    check("v1-response-roundtrip", 300, |rng| {
        let resp = arb_response(rng);
        let (ty, payload) = frame::encode_response(&resp);
        let back = frame::decode_response(ty, &payload)?;
        ensure(back == resp, &format!("corrupted: {resp:?} -> {back:?}"))?;
        let mut junk = payload.clone();
        junk.push(rng.usize(256) as u8);
        ensure(
            frame::decode_response(ty, &junk).is_err(),
            "trailing bytes accepted",
        )
    });
}

#[test]
fn prop_v1_truncated_payloads_never_panic() {
    // chopping a valid payload anywhere must yield Err (or, for list
    // frames, a shorter-but-valid prefix is impossible because counts
    // lead) — never a panic or a bogus success
    check("v1-truncation-safe", 200, |rng| {
        let req = arb_request(rng);
        let (ty, payload) = frame::encode_request(&req);
        if payload.is_empty() {
            return Ok(());
        }
        let cut = rng.usize(payload.len());
        ensure(
            frame::decode_request(ty, &payload[..cut]).is_err(),
            &format!("truncation at {cut} of {} accepted for {req:?}", payload.len()),
        )
    });
}

#[test]
fn prop_v1_correlation_envelope_roundtrips_and_rejects_nesting() {
    // correlation-id echo (DESIGN.md §20): the id rides the envelope
    // bit-exactly, truncation and trailing bytes are refused, and an
    // envelope inside an envelope is refused outright
    check("v1-corr-envelope", 300, |rng| {
        let corr = rng.next_u64();
        let req = match arb_request(rng) {
            // HELLO is transport-level and never rides the envelope
            Request::Hello { .. } => Request::Ping,
            other => other,
        };
        let (ty, payload) = frame::encode_correlated_request(corr, &req);
        ensure(ty == frame::T_CORR, "wrong envelope tag")?;
        let (c2, r2) = frame::decode_correlated_request(&payload)?;
        ensure(c2 == corr && r2 == req, &format!("corrupted envelope: {req:?} -> {r2:?}"))?;
        let cut = rng.usize(payload.len());
        ensure(
            frame::decode_correlated_request(&payload[..cut]).is_err(),
            "truncated envelope accepted",
        )?;
        let mut junk = payload.clone();
        junk.push(rng.usize(256) as u8);
        ensure(
            frame::decode_correlated_request(&junk).is_err(),
            "trailing bytes accepted",
        )?;
        let (_, nested) = frame::encode_correlated_request(corr, &Request::Ping);
        let mut twice = corr.to_le_bytes().to_vec();
        twice.push(frame::T_CORR);
        twice.extend_from_slice(&nested);
        ensure(
            frame::decode_correlated_request(&twice).is_err(),
            "nested envelope accepted",
        )
    });
}

#[test]
fn prop_v1_correlated_responses_roundtrip() {
    check("v1-corr-response", 300, |rng| {
        let corr = rng.next_u64();
        let resp = arb_response(rng);
        let (ty, payload) = frame::encode_correlated_response(corr, &resp);
        ensure(ty == frame::R_CORR, "wrong envelope tag")?;
        let (c2, r2) = frame::decode_correlated_response(&payload)?;
        ensure(c2 == corr && r2 == resp, &format!("corrupted: {resp:?} -> {r2:?}"))?;
        let mut junk = payload.clone();
        junk.push(rng.usize(256) as u8);
        ensure(
            frame::decode_correlated_response(&junk).is_err(),
            "trailing bytes accepted",
        )
    });
}

#[test]
fn prop_v1_stream_frames_roundtrip() {
    // streaming-reply frames (DESIGN.md §20): per-row frames carry
    // (corr, row index, prediction) bit-exactly; the end-of-stream
    // frame carries (corr, row count, passes); truncation and trailing
    // bytes are refused on both
    check("v1-stream-frames", 300, |rng| {
        let corr = rng.next_u64();
        let index = rng.usize(1 << 20) as u32;
        let p = arb_prediction(rng);
        let (ty, payload) = frame::encode_stream_row(corr, index, &p);
        ensure(ty == frame::R_STREAM_ROW, "wrong row tag")?;
        let (c2, i2, p2) = frame::decode_stream_row(&payload)?;
        ensure(
            c2 == corr && i2 == index && p2 == p,
            &format!("corrupted stream row: {p:?} -> {p2:?}"),
        )?;
        let cut = rng.usize(payload.len());
        ensure(
            frame::decode_stream_row(&payload[..cut]).is_err(),
            "truncated row accepted",
        )?;
        let (rows, passes) = (rng.usize(1 << 16) as u32, rng.next_u64());
        let (ty, end) = frame::encode_stream_end(corr, rows, passes);
        ensure(ty == frame::R_STREAM_END, "wrong end tag")?;
        let (c3, r3, p3) = frame::decode_stream_end(&end)?;
        ensure(c3 == corr && r3 == rows && p3 == passes, "corrupted stream end")?;
        let mut junk = end.clone();
        junk.push(rng.usize(256) as u8);
        ensure(frame::decode_stream_end(&junk).is_err(), "trailing bytes accepted")
    });
}

#[test]
fn prop_v1_frames_reassemble_from_single_byte_reads() {
    // the reactor's incremental parser: a frame delivered one byte at a
    // time must decode identically to the same frame read in one piece
    check("v1-partial-read-fuzz", 150, |rng| {
        let corr = rng.next_u64();
        let req = match arb_request(rng) {
            Request::Hello { .. } => Request::Ping,
            other => other,
        };
        let (ty, payload) = frame::encode_correlated_request(corr, &req);
        let wire = frame::frame_bytes(ty, &payload).map_err(|e| e.to_string())?;
        let mut buf = Vec::new();
        let mut out = None;
        for (i, b) in wire.iter().enumerate() {
            buf.push(*b);
            match frame::take_frame(&buf).map_err(|e| e.to_string())? {
                None => ensure(i + 1 < wire.len(), "frame complete, parser still hungry")?,
                Some((t2, p2, used)) => {
                    ensure(i + 1 == wire.len(), "parser finished early")?;
                    ensure(used == wire.len(), "wrong consumed count")?;
                    out = Some((t2, p2));
                }
            }
        }
        let (t2, p2) = out.ok_or_else(|| "no frame produced".to_string())?;
        ensure(t2 == ty && p2 == payload, "byte-at-a-time reassembly differs")?;
        let (c2, r2) = frame::decode_correlated_request(&p2)?;
        ensure(c2 == corr && r2 == req, "decoded frame differs from the original")
    });
}

#[test]
fn prop_governor_hysteresis_bounds_moves_per_window() {
    // DESIGN.md §17: whatever the traffic does, one die never moves
    // more than max_moves_per_window times inside a hysteresis window.
    // A sliding window of window_ticks ticks crosses at most one
    // budget-reset boundary, so it can see at most twice the budget.
    use velm::governor::{Actuator, GovernorConfig, Ladder, TickSignals};
    check("governor-hysteresis", 60, |rng| {
        let window = 2 + rng.usize(8) as u32;
        let max_moves = 1 + rng.usize(3) as u32;
        let cfg = GovernorConfig {
            enabled: true,
            cooldown_ticks: rng.usize(3) as u32,
            window_ticks: window,
            max_moves_per_window: max_moves,
            hot_queue_us: 1_000,
            ..GovernorConfig::default()
        };
        let ladder = Ladder::from_bits(&ChipConfig::default(), &[4, 6, 8, 10, 12]);
        let dies = 1 + rng.usize(3);
        let mut actuator = Actuator::new(cfg, ladder, dies);
        let ticks = 4 * window as usize + rng.usize(16);
        let mut moved = vec![vec![0u32; ticks]; dies];
        for t in 0..ticks {
            // adversarial traffic: flip between idle (wants a descent)
            // and hot (wants an escalation) at random every tick
            let signals: Vec<TickSignals> = (0..dies)
                .map(|_| {
                    if rng.bool(0.5) {
                        TickSignals { healthy: true, accuracy_ok: true, ..TickSignals::default() }
                    } else {
                        TickSignals {
                            healthy: true,
                            accuracy_ok: true,
                            requests_delta: 1 + rng.next_u64() % 100,
                            mean_queue_us: 5_000,
                            ..TickSignals::default()
                        }
                    }
                })
                .collect();
            for m in actuator.tick(&signals, |_, _| true) {
                if m.kind != velm::governor::MoveKind::Rejected {
                    moved[m.die][t] += 1;
                }
            }
        }
        let w = window as usize;
        for (die, lane) in moved.iter().enumerate() {
            for start in 0..=ticks.saturating_sub(w) {
                let n: u32 = lane[start..start + w].iter().sum();
                ensure(
                    n <= 2 * max_moves,
                    &format!(
                        "die {die}: {n} moves in the {w}-tick window at {start} \
                         (budget {max_moves}/window)"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dataset_generators_valid_for_any_seed() {
    check("datasets-valid", 8, |rng| {
        let seed = rng.next_u64();
        velm::datasets::synth::diabetes(seed).validate()?;
        velm::datasets::synth::brightdata(seed)
            .with_test_subsample(50, seed)
            .validate()?;
        velm::datasets::synth::sinc(100, 50, 0.2, seed).validate().map_err(|e| e)
    });
}
