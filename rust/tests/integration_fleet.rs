//! Integration: the fleet health subsystem end to end (DESIGN.md §12).
//!
//! Under an injected Fig. 18-style drift schedule the fleet must detect
//! the drift, renormalise or retrain the affected die, never route
//! traffic to a non-Healthy die, and finish within 2 percentage points
//! of its pre-drift accuracy — while an untreated control fleet under
//! the same drift degrades measurably more.

use std::collections::HashSet;
use std::time::Duration;

use velm::config::{ChipConfig, SystemConfig, Transfer};
use velm::coordinator::Coordinator;
use velm::fleet::{DieState, DriftEvent, DriftSchedule, FleetConfig};
use velm::util::prng::Prng;

/// Well-separated two-class blobs with deterministic exactly-balanced
/// labels of configurable period: `label_period = 1` alternates
/// +1,-1,+1,-1 (any prefix of even length is exactly balanced — the
/// probe set pins a prefix, and a dead die answering a constant label
/// must err on half of it); `label_period = 2` gives +1,+1,-1,-1,...,
/// which stays 50/50 on each die under *any* two-worker round-robin
/// parity (so a dead die's errors cannot alias away with the routing).
/// Every die trains to near-zero error, so pre/post accuracy
/// comparisons are not seed-sensitive.
fn blobs(seed: u64, n: usize, d: usize, label_period: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Prng::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for k in 0..n {
        let y = if (k / label_period) % 2 == 0 { 1.0 } else { -1.0 };
        xs.push(
            (0..d)
                .map(|_| (0.45 * y + rng.normal(0.0, 0.12)).clamp(-1.0, 1.0))
                .collect::<Vec<f64>>(),
        );
        ys.push(y);
    }
    (xs, ys)
}

fn fleet_config() -> FleetConfig {
    FleetConfig {
        probe_n: 40,
        probe_period: None, // ticked explicitly
        ewma_alpha: 0.7,
        err_margin: 0.05,
        cm_threshold: 0.04,
        profile_threshold: 0.06,
        max_renorms: 2,
        quarantine_err: 0.35,
        reply_timeout: Duration::from_secs(10),
        max_probe_misses: 3,
    }
}

fn system(n_chips: usize, standby: usize) -> SystemConfig {
    SystemConfig {
        n_chips,
        standby_chips: standby,
        max_wait: Duration::from_millis(1),
        artifact_dir: "/nonexistent".into(), // chip-sim path
        seed: 4242,
        fleet: fleet_config(),
        ..Default::default()
    }
}

fn chip() -> ChipConfig {
    ChipConfig::default()
        .with_dims(6, 64)
        .with_b(10)
        .with_mode(Transfer::Quadratic)
}

fn error_rate(coord: &Coordinator, xs: &[Vec<f64>], ys: &[f64]) -> f64 {
    let mut wrong = 0usize;
    for (x, &y) in xs.iter().zip(ys) {
        let resp = coord.classify(x.clone()).expect("classify");
        if (resp.label as f64 - y).abs() > 1e-9 {
            wrong += 1;
        }
    }
    wrong as f64 / xs.len() as f64
}

#[test]
fn fig18_drift_detected_recovered_and_beats_untreated_control() {
    let (xs, ys) = blobs(11, 240, 6, 1);
    let (xt, yt) = blobs(12, 100, 6, 2);

    // --- treated fleet: 2 active + 1 hot standby, manager ticking ---
    let coord = Coordinator::start(&system(2, 1), &chip(), &xs, &ys, 1e-2, 10).unwrap();
    let pre_err = error_rate(&coord, &xt, &yt);
    assert!(pre_err < 0.1, "pre-drift err {pre_err}");

    // Fig. 18-style thermal ramp on die 0 (ticks 1..=3), then a supply
    // brown-out at tick 5 that kills the die outright (Fig. 17 axis):
    // the ramp is recoverable by renormalisation, the brown-out is not
    // recoverable at all — quarantine + standby promotion territory.
    let schedule = DriftSchedule::temperature_ramp(Some(0), 1, 3, 315.0, 350.0).with(DriftEvent {
        at_tick: 5,
        die: Some(0),
        vdd: Some(0.30),
        temp_k: None,
        age_sigma_vt: None,
    });
    coord.set_drift_schedule(schedule);

    let mut die0_left_rotation = false;
    let mut states_seen: HashSet<String> = HashSet::new();
    for _ in 0..18 {
        coord.fleet_tick();
        let snap = coord.health_snapshot();
        die0_left_rotation |= snap[0] != DieState::Healthy;
        states_seen.insert(snap[0].to_string());
        // routing invariant: between ticks the states are frozen, and
        // every response must come from a die that is Healthy right now
        let healthy: HashSet<usize> = (0..snap.len())
            .filter(|&i| snap[i] == DieState::Healthy)
            .collect();
        assert!(!healthy.is_empty(), "fleet lost all capacity: {snap:?}");
        for k in 0..10 {
            let resp = coord.classify(xt[k % xt.len()].clone()).expect("no downtime");
            assert!(
                healthy.contains(&resp.worker),
                "request served by non-Healthy die {} (healthy: {healthy:?}, snap {snap:?})",
                resp.worker
            );
        }
    }

    // the thermal ramp must have been caught and renormalised, the
    // brown-out must have walked the die to quarantine, and the hot
    // standby must be serving in its place
    let m = &coord.metrics;
    use std::sync::atomic::Ordering::Relaxed;
    assert!(die0_left_rotation, "drift never pulled die 0 from rotation");
    assert!(m.renorms.load(Relaxed) >= 1, "no renormalisation: {}", coord.fleet_status());
    assert_eq!(m.quarantines.load(Relaxed), 1, "{}", coord.fleet_status());
    assert_eq!(m.promotions.load(Relaxed), 1, "{}", coord.fleet_status());
    let snap = coord.health_snapshot();
    assert_eq!(snap[0], DieState::Quarantined, "{snap:?}");
    assert_eq!(snap[2], DieState::Healthy, "standby not promoted: {snap:?}");
    assert!(states_seen.contains("Quarantined"), "{states_seen:?}");

    // end-of-run accuracy back within 2 points of pre-drift
    let post_err = error_rate(&coord, &xt, &yt);
    assert!(
        post_err <= pre_err + 0.02,
        "fleet did not recover: pre {pre_err} post {post_err}"
    );

    // --- control fleet: identical drift end-state, no fleet manager ---
    let control = Coordinator::start(&system(2, 1), &chip(), &xs, &ys, 1e-2, 10).unwrap();
    control.inject_drift(Some(0), Some(0.30), Some(350.0), None);
    std::thread::sleep(Duration::from_millis(50)); // let the worker absorb it
    let control_err = error_rate(&control, &xt, &yt);
    assert!(
        control_err >= post_err + 0.08,
        "untreated control should degrade measurably more: control {control_err}, treated {post_err}"
    );
    control.shutdown();
    coord.shutdown();
}

#[test]
fn aging_profile_drift_walks_the_state_machine_to_a_successful_refit() {
    let (xs, ys) = blobs(21, 240, 6, 1);
    let (xt, yt) = blobs(22, 100, 6, 2);
    let coord = Coordinator::start(&system(1, 0), &chip(), &xs, &ys, 1e-2, 10).unwrap();
    let pre_err = error_rate(&coord, &xt, &yt);
    assert!(pre_err < 0.1, "pre-drift err {pre_err}");

    // mismatch aging + mild heating: the per-column residual survives
    // renormalisation, so the detector must escalate to the refit tier
    coord.set_drift_schedule(DriftSchedule::new().with(DriftEvent {
        at_tick: 1,
        die: Some(0),
        vdd: None,
        temp_k: Some(312.0),
        age_sigma_vt: Some(0.018),
    }));

    let mut walked: Vec<DieState> = Vec::new();
    for _ in 0..12 {
        coord.fleet_tick();
        let s = coord.health_snapshot()[0];
        if walked.last() != Some(&s) {
            walked.push(s);
        }
    }
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(
        coord.health_snapshot()[0],
        DieState::Healthy,
        "die must be re-admitted after refit; walk {walked:?}, log:\n{}",
        coord.fleet_log().join("\n")
    );
    assert!(
        walked.contains(&DieState::Draining) && walked.contains(&DieState::Recalibrating),
        "state machine must pass through drain + recalibrate: {walked:?}"
    );
    assert!(coord.metrics.refits.load(Relaxed) >= 1, "{}", coord.fleet_status());
    assert!(coord.metrics.probes.load(Relaxed) >= 4);

    // the refitted head serves at pre-drift accuracy on the drifted die
    let post_err = error_rate(&coord, &xt, &yt);
    assert!(
        post_err <= pre_err + 0.02,
        "refit did not recover accuracy: pre {pre_err} post {post_err}"
    );
    coord.shutdown();
}
