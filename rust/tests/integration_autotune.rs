//! Integration: the closed autotune loop — explore the Fig. 7 design
//! space on a workload, check the selected operating point lands where
//! the paper says it should (sigma_VT in the 15–25 mV optimum band),
//! and boot the serving coordinator at that point.

use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::Coordinator;
use velm::datasets::synth;
use velm::dse::{self, Explorer, Objective, OperatingPoint, SearchSpace};
use velm::util::prng::Prng;

/// The paper's Fig. 7(a) axes on the sinc regression task: full sigma
/// range, ratio pinned at the known 0.75 optimum, one L, two batches.
fn sinc_space() -> SearchSpace {
    SearchSpace {
        sigma_vt: (0.005, 0.045),
        ratio: (0.75, 0.75),
        sigma_steps: 5,
        ratio_steps: 1,
        b: vec![14],
        l: vec![64],
        batch: vec![1, 16],
    }
}

#[test]
fn tune_knee_lands_in_paper_sigma_band() {
    // Fig. 7(a): "sigma_VT in 15-25 mV is optimal". Energy and timing
    // are sigma-independent, so the knee's sigma is decided purely by
    // validation error — the explorer must rediscover the paper's band.
    let ds = synth::sinc(600, 256, 0.2, 5);
    let objective = Objective::new(&ds, 3, 11);
    let explorer = Explorer {
        space: sinc_space(),
        objective,
        rounds: 2,
        threads: dse::default_threads(),
    };
    let result = explorer.run();
    assert!(!result.front.is_empty(), "empty Pareto front");
    let knee = result.knee.expect("knee point");
    let sigma_mv = knee.point.sigma_vt * 1e3;
    assert!(
        (15.0 - 1e-6..=25.0 + 1e-6).contains(&sigma_mv),
        "knee sigma_VT {sigma_mv:.1} mV outside the paper's 15-25 mV optimum"
    );
    // the front never keeps a point that another front point dominates
    for a in &result.front {
        for b in &result.front {
            let (oa, ob) = (a.objectives(), b.objectives());
            assert!(
                !velm::dse::pareto::dominates(&oa, &ob),
                "front contains dominated point: {:?} dominated by {:?}",
                b.point,
                a.point
            );
        }
    }
    // adaptive refinement shrank the sigma search region
    assert!(result.regions.len() >= 2);
    assert!(
        result.regions[1].sigma_span() < result.regions[0].sigma_span(),
        "refinement did not shrink: {:?}",
        result.regions
    );
    // refinement revisited cached grid points
    assert!(result.cache_hits > 0, "no cache hits across rounds");
}

#[test]
fn tuned_point_boots_coordinator_and_serves() {
    // two separable blobs, then serve at an explorer-shaped point
    let mut rng = Prng::new(42);
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for _ in 0..160 {
        let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
        xs.push((0..6).map(|_| (0.4 * y + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0)).collect());
        ys.push(y);
    }
    let op = OperatingPoint {
        sigma_vt: 0.018,
        ratio: 0.75,
        b: 10,
        l: 32,
        batch: 8,
    };
    let sys = SystemConfig {
        n_chips: 2,
        artifact_dir: "/nonexistent".into(),
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::start_tuned(&sys, &op, &xs, &ys, 1e-2, 10).expect("start_tuned");
    assert_eq!(coord.n_workers(), 2);
    let mut correct = 0;
    for (x, &y) in xs.iter().take(60).zip(&ys) {
        let resp = coord.classify(x.clone()).expect("classify");
        if (resp.label as f64 - y).abs() < 1e-9 {
            correct += 1;
        }
    }
    assert!(correct >= 50, "only {correct}/60 correct at the tuned point");
    coord.shutdown();

    // the chip config the coordinator trained with matches the point
    let cfg = ChipConfig::from_operating_point(&op, 6);
    assert_eq!((cfg.d, cfg.l, cfg.b), (6, 32, 10));
    assert!((cfg.sigma_vt - 0.018).abs() < 1e-15);
}

#[test]
fn repeated_tune_is_cache_cheap() {
    // a second explorer over the same workload+seed re-evaluates nothing
    // new in round 1 of 1 — but within one run, refinement rounds reuse
    // overlapping grid points. Run 3 rounds on a 1-point discrete space:
    // rounds 2 and 3 must be mostly hits.
    let ds = synth::sinc(200, 64, 0.2, 7);
    let mut objective = Objective::new(&ds, 1, 13);
    objective.max_train = 120;
    let space = SearchSpace {
        sigma_vt: (0.015, 0.025),
        ratio: (0.75, 0.75),
        sigma_steps: 3,
        ratio_steps: 1,
        b: vec![10],
        l: vec![32],
        batch: vec![1],
    };
    let explorer = Explorer { space, objective, rounds: 3, threads: 2 };
    let result = explorer.run();
    assert!(result.cache_hits > 0);
    // every distinct point was evaluated exactly once
    assert_eq!(result.cache_misses as usize, result.evals.len());
}
