//! Integration: end-to-end observability (DESIGN.md §16).
//!
//!   * multi-tenant mixed traffic over the v1 framed wire leaves a
//!     flight-recorder trail whose per-stage spans bracket each
//!     request's end-to-end latency;
//!   * every stage histogram (queue / batch-wait / compute) is
//!     populated, one sample per answered row;
//!   * the energy ledger is exact: total fJ equals booked conversions
//!     priced through the die's operating point, and MACs follow the
//!     fabricated array dims;
//!   * the structured `StatsSnapshot` export roundtrips through JSON
//!     with `responses <= requests`, and renders Prometheus text;
//!   * protocol v0 stays display-only for traces and has no snapshot
//!     frame — the SDK guards both with actionable errors.

use std::sync::Arc;
use std::time::Duration;

use velm::chip::energy::conversion_price_fj;
use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;
use velm::protocol::{PredictRow, StatsSnapshot, TraceOutcome};
use velm::registry::TenantSpec;

/// Two-die homogeneous fleet on brightdata plus a regression tenant,
/// so the traffic is multi-tenant and routed across dies.
fn start_system() -> (Arc<Coordinator>, ChipConfig, velm::datasets::Dataset) {
    let ds = synth::brightdata(7).with_test_subsample(40, 7);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips: 2,
        artifact_dir: "/nonexistent".into(),
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let coord =
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start");
    let reg_y: Vec<f64> = ds.train_x.iter().map(|x| 0.5 * x[0] - 0.25 * x[1]).collect();
    coord
        .register_tenant(
            TenantSpec::regression("slope", ds.train_x.clone(), &reg_y, 1e-3, 12).unwrap(),
        )
        .unwrap();
    (Arc::new(coord), cfg, ds)
}

#[test]
fn traces_stages_and_energy_are_consistent_over_v1() {
    let (coord, cfg, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let mut c = Client::connect(addr).expect("v1 connect");

    // multi-tenant mixed batch: default and tenant rows interleaved,
    // one framed submission, plus a few singles through the batcher
    let rows: Vec<PredictRow> = ds
        .test_x
        .iter()
        .take(12)
        .enumerate()
        .map(|(i, x)| PredictRow {
            tenant: if i % 3 == 0 { Some("slope".into()) } else { None },
            features: x.clone(),
        })
        .collect();
    let answers = c.predict_batch(&rows).expect("mixed batch");
    assert_eq!(answers.len(), rows.len());
    for x in ds.test_x.iter().skip(12).take(4) {
        c.predict(None, x).expect("single predict");
    }
    let served = rows.len() as u64 + 4;

    // flight recorder: every served row left a span record whose
    // stage sums bracket the end-to-end latency (micros flooring may
    // undershoot by < 3 us, never overshoot)
    let traces = c.trace(1024).expect("trace over v1");
    assert_eq!(traces.len(), served as usize, "one trace per answered row");
    let mut ids = std::collections::HashSet::new();
    for t in &traces {
        assert_eq!(t.outcome, TraceOutcome::Ok, "{t}");
        assert!(t.die < 2, "{t}");
        assert_eq!(t.passes, 1, "physical dies serve in one pass: {t}");
        let sum = t.queue_us + t.batch_us + t.compute_us;
        assert!(sum <= t.total_us, "stage sum overshoots the span: {t}");
        assert!(t.total_us - sum <= 3, "stage sum undershoots by > 3us: {t}");
        ids.insert(t.id);
    }
    assert_eq!(ids.len(), traces.len(), "request ids must be unique");
    // the ring dumps newest-first and respects the requested depth
    let last3 = c.trace(3).expect("trace depth");
    assert_eq!(last3.len(), 3);
    assert_eq!(last3[0], traces[0], "newest entry first");

    // structured snapshot: stage histograms carry one sample per
    // answered row, and counters are never torn
    let s = c.snapshot().expect("snapshot over v1");
    assert!(s.responses <= s.requests, "torn snapshot: {s:?}");
    assert_eq!(s.responses, served);
    assert_eq!(s.latency.count, served);
    assert_eq!(s.queue.count, served, "queue-wait histogram not populated");
    assert_eq!(s.batch_wait.count, served, "batch-wait histogram not populated");
    assert_eq!(s.compute.count, served, "compute histogram not populated");
    assert!(s.uptime_us > 0);
    assert!(s.requests_per_s() > 0.0);

    // energy ledger: exact, not approximate — a homogeneous fleet
    // prices every booked conversion at the same operating point
    let price = conversion_price_fj(&cfg);
    assert!(price > 0, "the default operating point must cost energy");
    assert!(s.conversions >= served, "each served row books >= 1 conversion");
    assert_eq!(s.energy_fj, s.conversions * price, "energy != conversions x price");
    assert_eq!(s.macs, s.conversions * (cfg.d * cfg.l) as u64);
    assert!(s.pj_per_mac() > 0.0);

    // per-tenant slice: the regression tenant saw its 4 batch rows
    let slope = s.tenants.iter().find(|t| t.name == "slope").expect("tenant stats");
    assert_eq!(slope.requests, 4);
    assert_eq!(slope.responses, 4);
    assert_eq!(slope.latency.count, 4);
    assert!(slope.energy_fj > 0, "tenant rows must be priced");
    assert!(slope.energy_fj <= s.energy_fj);
    assert!(slope.busy_us >= 1, "tenant utilization share must be booked");

    // fleet timeline (DESIGN.md §19): both dies stamped (tenant
    // registration alone broadcasts a control interval to every
    // worker), and each die's occupancy fractions tile its profiled
    // wall clock exactly
    assert_eq!(s.occupancy.len(), 2, "one occupancy ledger per die");
    for o in &s.occupancy {
        assert!(o.total_us() > 0, "die {} never stamped", o.die);
        let sum: f64 = o.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "die {}: fractions sum {sum}", o.die);
    }

    // the timeline frame answers over v1 and exports as a Chrome trace
    // Perfetto would load: validated structurally, not by eyeball
    let events = c.timeline(4096).expect("timeline over v1");
    assert!(!events.is_empty(), "served traffic must leave timeline events");
    for w in events.windows(2) {
        assert!(w[0].start_us <= w[1].start_us, "events must arrive oldest-first");
    }
    let trace_json = velm::coordinator::timeline::chrome_trace_json(&events);
    let records = velm::coordinator::timeline::validate_chrome_trace(&trace_json)
        .expect("exported trace must validate");
    assert!(records > events.len(), "metadata + B/E pairs outnumber the events");

    // the JSON export parses back into the identical snapshot, and the
    // Prometheus rendering carries the same counters
    let parsed = StatsSnapshot::from_json(&s.to_json()).expect("snapshot json");
    assert_eq!(parsed, s);
    let prom = s.to_prometheus();
    assert!(prom.contains(&format!("velm_responses_total {served}\n")), "{prom}");
    assert!(prom.contains(&format!("velm_conversions_total {}\n", s.conversions)), "{prom}");
    assert!(prom.contains("velm_stage_latency_us{stage=\"queue\",quantile=\"0.99\"}"), "{prom}");
    assert!(prom.contains("velm_tenant_requests_total{tenant=\"slope\"} 4\n"), "{prom}");

    drop(c);
    srv.join();
}

#[test]
fn v0_stays_display_only_for_traces_and_has_no_snapshot() {
    let (coord, _cfg, ds) = start_system();
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let mut v0 = Client::connect_v0(addr).expect("v0 connect");
    v0.predict(None, &ds.test_x[0]).expect("v0 predict");

    // the SDK refuses typed observability verbs on the line protocol
    // before touching the wire, with guidance instead of a decode error
    let err = v0.trace(8).unwrap_err().to_string();
    assert!(err.contains("display-only"), "{err}");
    let err = v0.snapshot().unwrap_err().to_string();
    assert!(err.contains("v1"), "{err}");
    let err = v0.timeline(8).unwrap_err().to_string();
    assert!(err.contains("v1"), "{err}");

    // the raw v0 TRACE verb answers in ONE line (the line grammar's
    // framing invariant), entries joined by ' | '
    let line = server::handle_line(&coord, "TRACE 2").expect("TRACE reply");
    assert!(line.starts_with("OK trace "), "{line}");
    assert!(!line.contains('\n'), "v0 replies are single-line: {line}");
    assert!(line.contains("outcome=ok"), "{line}");
    assert_eq!(
        server::handle_line(&coord, "TRACE abc"),
        Some("ERR TRACE wants an entry count, got 'abc'".into())
    );

    drop(v0);
    srv.join();
}
