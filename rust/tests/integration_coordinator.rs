//! Integration: the serving stack over real TCP sockets, including
//! accuracy through the full protocol and graceful error handling.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;

fn start_system(n_chips: usize) -> (Arc<Coordinator>, velm::datasets::Dataset) {
    let ds = synth::brightdata(1).with_test_subsample(60, 1);
    let mut cfg = ChipConfig::default().with_b(10);
    cfg.d = ds.d();
    let sys = SystemConfig {
        n_chips,
        artifact_dir: "/nonexistent".into(),
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let coord =
        Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).expect("start");
    (Arc::new(coord), ds)
}

#[test]
fn tcp_protocol_roundtrip() {
    let (coord, ds) = start_system(1);
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    writeln!(writer, "PING").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "OK pong");

    line.clear();
    let feats: Vec<String> = ds.test_x[0].iter().map(|v| v.to_string()).collect();
    writeln!(writer, "CLASSIFY {}", feats.join(",")).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "got {line}");
    let label: i32 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    assert!(label == 1 || label == -1);

    line.clear();
    writeln!(writer, "STATS").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("requests="), "got {line}");

    line.clear();
    writeln!(writer, "CLASSIFY 0.1,bogus").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"), "got {line}");

    line.clear();
    writeln!(writer, "NOSUCH").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR unknown"), "got {line}");

    writeln!(writer, "QUIT").unwrap();
    srv.join();
}

#[test]
fn tcp_accuracy_matches_direct_path() {
    let (coord, ds) = start_system(2);
    // direct path accuracy
    let mut direct_correct = 0usize;
    for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
        let resp = coord.classify(x.clone()).unwrap();
        if (resp.label as f64 - y).abs() < 1e-9 {
            direct_correct += 1;
        }
    }
    // protocol path accuracy must be identical (same dies, same heads)
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 1).expect("serve");
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut tcp_correct = 0usize;
    for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
        let feats: Vec<String> = x.iter().map(|v| v.to_string()).collect();
        writeln!(writer, "CLASSIFY {}", feats.join(",")).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let label: f64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
        if (label - y).abs() < 1e-9 {
            tcp_correct += 1;
        }
    }
    writeln!(writer, "QUIT").unwrap();
    srv.join();
    assert_eq!(direct_correct, tcp_correct);
    assert!(direct_correct as f64 / ds.n_test() as f64 > 0.85);
}

#[test]
fn handle_line_unit_surface() {
    let (coord, _) = start_system(1);
    assert_eq!(server::handle_line(&coord, "PING"), Some("OK pong".into()));
    assert_eq!(server::handle_line(&coord, "QUIT"), None);
    assert!(server::handle_line(&coord, "")
        .unwrap()
        .starts_with("ERR"));
    assert!(server::handle_line(&coord, "CLASSIFY 1,2")
        .unwrap()
        .starts_with("ERR")); // wrong dimension
}

#[test]
fn handle_line_health_reports_per_die_gauges() {
    let (coord, _) = start_system(2);
    let resp = server::handle_line(&coord, "HEALTH").expect("HEALTH answers");
    assert!(resp.starts_with("OK "), "{resp}");
    assert!(resp.contains("die0=Healthy"), "{resp}");
    assert!(resp.contains("die1=Healthy"), "{resp}");
    assert!(resp.contains("renorms=0") && resp.contains("refits=0"), "{resp}");
    // case-insensitive like the other verbs
    assert!(server::handle_line(&coord, "health").unwrap().starts_with("OK "));
}

#[test]
fn handle_line_drain_pulls_die_and_health_reflects_it() {
    let (coord, _) = start_system(2);
    let resp = server::handle_line(&coord, "DRAIN 0").expect("DRAIN answers");
    assert_eq!(resp, "OK draining die 0");
    let health = server::handle_line(&coord, "HEALTH").unwrap();
    assert!(health.contains("die0=Draining"), "{health}");
    assert!(health.contains("die1=Healthy"), "{health}");
    // a draining die cannot be drained twice
    assert!(server::handle_line(&coord, "DRAIN 0").unwrap().starts_with("ERR"));
    // bad operands are protocol errors, not panics
    assert!(server::handle_line(&coord, "DRAIN").unwrap().starts_with("ERR"));
    assert!(server::handle_line(&coord, "DRAIN abc").unwrap().starts_with("ERR"));
    assert!(server::handle_line(&coord, "DRAIN 99").unwrap().starts_with("ERR"));
    // traffic still flows on the remaining healthy die
    let ds = synth::brightdata(1).with_test_subsample(5, 1);
    for x in &ds.test_x {
        let feats: Vec<String> = x.iter().map(|v| v.to_string()).collect();
        let line = server::handle_line(&coord, &format!("CLASSIFY {}", feats.join(",")))
            .unwrap();
        assert!(line.starts_with("OK "), "{line}");
    }
}

#[test]
fn load_spreads_across_dies() {
    let (coord, ds) = start_system(3);
    let mut by_worker = [0usize; 3];
    for i in 0..90 {
        let resp = coord.classify(ds.test_x[i % ds.n_test()].clone()).unwrap();
        by_worker[resp.worker] += 1;
    }
    for (w, &n) in by_worker.iter().enumerate() {
        assert!(n > 5, "worker {w} starved: {by_worker:?}");
    }
}
