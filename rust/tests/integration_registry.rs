//! Integration: the multi-tenant model registry (DESIGN.md §14) — two
//! tenants (10-class digits + brightness regression) served
//! concurrently from ONE die fleet over TCP, per-tenant scores matching
//! their single-tenant baselines exactly, tenant isolation under
//! unregister, and a post-drift refit restoring every tenant's heads.

use std::sync::Arc;

use velm::client::Client;
use velm::config::{ChipConfig, SystemConfig};
use velm::coordinator::{server, Coordinator};
use velm::datasets::digits::digits;
use velm::fleet::DieState;
use velm::registry::TenantSpec;

const D: usize = 64; // 8x8 digit images
const L: usize = 96;

/// Boot a fleet on the binary "digit < 5" task over the digit images —
/// the default tenant every other model shares dies with.
fn boot(n_chips: usize) -> Coordinator {
    let (ds, labels, _) = digits(240, 1, 5);
    let ys: Vec<f64> = labels.iter().map(|&c| if c < 5 { 1.0 } else { -1.0 }).collect();
    let cfg = ChipConfig::default().with_dims(D, L).with_b(10);
    let sys = SystemConfig {
        n_chips,
        artifact_dir: "/nonexistent".into(),
        max_wait: std::time::Duration::from_millis(1),
        seed: 0x7E41,
        ..Default::default()
    };
    Coordinator::start(&sys, &cfg, &ds.train_x, &ys, 0.1, 10).expect("boot fleet")
}

/// A labelled digits evaluation set (same generator, disjoint seed).
fn eval_digits(n: usize) -> (Vec<Vec<f64>>, Vec<usize>) {
    let (ds, _, test_labels) = digits(1, n, 991);
    (ds.test_x, test_labels)
}

#[test]
fn tenant_scores_match_single_tenant_baselines_exactly() {
    // one die (deterministic routing): a fleet serving BOTH tenants
    // must answer each tenant bit-identically to a fleet serving only
    // that tenant — same die seeds, same chip-in-the-loop solve, the
    // other tenant's presence is invisible
    let multi = boot(1);
    multi
        .register_tenant(TenantSpec::from_dataset("digits", "digits", 7, D).unwrap())
        .unwrap();
    multi
        .register_tenant(TenantSpec::from_dataset("bright", "brightness", 7, D).unwrap())
        .unwrap();

    let solo_digits = boot(1);
    solo_digits
        .register_tenant(TenantSpec::from_dataset("digits", "digits", 7, D).unwrap())
        .unwrap();
    let solo_bright = boot(1);
    solo_bright
        .register_tenant(TenantSpec::from_dataset("bright", "brightness", 7, D).unwrap())
        .unwrap();

    let (eval_x, _) = eval_digits(25);
    for x in &eval_x {
        let m = multi.classify_tenant(Some("digits"), x.clone()).unwrap();
        let s = solo_digits.classify_tenant(Some("digits"), x.clone()).unwrap();
        assert_eq!(m.label, s.label, "digits label diverged under multi-tenancy");
        assert!(
            (m.score - s.score).abs() < 1e-9,
            "digits score diverged: {} vs {}",
            m.score,
            s.score
        );
        let mb = multi.classify_tenant(Some("bright"), x.clone()).unwrap();
        let sb = solo_bright.classify_tenant(Some("bright"), x.clone()).unwrap();
        assert_eq!(mb.label, 0);
        assert!(
            (mb.score - sb.score).abs() < 1e-9,
            "bright score diverged: {} vs {}",
            mb.score,
            sb.score
        );
    }

    // tenant isolation: unregistering digits must not perturb bright
    let before: Vec<f64> = eval_x
        .iter()
        .map(|x| multi.classify_tenant(Some("bright"), x.clone()).unwrap().score)
        .collect();
    multi.unregister_tenant("digits").unwrap();
    for (x, &b) in eval_x.iter().zip(&before) {
        let after = multi.classify_tenant(Some("bright"), x.clone()).unwrap().score;
        assert!(
            (after - b).abs() < 1e-12,
            "unregistering digits perturbed bright: {b} -> {after}"
        );
    }
    assert!(multi.classify_tenant(Some("digits"), eval_x[0].clone()).is_err());

    multi.shutdown();
    solo_digits.shutdown();
    solo_bright.shutdown();
}

#[test]
fn two_tenants_serve_concurrently_over_tcp_from_one_fleet() {
    let coord = Arc::new(boot(2));
    let (addr, srv) = server::serve_n(Arc::clone(&coord), 3).expect("serve");

    // control connection (client SDK, v1 frames): REGISTER both tenants
    let mut ctl = Client::connect(addr).expect("connect control");
    let (task, _) = ctl.register("digits", "digits", 7).expect("register digits");
    assert_eq!(task, "classification/10");
    let (task, _) = ctl.register("bright", "brightness", 7).expect("register bright");
    assert_eq!(task, "regression");
    let models = ctl.models().expect("models");
    assert!(models.contains("digits task=classification/10"), "{models}");
    assert!(models.contains("bright task=regression"), "{models}");
    // duplicate registration is a protocol error, not a panic or hangup
    let err = ctl.register("digits", "digits", 7).unwrap_err();
    assert!(format!("{err:#}").contains("already registered"), "{err:#}");

    // two concurrent clients, one per tenant, hammering the same fleet
    // — one over v1 frames (batched), one over the v0 line protocol
    let digits_client = {
        let (xs, labels) = eval_digits(40);
        std::thread::spawn(move || {
            let mut client = Client::connect(addr).expect("connect digits client");
            let rows: Vec<velm::protocol::PredictRow> = xs
                .iter()
                .map(|x| velm::protocol::PredictRow {
                    tenant: Some("digits".into()),
                    features: x.clone(),
                })
                .collect();
            // the whole evaluation is ONE framed round-trip
            let preds = client.predict_batch(&rows).expect("batch predict");
            let mut correct = 0usize;
            for (p, &label) in preds.iter().zip(&labels) {
                let got = p.label as usize;
                assert!(got < 10, "class out of range: {got}");
                if got == label {
                    correct += 1;
                }
            }
            correct
        })
    };
    let bright_client = {
        let (xs, _) = eval_digits(40);
        std::thread::spawn(move || {
            let mut client = Client::connect_v0(addr).expect("connect bright client");
            let mut acc = 0.0f64;
            for x in &xs {
                let target = x.iter().sum::<f64>() / x.len() as f64;
                let p = client.predict(Some("bright"), x).expect("predict");
                assert_eq!(p.label, 0, "regression label must be 0");
                acc += (p.score - target) * (p.score - target);
            }
            (acc / xs.len() as f64).sqrt()
        })
    };
    let digit_correct = digits_client.join().unwrap();
    let bright_rmse = bright_client.join().unwrap();
    assert!(
        digit_correct >= 20,
        "10-class digits through the fleet: only {digit_correct}/40"
    );
    assert!(bright_rmse < 0.2, "brightness rmse {bright_rmse}");

    // per-tenant metrics reached STATS, and both tenants really served
    let report = coord.metrics.report();
    assert!(report.contains("tenant[digits:"), "{report}");
    assert!(report.contains("tenant[bright:"), "{report}");
    let digits_metrics = coord
        .metrics
        .tenant_snapshot()
        .into_iter()
        .find(|(name, _)| name == "digits")
        .expect("digits gauges")
        .1;
    assert_eq!(
        digits_metrics
            .responses
            .load(std::sync::atomic::Ordering::Relaxed),
        40
    );

    drop(ctl); // client Drop sends the quit frame
    srv.join();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => panic!("server threads still hold the coordinator"),
    }
}

#[test]
fn post_drift_refit_restores_every_tenant() {
    let coord = boot(1);
    coord
        .register_tenant(TenantSpec::from_dataset("digits", "digits", 7, D).unwrap())
        .unwrap();
    coord
        .register_tenant(TenantSpec::from_dataset("bright", "brightness", 7, D).unwrap())
        .unwrap();
    let (eval_x, eval_labels) = eval_digits(40);

    let digit_err = |c: &Coordinator| -> f64 {
        let mut wrong = 0usize;
        for (x, &label) in eval_x.iter().zip(&eval_labels) {
            let resp = c.classify_tenant(Some("digits"), x.clone()).unwrap();
            if resp.label as usize != label {
                wrong += 1;
            }
        }
        wrong as f64 / eval_x.len() as f64
    };
    let bright_rmse = |c: &Coordinator| -> f64 {
        let mut acc = 0.0;
        for x in &eval_x {
            let target = x.iter().sum::<f64>() / x.len() as f64;
            let resp = c.classify_tenant(Some("bright"), x.clone()).unwrap();
            acc += (resp.score - target) * (resp.score - target);
        }
        (acc / eval_x.len() as f64).sqrt()
    };

    let pre_err = digit_err(&coord);
    let pre_rmse = bright_rmse(&coord);
    assert!(pre_err < 0.5, "pre-drift digits err {pre_err}");
    assert!(pre_rmse < 0.2, "pre-drift bright rmse {pre_rmse}");

    // age the mismatch profile (Fig. 17/18-style) and walk the die
    // through the drain -> recalibrate cycle; the refit re-solves the
    // default head AND both tenants chip-in-the-loop
    coord.inject_drift(Some(0), None, None, Some(0.015));
    coord.drain_die(0).unwrap();
    coord.fleet_tick(); // Draining -> Recalibrating
    coord.fleet_tick(); // refit -> Healthy
    assert_eq!(
        coord.health_snapshot()[0],
        DieState::Healthy,
        "die not re-admitted: {}\n{}",
        coord.fleet_status(),
        coord.fleet_log().join("\n")
    );
    assert!(coord.metrics.refits.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    let post_err = digit_err(&coord);
    let post_rmse = bright_rmse(&coord);
    assert!(
        post_err <= pre_err + 0.15,
        "digits not restored: pre {pre_err} post {post_err}"
    );
    assert!(
        post_rmse <= pre_rmse * 2.0 + 0.05,
        "bright not restored: pre {pre_rmse} post {post_rmse}"
    );
    coord.shutdown();
}
