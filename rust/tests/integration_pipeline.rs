//! Integration: chip -> ELM -> second stage across modules, and the
//! extension pipeline end to end.

use velm::chip::{dac, ChipModel};
use velm::config::{ChipConfig, Transfer};
use velm::datasets::synth;
use velm::elm::secondstage::codes_sum;
use velm::elm::{self, train::HiddenLayer, ChipHidden};
use velm::extension::VirtualChip;

#[test]
fn brightdata_full_pipeline_beats_chance_by_far() {
    let ds = synth::brightdata(1).with_test_subsample(400, 1);
    let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 7));
    let (model, _) =
        elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
    let err = elm::eval_classification_fixed(&mut hidden, &model, &ds.test_x, &ds.test_y);
    assert!(err < 0.10, "brightdata err {err}");
}

#[test]
fn diabetes_pipeline_lands_near_bayes_floor() {
    let ds = synth::diabetes(2);
    let cfg = ChipConfig::default().with_dims(ds.d(), 128).with_b(10);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 8));
    let (model, _) =
        elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
    let err = elm::eval_classification_fixed(&mut hidden, &model, &ds.test_x, &ds.test_y);
    // flip rate ~19.5%; the chip should sit within ~12 points of it
    assert!(err > 0.10 && err < 0.34, "diabetes err {err}");
}

#[test]
fn quadratic_and_linear_modes_both_train() {
    let ds = synth::australian(3).with_test_subsample(200, 3);
    for mode in [Transfer::Quadratic, Transfer::Linear] {
        let cfg = ChipConfig::default().with_dims(ds.d(), 96).with_b(10).with_mode(mode);
        let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 9));
        let (model, _) =
            elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
        let err = elm::eval_classification(&mut hidden, &model, &ds.test_x, &ds.test_y);
        assert!(err < 0.35, "mode {mode:?} err {err}");
    }
}

#[test]
fn noise_injection_costs_little_accuracy() {
    // the Section IV-A claim behind C = 0.4 pF: thermal noise at the
    // designed SNR must not visibly hurt classification
    let ds = synth::australian(4).with_test_subsample(200, 4);
    let mk = |noise: bool| {
        let cfg = ChipConfig::default()
            .with_dims(ds.d(), 96)
            .with_b(10)
            .with_noise(noise);
        let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 10));
        let (model, _) =
            elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
        elm::eval_classification(&mut hidden, &model, &ds.test_x, &ds.test_y)
    };
    let clean = mk(false);
    let noisy = mk(true);
    assert!(
        noisy - clean < 0.05,
        "noise cost too high: clean {clean} noisy {noisy}"
    );
}

#[test]
fn virtual_chip_trains_on_high_dimensional_data() {
    // miniature leukemia: d = 300 through a 64-channel die
    let ds = synth::classification(
        "mini-leukemia",
        300,
        60,
        40,
        synth::FeatureStyle::SparseInformative { informative: 20 },
        0.08,
        5,
    );
    let cfg = ChipConfig::default().with_dims(64, 64).with_b(10);
    let mut vchip = VirtualChip::new(ChipModel::fabricate(cfg, 11), ds.d(), 64).unwrap();
    assert_eq!(vchip.plan.input_chunks(), 5);
    let (model, h) =
        elm::train_model(&mut vchip, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
    let train_err =
        elm::train::misclassification(&elm::train::predict(&h, &model.head), &ds.train_y);
    assert!(train_err < 0.15, "train err {train_err}");
    let test_err = elm::eval_classification(&mut vchip, &model, &ds.test_x, &ds.test_y);
    assert!(test_err < 0.5, "test err {test_err}");
}

#[test]
fn hidden_extension_improves_small_die() {
    let ds = synth::diabetes(6).with_test_subsample(200, 6);
    let small = ChipConfig::default().with_dims(ds.d(), 12).with_b(10);
    let mut s = ChipHidden::new(ChipModel::fabricate(small.clone(), 22));
    let (m_small, _) =
        elm::train_model(&mut s, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
    let e_small = elm::eval_classification(&mut s, &m_small, &ds.test_x, &ds.test_y);
    let mut v = VirtualChip::new(ChipModel::fabricate(small, 22), ds.d(), 96).unwrap();
    let (m_big, _) =
        elm::train_model(&mut v, &ds.train_x, &ds.train_y, 0.1, 10, false).unwrap();
    let e_big = elm::eval_classification(&mut v, &m_big, &ds.test_x, &ds.test_y);
    assert!(
        e_big <= e_small + 0.02,
        "expansion didn't help: L=12 {e_small} vs virtual L=96 {e_big}"
    );
}

#[test]
fn normalization_reduces_vdd_sensitivity_end_to_end() {
    // Fig 17/Table IV mechanism through the full pipeline
    let ds = synth::sinc(800, 200, 0.2, 7);
    let run = |normalize: bool| {
        let cfg = ChipConfig::default().with_dims(1, 96).with_b(12);
        let chip = ChipModel::fabricate(cfg, 13);
        let mut hidden = if normalize {
            ChipHidden::normalized(chip)
        } else {
            ChipHidden::new(chip)
        };
        let (model, _) =
            elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, 1e-4, 14, normalize)
                .unwrap();
        let mut errs = Vec::new();
        for vdd in [0.8, 1.0, 1.2] {
            hidden.chip.set_vdd(vdd);
            errs.push(elm::eval_regression(&mut hidden, &model, &ds.test_x, &ds.test_y));
        }
        errs
    };
    let raw = run(false);
    let norm = run(true);
    let spread = |e: &[f64]| e.iter().cloned().fold(f64::MIN, f64::max) - e[1];
    assert!(
        spread(&norm) < spread(&raw),
        "normalisation must shrink off-nominal degradation: raw {raw:?} norm {norm:?}"
    );
}

#[test]
fn second_stage_fixed_point_matches_float_scores() {
    let cfg = ChipConfig::default().with_dims(8, 32).with_b(10);
    let mut chip = ChipModel::fabricate(cfg.clone(), 15);
    let beta: Vec<f64> = (0..32).map(|i| ((i * 13) % 7) as f64 / 3.5 - 1.0).collect();
    let second = velm::elm::secondstage::SecondStage::new(&beta, 10, false);
    let x: Vec<f64> = (0..8).map(|i| i as f64 / 4.0 - 1.0).collect();
    let codes = dac::features_to_codes(&x, &cfg);
    let h = chip.forward(&codes);
    let float: f64 = h.iter().zip(&beta).map(|(&hj, &bj)| hj as f64 * bj).sum();
    let fixed = second.score(&h, codes_sum(&codes));
    let bound = second.beta.lsb() * 0.5 * h.iter().map(|&v| v as f64).sum::<f64>();
    assert!(
        (fixed - float).abs() <= bound,
        "fixed {fixed} float {float} bound {bound}"
    );
}

#[test]
fn chip_hidden_layer_trait_dims() {
    let cfg = ChipConfig::default().with_dims(10, 20);
    let mut hidden = ChipHidden::new(ChipModel::fabricate(cfg, 16));
    assert_eq!(hidden.input_dim(), 10);
    assert_eq!(hidden.hidden_dim(), 20);
    assert_eq!(hidden.transform(&[0.5; 10]).len(), 20);
}
