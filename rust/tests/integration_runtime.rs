//! Integration: the AOT JAX/Pallas artifacts executed from Rust via PJRT
//! must agree with the behavioural chip simulator — the two independent
//! implementations of the same quantised math (DESIGN.md §2).
//!
//! These tests skip (with a message) when `make artifacts` hasn't run.

use std::path::Path;

use velm::chip::{dac, ChipModel};
use velm::config::ChipConfig;
use velm::runtime::{artifacts_available, PjrtEngine};
use velm::util::mat::{ridge_solve, Mat};
use velm::util::prng::Prng;

fn engine_or_skip() -> Option<PjrtEngine> {
    let dir = Path::new("artifacts");
    if !artifacts_available(dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    // a default (no-`pjrt`) build exposes the stub engine, whose
    // constructor fails even with artifacts present: skip, don't panic
    match PjrtEngine::new(dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP: PJRT engine unavailable ({e:#})");
            None
        }
    }
}

/// The chip forward and the artifact may differ by 1 count where the
/// pre-floor estimate sits on an integer boundary (f32 vs f64).
fn assert_counts_close(a: &[u32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len());
    let mut big = 0usize;
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let diff = (x as f64 - y as f64).abs();
        if diff > 1.0 {
            big += 1;
            assert!(big < 3, "{what}: count {i} differs by {diff} ({x} vs {y})");
        }
    }
}

#[test]
fn pjrt_hidden_matches_chip_simulator() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = ChipConfig::default(); // must match aot.py DEFAULT
    let mut chip = ChipModel::fabricate(cfg.clone(), 42);
    let mut rng = Prng::new(9);
    for bsz in [1usize, 5, 32] {
        let samples: Vec<Vec<u16>> = (0..bsz)
            .map(|_| (0..cfg.d).map(|_| rng.usize(1024) as u16).collect())
            .collect();
        let flat: Vec<f32> = samples
            .iter()
            .flat_map(|s| s.iter().map(|&c| c as f32))
            .collect();
        let w = chip.weights().to_f32();
        let out = engine
            .hidden(&flat, bsz, cfg.d, cfg.l, &w, false)
            .expect("pjrt hidden");
        for (k, s) in samples.iter().enumerate() {
            let h_sim = chip.forward(s);
            assert_counts_close(&h_sim, &out[k * cfg.l..(k + 1) * cfg.l], "hidden");
        }
    }
}

#[test]
fn pjrt_hidden_norm_matches_rust_normalization() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = ChipConfig::default();
    let mut chip = ChipModel::fabricate(cfg.clone(), 43);
    let mut rng = Prng::new(10);
    let codes: Vec<u16> = (0..cfg.d).map(|_| rng.usize(1024) as u16).collect();
    let flat: Vec<f32> = codes.iter().map(|&c| c as f32).collect();
    let w = chip.weights().to_f32();
    let out = engine
        .hidden(&flat, 1, cfg.d, cfg.l, &w, true)
        .expect("pjrt hidden_norm");
    let h_sim = chip.forward(&codes);
    let h_norm = velm::elm::secondstage::normalize_h(
        &h_sim,
        velm::elm::secondstage::codes_sum(&codes),
    );
    for (j, (&ours, &theirs)) in h_norm.iter().zip(&out).enumerate() {
        let rel = (ours - theirs as f64).abs() / ours.abs().max(1.0);
        assert!(rel < 0.02, "norm {j}: {ours} vs {theirs}");
    }
}

#[test]
fn pjrt_train_matches_rust_ridge() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (n, l) = (200usize, 128usize);
    let mut rng = Prng::new(11);
    let h = Mat::from_fn(n, l, |_, _| rng.range(0.0, 1.0));
    let t: Vec<f64> = (0..n).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let lam = 0.1f64;
    let rust_beta = ridge_solve(&h, &Mat { rows: n, cols: 1, data: t.clone() }, lam).unwrap();
    let h32 = h.to_f32();
    let t32: Vec<f32> = t.iter().map(|&v| v as f32).collect();
    let xla_beta = engine
        .train_beta(&h32, n, l, &t32, lam as f32)
        .expect("pjrt train");
    assert_eq!(xla_beta.len(), l);
    for j in 0..l {
        let a = rust_beta.get(j, 0);
        let b = xla_beta[j] as f64;
        assert!(
            (a - b).abs() < 1e-2 * a.abs().max(0.1),
            "beta {j}: rust {a} xla {b}"
        );
    }
}

#[test]
fn pjrt_predict_matches_matvec() {
    let Some(mut engine) = engine_or_skip() else { return };
    let (n, l) = (40usize, 128usize);
    let mut rng = Prng::new(12);
    let h: Vec<f32> = (0..n * l).map(|_| rng.range(0.0, 100.0) as f32).collect();
    let beta: Vec<f32> = (0..l).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let scores = engine.predict(&h, n, l, &beta).expect("pjrt predict");
    assert_eq!(scores.len(), n);
    for i in 0..n {
        let expect: f32 = (0..l).map(|j| h[i * l + j] * beta[j]).sum();
        assert!(
            (scores[i] - expect).abs() < 1e-2 * expect.abs().max(1.0),
            "score {i}: {} vs {expect}",
            scores[i]
        );
    }
}

#[test]
fn artifact_errors_are_reported_not_panicked() {
    let Some(mut engine) = engine_or_skip() else { return };
    assert!(engine.execute_f32("no_such_artifact", &[]).is_err());
    // wrong shape is an error, not UB
    let err = engine.execute_f32("predict_b1_l128", &[&[0.0f32; 3], &[0.0f32; 128]]);
    assert!(err.is_err());
}

#[test]
fn end_to_end_train_and_serve_through_pjrt_only() {
    // full loop: hidden on PJRT -> train on PJRT -> predict on PJRT,
    // cross-checked against the all-Rust path on the same die.
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = ChipConfig::default();
    let mut chip = ChipModel::fabricate(cfg.clone(), 77);
    let ds = velm::datasets::synth::brightdata(1).with_test_subsample(100, 1);
    let pad = |x: &Vec<f64>| {
        let mut p = vec![-1.0; cfg.d];
        p[..x.len()].copy_from_slice(x);
        p
    };
    let n = 300.min(ds.n_train());
    let codes_of = |x: &Vec<f64>| dac::features_to_codes(&pad(x), &cfg);
    let w = chip.weights().to_f32();
    // hidden via PJRT
    let flat: Vec<f32> = ds.train_x[..n]
        .iter()
        .flat_map(|x| codes_of(x).iter().map(|&c| c as f32).collect::<Vec<f32>>())
        .collect();
    let mut h = engine.hidden(&flat, n, cfg.d, cfg.l, &w, false).expect("hidden");
    // scale counts to O(1) before the f32 solve (lambda parity with the
    // Rust path; conditioning for f32 Gauss-Jordan)
    let scale = 1.0f32 / cfg.cap() as f32;
    h.iter_mut().for_each(|v| *v *= scale);
    // train via PJRT
    let t: Vec<f32> = ds.train_y[..n].iter().map(|&v| v as f32).collect();
    let beta = engine.train_beta(&h, n, cfg.l, &t, 0.1).expect("train");
    // predict via PJRT on the test slice
    let m = ds.n_test();
    let flat_te: Vec<f32> = ds.test_x
        .iter()
        .flat_map(|x| codes_of(x).iter().map(|&c| c as f32).collect::<Vec<f32>>())
        .collect();
    let mut h_te = engine.hidden(&flat_te, m, cfg.d, cfg.l, &w, false).expect("hidden te");
    h_te.iter_mut().for_each(|v| *v *= scale);
    let scores = engine.predict(&h_te, m, cfg.l, &beta).expect("predict");
    let err = scores
        .iter()
        .zip(&ds.test_y)
        .filter(|(s, &y)| (s.signum() as f64 - y).abs() > 1e-9)
        .count() as f64
        / m as f64;
    assert!(err < 0.15, "pjrt-only pipeline err {err}");
}
