//! Exhaustive-interleaving proofs for the lock-free serving core,
//! driven by the in-repo model checker (`velm::testing::model`,
//! DESIGN.md §18). Only compiled under `--features model`, which swaps
//! the `velm::sync` facade to deterministic modeled atomics — every
//! schedule within the preemption bound is explored, so a passing test
//! here is a proof over that space, not a stress run.
//!
//! The four checked claims from the concurrency model:
//!   1. flight-recorder push/dump never tears an entry and never
//!      blocks the hot path;
//!   2. the stats-snapshot clamp (`responses <= requests`) holds under
//!      concurrent booking — and the load *order* behind it is
//!      load-bearing (the inverted order is refuted below);
//!   3. carry-queue rows are admitted exactly once — admission state
//!      is confined to the worker thread, so the proof obligation is
//!      input-space coverage, discharged exhaustively in
//!      `tests/invariants.rs` over the same `assignments` helper;
//!   4. `energy_fj + fj_saved == boot-priced conversions` at every
//!      observable point (bounded mid-flight, exact at quiescence).

#![cfg(feature = "model")]

use std::sync::Arc;
use std::time::Duration;

use velm::coordinator::metrics::Metrics;
use velm::coordinator::trace::FlightRecorder;
use velm::protocol::stats::{TraceEntry, TraceOutcome};
use velm::sync::{AtomicU64, Ordering};
use velm::testing::model::Model;

/// Every field is a function of `id`, so a torn entry (fields from two
/// different writes) is detectable in one equality sweep.
fn entry(id: u64) -> TraceEntry {
    TraceEntry {
        id,
        tenant: Some(format!("t{id}")),
        die: id as u32,
        pjrt: id % 2 == 0,
        passes: id as u32 + 1,
        queue_us: id * 10,
        batch_us: id * 100,
        compute_us: id * 1000,
        total_us: id * 1110,
        outcome: TraceOutcome::Ok,
    }
}

fn assert_coherent(e: &TraceEntry) {
    assert_eq!(e, &entry(e.id), "torn trace entry: {e:?}");
}

/// Claim 1: two pushers and a concurrent dumper over a 2-slot ring.
/// No schedule tears an entry, blocks a pusher, or deadlocks; at
/// quiescence both claims are counted and every surfaced entry is one
/// of the two written.
#[test]
#[cfg_attr(miri, ignore)] // spawns OS threads per schedule; exhaustive loop is too slow under miri
fn flight_recorder_push_dump_never_tears_or_blocks() {
    let stats = Model::bounded(2).check("flight-recorder", |t| {
        let r = Arc::new(FlightRecorder::new(2));
        for id in [1u64, 2] {
            let r = Arc::clone(&r);
            t.spawn(move || r.push(entry(id)));
        }
        let r_dump = Arc::clone(&r);
        t.spawn(move || {
            for e in r_dump.dump(2) {
                assert_coherent(&e);
                assert!(e.id == 1 || e.id == 2, "phantom entry {e:?}");
            }
        });
        t.after(move || {
            // Both slots were claimed even when a push lost its slot
            // to the dumper's lock (best-effort drop, never a block).
            assert_eq!(r.recorded(), 2);
            let dumped = r.dump(2);
            assert!(dumped.len() <= 2);
            for e in &dumped {
                assert_coherent(e);
            }
        });
    });
    assert!(stats.schedules > 1, "no interleavings explored");
}

/// Claim 2, full stack: a writer booking request/response pairs races
/// a `Metrics::snapshot`. The exported clamp must hold in every
/// schedule, and quiescence must count everything.
#[test]
#[cfg_attr(miri, ignore)]
fn snapshot_clamp_holds_under_concurrent_booking() {
    Model::bounded(1).check("snapshot-clamp", |t| {
        let m = Arc::new(Metrics::new());
        let w = Arc::clone(&m);
        t.spawn(move || {
            for _ in 0..2 {
                w.record_request();
                w.record_response(Duration::from_micros(5));
            }
        });
        let r = Arc::clone(&m);
        t.spawn(move || {
            let s = r.snapshot();
            assert!(
                s.responses <= s.requests,
                "snapshot clamp violated: {} responses > {} requests",
                s.responses,
                s.requests
            );
        });
        t.after(move || {
            let s = m.snapshot();
            assert_eq!(s.requests, 2);
            assert_eq!(s.responses, 2);
        });
    });
}

/// Claim 2, mechanism: the clamp discipline is "load responses BEFORE
/// requests, then clamp". Reading in that order keeps the raw pair
/// sound in every schedule...
#[test]
#[cfg_attr(miri, ignore)]
fn response_before_request_load_order_is_sound() {
    Model::bounded(2).check("clamp-good-order", |t| {
        let pair = Arc::new((AtomicU64::new(0), AtomicU64::new(0))); // (requests, responses)
        let w = Arc::clone(&pair);
        t.spawn(move || {
            for _ in 0..2 {
                w.0.fetch_add(1, Ordering::Relaxed);
                w.1.fetch_add(1, Ordering::Relaxed);
            }
        });
        t.spawn(move || {
            let responses = pair.1.load(Ordering::Relaxed);
            let requests = pair.0.load(Ordering::Relaxed);
            assert!(
                responses <= requests,
                "clamp order failed: {responses} > {requests}"
            );
        });
    });
}

/// ...and the inverted order is a real bug the checker refutes: load
/// requests first and some schedule shows more responses than
/// requests. This doubles as the seeded-bug self-test proving the
/// search actually finds interleaving bugs in this shape of code.
#[test]
#[cfg_attr(miri, ignore)]
fn request_before_response_load_order_is_refuted() {
    let violation = Model::bounded(1)
        .search(|t| {
            let pair = Arc::new((AtomicU64::new(0), AtomicU64::new(0)));
            let w = Arc::clone(&pair);
            t.spawn(move || {
                w.0.fetch_add(1, Ordering::Relaxed);
                w.1.fetch_add(1, Ordering::Relaxed);
            });
            t.spawn(move || {
                let requests = pair.0.load(Ordering::Relaxed); // bug: wrong order
                let responses = pair.1.load(Ordering::Relaxed);
                assert!(
                    responses <= requests,
                    "clamp order failed: {responses} > {requests}"
                );
            });
        })
        .expect_err("inverted load order must be refuted");
    assert!(
        violation.message.contains("clamp order failed"),
        "unexpected violation: {}",
        violation.message
    );
}

/// Claim 4, mechanism: writers book conversions, then energy, then
/// saved; readers load in the reverse order, so every schedule
/// observes `energy + saved <= boot_price * conversions` (each
/// loaded counter's predecessors are already visible).
#[test]
#[cfg_attr(miri, ignore)]
fn ledger_reverse_read_order_is_sound() {
    const PRICE_FJ: u64 = 100;
    const BOOT_FJ: u64 = 150;
    Model::bounded(2).check("ledger-good-order", |t| {
        let led = Arc::new((AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)));
        let w = Arc::clone(&led);
        t.spawn(move || {
            for _ in 0..2 {
                w.0.fetch_add(6, Ordering::Relaxed); // conversions
                w.1.fetch_add(6 * PRICE_FJ, Ordering::Relaxed); // energy
                w.2.fetch_add(6 * (BOOT_FJ - PRICE_FJ), Ordering::Relaxed); // saved
            }
        });
        let r = Arc::clone(&led);
        t.spawn(move || {
            let saved = r.2.load(Ordering::Relaxed);
            let energy = r.1.load(Ordering::Relaxed);
            let conversions = r.0.load(Ordering::Relaxed);
            assert!(
                energy + saved <= BOOT_FJ * conversions,
                "ledger overshot: {energy} + {saved} > {BOOT_FJ} * {conversions}"
            );
        });
        t.after(move || {
            let (c, e, s) = (
                led.0.load(Ordering::Relaxed),
                led.1.load(Ordering::Relaxed),
                led.2.load(Ordering::Relaxed),
            );
            assert_eq!(e + s, BOOT_FJ * c, "ledger must balance at quiescence");
        });
    });
}

/// The seeded-bug twin: loading conversions FIRST lets a schedule see
/// booked energy against unbooked conversions and overshoot the
/// boot-priced bound — the checker must find it.
#[test]
#[cfg_attr(miri, ignore)]
fn ledger_forward_read_order_is_refuted() {
    const PRICE_FJ: u64 = 100;
    const BOOT_FJ: u64 = 150;
    let violation = Model::bounded(1)
        .search(|t| {
            let led = Arc::new((AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)));
            let w = Arc::clone(&led);
            t.spawn(move || {
                w.0.fetch_add(6, Ordering::Relaxed);
                w.1.fetch_add(6 * PRICE_FJ, Ordering::Relaxed);
                w.2.fetch_add(6 * (BOOT_FJ - PRICE_FJ), Ordering::Relaxed);
            });
            t.spawn(move || {
                let conversions = led.0.load(Ordering::Relaxed); // bug: wrong order
                let saved = led.2.load(Ordering::Relaxed);
                let energy = led.1.load(Ordering::Relaxed);
                assert!(
                    energy + saved <= BOOT_FJ * conversions,
                    "ledger overshot: {energy} + {saved} > {BOOT_FJ} * {conversions}"
                );
            });
        })
        .expect_err("forward load order must be refuted");
    assert!(
        violation.message.contains("ledger overshot"),
        "unexpected violation: {}",
        violation.message
    );
}

/// Claim 4, full stack: worker-order bookings race `Metrics::snapshot`;
/// the exported ledger never overshoots the boot price mid-flight and
/// balances exactly at quiescence.
#[test]
#[cfg_attr(miri, ignore)]
fn metrics_ledger_is_boot_priced_at_every_observable_point() {
    const PRICE_FJ: u64 = 100;
    const BOOT_FJ: u64 = 150;
    Model::bounded(1).check("metrics-ledger", |t| {
        let m = Arc::new(Metrics::new());
        let w = Arc::clone(&m);
        t.spawn(move || {
            // one batch booked in worker.rs order
            w.record_conversions(6);
            w.record_energy(6 * PRICE_FJ, 6 * 48);
            w.record_gov_fj_saved(6 * (BOOT_FJ - PRICE_FJ));
        });
        let r = Arc::clone(&m);
        t.spawn(move || {
            let s = r.snapshot();
            assert!(
                s.energy_fj + s.governor.fj_saved <= BOOT_FJ * s.conversions,
                "exported ledger overshot: {} + {} > {BOOT_FJ} * {}",
                s.energy_fj,
                s.governor.fj_saved,
                s.conversions
            );
        });
        t.after(move || {
            let s = m.snapshot();
            assert_eq!(s.conversions, 6);
            assert_eq!(s.energy_fj + s.governor.fj_saved, BOOT_FJ * s.conversions);
        });
    });
}
