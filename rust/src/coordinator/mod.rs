//! L3 serving coordinator: the paper's classifier chip recast as a
//! request pipeline (DESIGN.md §8, §12, §13, §14).
//!
//! ```text
//! client -> Coordinator::submit (tenant tag resolved once)
//!        -> Router (least pass-weighted outstanding work over
//!           HEALTHY dies; per-die pass costs on heterogeneous fleets)
//!        -> per-worker dynamic batcher (conversion budget)
//!        -> hidden layer (PJRT batched artifact | chip sim,
//!           through the Section V rotation plan on virtual dies)
//!           — computed ONCE per row, shared by every tenant
//!        -> the row's tenant head (fixed-point second stage)
//!        -> response + metrics (global + per-tenant)
//!
//! fleet manager -> probe / renormalise / refit control messages
//!               -> per-die lifecycle state read by the router
//! registry      -> register / unregister / OS-ELM update control
//!                  messages on the same ordered channel
//! ```
//!
//! Threads + channels from std only (no tokio in the offline vendor
//! set); one OS thread per die mirrors one physical chip per board.

pub mod batcher;
pub mod hist;
pub mod metrics;
pub mod reactor;
pub mod request;
pub mod router;
pub mod server;
pub mod timeline;
pub mod trace;
pub mod worker;
pub mod workload;

use std::sync::{mpsc, Arc};

use crate::sync::{AtomicBool, AtomicU64, Mutex, Ordering};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::chip::ChipModel;
use crate::config::{ChipConfig, SystemConfig};
use crate::elm::secondstage::SecondStage;
use crate::elm::train::{assemble_h, solve_head};
use crate::extension::{ServeChip, ServeHidden};
use crate::fleet::{
    DieState, DriftSchedule, FleetManager, FleetSetup, FleetState, ProbeSet,
};
use crate::governor::{Actuator, Ladder, MoveKind, TickSignals};
use crate::protocol::stats::{TraceEntry, TraceOutcome};
use crate::protocol::{PredictRow, Request, Response};
use crate::registry::{ModelRegistry, TenantInfo, TenantSpec};

pub use metrics::Metrics;
pub use request::{Backend, ClassifyRequest, ClassifyResponse, TenantTag};
pub use router::Router;

use hist::{percentile_from, BUCKETS};
use request::{ControlMsg, WorkerMsg};

/// Mutable half of the governor loop: the actuator (ladder + per-die
/// policies) plus the snapshot cursors the tick differentiates against.
struct GovernorInner {
    actuator: Actuator,
    /// `Metrics::requests` at the previous tick.
    last_requests: u64,
    /// Queue-wait histogram `(sum_us, count)` at the previous tick.
    last_queue: (u64, u64),
    /// Fleet end-to-end latency buckets at the previous tick — the
    /// cursor the sliding-window p99 SLO check diffs against
    /// (DESIGN.md §19).
    last_latency: [u64; BUCKETS],
    /// Per-tenant latency-bucket cursors, keyed by tenant name.
    last_tenant_latency: std::collections::BTreeMap<String, [u64; BUCKETS]>,
}

/// Everything the governor control loop reads or drives (DESIGN.md
/// §17), shared between the background thread and the coordinator's
/// manual [`Coordinator::governor_tick`]. Built only when
/// `SystemConfig::governor.enabled`.
struct GovernorCtx {
    cfg: crate::governor::GovernorConfig,
    inner: Mutex<GovernorInner>,
    /// Per-tenant accuracy SLO (`TenantSpec::slo_max_err`), maintained
    /// by register/unregister; `None` falls back to `cfg.err_slo`.
    slos: Mutex<std::collections::BTreeMap<String, Option<f64>>>,
    /// Per-tenant latency SLO (`TenantSpec::slo_p99_us`), maintained by
    /// register/unregister; `None` falls back to `cfg.p99_slo_us`.
    p99_slos: Mutex<std::collections::BTreeMap<String, Option<u64>>>,
    metrics: Arc<Metrics>,
    /// Worker traffic channels the retune callback applies moves on.
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    /// Lifecycle gauges: the governor never touches a non-Healthy die.
    health: FleetState,
    /// Per-die queued-request gauges (the router's load accounting).
    outstanding: router::Outstanding,
}

/// One governor control tick: differentiate the metrics snapshot into
/// per-die [`TickSignals`], let the actuator decide and apply moves
/// through `ControlMsg::Retune`, then publish counters + flight-recorder
/// events. Free function so the background thread and the coordinator
/// share one code path.
fn governor_tick_impl(g: &GovernorCtx) {
    let snap = g.metrics.snapshot();
    let mut inner = g.inner.lock().unwrap();
    let requests_delta = snap.requests.saturating_sub(inner.last_requests);
    inner.last_requests = snap.requests;
    let dq_sum = snap.queue.sum_us.saturating_sub(inner.last_queue.0);
    let dq_count = snap.queue.count.saturating_sub(inner.last_queue.1);
    inner.last_queue = (snap.queue.sum_us, snap.queue.count);
    let mean_queue_us = if dq_count == 0 { 0 } else { dq_sum / dq_count };
    // every registered tenant must hold its accuracy SLO before any die
    // may drop to a cheaper, noisier rung
    let accuracy_ok = {
        let slos = g.slos.lock().unwrap();
        snap.tenants.iter().all(|t| {
            let thr = slos.get(&t.name).copied().flatten().unwrap_or(g.cfg.err_slo);
            t.train_score <= thr
        })
    };
    // sliding-window p99 (DESIGN.md §19): diff the log2 latency
    // buckets against the previous tick's copy and run the shared
    // estimator over the delta — the p99 of exactly the rows answered
    // since the last tick, fleet-wide and per tenant. An SLO of 0
    // disables the check.
    let fleet_buckets = g.metrics.latency_buckets();
    let fleet_window: [u64; BUCKETS] =
        std::array::from_fn(|i| fleet_buckets[i].saturating_sub(inner.last_latency[i]));
    inner.last_latency = fleet_buckets;
    let mut slo_breach =
        g.cfg.p99_slo_us > 0 && percentile_from(&fleet_window, 99.0) > g.cfg.p99_slo_us;
    {
        let slos = g.p99_slos.lock().unwrap();
        let mut cursors = std::mem::take(&mut inner.last_tenant_latency);
        cursors.retain(|name, _| slos.contains_key(name));
        for (name, slo) in slos.iter() {
            let Some(handle) = g.metrics.tenant_handle(name) else { continue };
            let now = handle.latency_buckets();
            let prev = cursors.insert(name.clone(), now).unwrap_or([0; BUCKETS]);
            let window: [u64; BUCKETS] =
                std::array::from_fn(|i| now[i].saturating_sub(prev[i]));
            let slo_us = slo.unwrap_or(g.cfg.p99_slo_us);
            if slo_us > 0 && percentile_from(&window, 99.0) > slo_us {
                slo_breach = true;
            }
        }
        inner.last_tenant_latency = cursors;
    }
    if slo_breach {
        g.metrics.mark_slo_breach();
    }
    let health = g.health.snapshot();
    let signals: Vec<TickSignals> = (0..g.senders.len())
        .map(|i| TickSignals {
            healthy: health.get(i).is_some_and(|&s| s == DieState::Healthy),
            requests_delta,
            outstanding: g.outstanding.load(i),
            mean_queue_us,
            accuracy_ok,
            slo_breach,
        })
        .collect();
    let senders = &g.senders;
    let moves = inner.actuator.tick(&signals, |die, b| {
        let (rtx, rrx) = mpsc::channel();
        senders[die]
            .send(WorkerMsg::Control(ControlMsg::Retune { b, reply: rtx }))
            .is_ok()
            && rrx.recv_timeout(std::time::Duration::from_secs(5)).is_ok()
    });
    let tick_no = inner.actuator.ticks;
    let (mut raises, mut lowers, mut rejected) = (0u64, 0u64, 0u64);
    for m in &moves {
        let outcome = match m.kind {
            MoveKind::Raised => {
                raises += 1;
                TraceOutcome::GovernorRaised
            }
            MoveKind::Lowered => {
                lowers += 1;
                TraceOutcome::GovernorLowered
            }
            MoveKind::Rejected => {
                rejected += 1;
                continue; // deferrals are counted, not traced
            }
        };
        // governor events ride the flight recorder alongside request
        // traces: `passes` carries the new counter bits, `total_us` the
        // new conversion price [fJ] (DESIGN.md §17)
        g.metrics.trace.push(TraceEntry {
            id: tick_no,
            tenant: None,
            die: m.die as u32,
            pjrt: false,
            passes: m.b,
            queue_us: 0,
            batch_us: 0,
            compute_us: 0,
            total_us: m.price_fj,
            outcome,
        });
    }
    let points = inner.actuator.points();
    g.metrics.record_gov_tick(raises, lowers, rejected, points);
}

/// A running serving system: router + one thread per fabricated die
/// (actives and hot standbys) + the fleet-health manager + the
/// multi-tenant model registry.
pub struct Coordinator {
    router: Router,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
    pub d: usize,
    /// Worst-case physical conversions a request costs on any die of
    /// the fleet: 1 on an all-physical fleet, the rotation plan's
    /// passes on virtual dies; heterogeneous fleets mix per-die costs
    /// and this reports the maximum (DESIGN.md §13).
    pub passes: usize,
    fleet: Arc<Mutex<FleetManager>>,
    /// Worker channels, kept for registry broadcasts (register /
    /// unregister / OS-ELM updates ride the ordered control channel).
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    /// The tenant directory (DESIGN.md §14). Cold path only: the serve
    /// path resolves heads from worker-owned tables.
    registry: Mutex<ModelRegistry>,
    /// Serialises register/unregister end-to-end (training included) so
    /// two concurrent REGISTERs of one name cannot both pass the
    /// duplicate check and leave dies serving different models under
    /// it. The directory mutex above stays short-held, so the submit
    /// path never blocks behind a registration in progress.
    registration_gate: Mutex<()>,
    /// Background prober (only when `fleet.probe_period` is set).
    auto_probe: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
    /// Traffic-adaptive power/accuracy governor (DESIGN.md §17), built
    /// only when `SystemConfig::governor.enabled`: watches snapshot
    /// deltas and walks each Healthy die along the operating-point
    /// ladder via `ControlMsg::Retune`.
    governor: Option<Arc<GovernorCtx>>,
    /// Background governor loop ticking at `governor.tick` cadence.
    governor_thread: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
    /// Per-connection TCP read timeout applied by the server front end
    /// (`SystemConfig::read_timeout`): idle/dead clients drain instead
    /// of pinning a connection thread each.
    pub read_timeout: Option<std::time::Duration>,
    /// Token -> scope table parsed from `SystemConfig::auth_tokens`
    /// (DESIGN.md §20). Consulted by [`Request::Hello`]; empty means no
    /// tokens are configured and every connection stays unrestricted.
    auth: std::collections::BTreeMap<String, reactor::Scope>,
    /// Reactor worker-pool width (`SystemConfig::reactor_workers`): the
    /// TCP serve path runs exactly `reactor_workers + 2` threads no
    /// matter how many connections are open (DESIGN.md §20).
    pub reactor_workers: usize,
}

impl Coordinator {
    /// Fabricate `sys.n_chips + sys.standby_chips` dies, train each
    /// die's default head on the given training set (per-die mismatch
    /// means per-die weights — exactly the chip-in-the-loop training of
    /// Section VI-C), enrol a fleet-health baseline per die, then start
    /// serving. Standby dies are fully trained but held out of rotation
    /// until a quarantine promotes them.
    ///
    /// When `sys.virtual_d` / `sys.virtual_l` exceed the fabricated
    /// dims, dies are wrapped in the Section V rotation plan
    /// (DESIGN.md §13): training, probing, recalibration and serving
    /// all flow through the virtual forward, and each request costs
    /// that die's [`RotationPlan::passes`] physical conversions —
    /// priced into the router's load accounting and the batcher's
    /// conversion budget. `sys.die_geoms` fabricates a *heterogeneous*
    /// pool (per-die k x N) behind the same router: every die serves
    /// the same projection, each at its own pass cost.
    ///
    /// Additional workloads share the fleet through the model registry:
    /// [`Coordinator::register_tenant`] installs per-tenant heads on
    /// every die without re-fabricating anything (DESIGN.md §14).
    ///
    /// [`RotationPlan::passes`]: crate::extension::RotationPlan::passes
    pub fn start(
        sys: &SystemConfig,
        chip_cfg: &ChipConfig,
        train_x: &[Vec<f64>],
        train_y: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::with_trace_cap(sys.trace_cap));
        let n_total = sys.n_chips + sys.standby_chips;
        anyhow::ensure!(
            sys.die_geoms.is_empty() || sys.die_geoms.len() == n_total,
            "die_geoms has {} entries but the fleet has {n_total} dies \
             (actives + standbys)",
            sys.die_geoms.len()
        );
        // the served projection: virtual dims are *extensions* of each
        // die. Serving below a die's fabricated dims would silently mask
        // neurons (and disable the PJRT fast path) when the right move
        // is fabricating smaller dies.
        let vd = sys.virtual_d.unwrap_or(chip_cfg.d);
        let vl = sys.virtual_l.unwrap_or(chip_cfg.l);
        if let Some(x) = train_x.first() {
            anyhow::ensure!(
                x.len() == vd,
                "training set dimension {} != served dimension {vd}",
                x.len()
            );
        }
        let probe = Arc::new(ProbeSet::from_training(
            train_x,
            train_y,
            sys.fleet.probe_n,
            chip_cfg,
        ));
        let mut senders = Vec::new();
        let mut setups = Vec::new();
        let mut baselines = Vec::new();
        let mut costs = Vec::new();
        for i in 0..n_total {
            let (ki, li) =
                sys.die_geoms.get(i).copied().unwrap_or((chip_cfg.d, chip_cfg.l));
            anyhow::ensure!(
                vd >= ki && vl >= li,
                "die {i} geometry {ki}x{li} exceeds the served projection {vd}x{vl} \
                 (virtual dims must extend every die)"
            );
            let mut cfg_i = chip_cfg.clone();
            cfg_i.d = ki;
            cfg_i.l = li;
            // price one physical conversion on this die at its operating
            // point (DESIGN.md §16) — every conversion the worker books
            // lands in the energy ledger at this integer fJ price
            let energy_fj_per_conversion =
                crate::chip::energy::conversion_price_fj(&cfg_i);
            let seed = sys.seed + i as u64;
            let chip = ChipModel::fabricate(cfg_i, seed);
            let die = ServeChip::new(chip, vd, vl)
                .map_err(|e| anyhow::anyhow!("wrapping die {i} ({ki}x{li}): {e}"))?;
            costs.push(die.passes());
            // chip-in-the-loop training on this die, through the serving
            // plan (virtual dies train on their virtual projection)
            let mut hidden = ServeHidden { die, normalize: sys.normalize };
            let h = assemble_h(&mut hidden, train_x);
            let head = solve_head(&h, train_y, lambda)
                .map_err(|e| anyhow::anyhow!("training die {i}: {e}"))?;
            let second = SecondStage::new(&head.beta, beta_bits, sys.normalize);
            // fleet enrolment: baseline probe on the freshly trained die
            let mut die = hidden.die;
            baselines.push(crate::fleet::probe::run_probe(&mut die, &second, &probe));
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            setups.push((i, die, second, rx, energy_fj_per_conversion));
        }
        let passes = costs.iter().copied().max().unwrap_or(1);
        let state = FleetState::new(n_total, sys.n_chips);
        let router = Router::with_costs(senders.clone(), state.clone(), costs);
        let mut workers = Vec::new();
        for (i, die, second, rx, energy_fj_per_conversion) in setups {
            let setup = worker::WorkerSetup {
                index: i,
                die,
                second,
                tenants: std::collections::BTreeMap::new(),
                artifact_dir: worker::usable_artifact_dir(sys),
                rx,
                stamper: metrics.timeline.stamper(i as u32),
                metrics: Arc::clone(&metrics),
                outstanding: router.outstanding.clone(),
                max_batch: sys.max_batch,
                max_wait: sys.max_wait,
                pjrt_min_batch: sys.pjrt_min_batch,
                pjrt_max_failures: sys.pjrt_max_failures,
                normalize: sys.normalize,
                energy_fj_per_conversion,
                // the boot price doubles as the governor's savings
                // baseline: retunes re-price the die, the delta vs this
                // lands in `gov_fj_saved`
                baseline_fj_per_conversion: energy_fj_per_conversion,
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("velm-worker-{i}"))
                    .spawn(move || worker::run(setup))
                    .context("spawning worker")?,
            );
        }
        let manager = FleetManager::new(FleetSetup {
            senders: senders.clone(),
            state,
            outstanding: router.outstanding.clone(),
            metrics: Arc::clone(&metrics),
            cfg: sys.fleet.clone(),
            probe,
            baselines,
            refit_x: Arc::new(train_x.to_vec()),
            refit_y: Arc::new(train_y.to_vec()),
            lambda,
            beta_bits,
        });
        let fleet = Arc::new(Mutex::new(manager));
        let auto_probe = sys.fleet.probe_period.map(|period| {
            let stop = Arc::new(AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let fleet2 = Arc::clone(&fleet);
            let handle = std::thread::Builder::new()
                .name("velm-fleet-prober".into())
                .spawn(move || {
                    let slice = std::time::Duration::from_millis(5).min(period);
                    let mut since_tick = std::time::Duration::ZERO;
                    // relaxed-ok: pure quit flag polled every slice;
                    // the only consequence of a stale read is one
                    // extra 5 ms nap before exit, and `shutdown` joins
                    // the thread so nothing races the teardown.
                    while !stop2.load(Ordering::Relaxed) {
                        std::thread::sleep(slice);
                        since_tick += slice;
                        if since_tick >= period {
                            fleet2.lock().unwrap().tick();
                            since_tick = std::time::Duration::ZERO;
                        }
                    }
                })
                .expect("spawning fleet prober");
            (stop, handle)
        });
        // the governor ladder: the tuned/default bits rungs priced at
        // this fleet's base config, with the boot point on top.
        // Heterogeneous dies share the ladder — rung prices are quoted
        // at the base geometry; each worker re-prices its own die on
        // retune, so the ledger stays exact per die.
        let governor = if sys.governor.enabled {
            let ladder = Ladder::from_bits(chip_cfg, &sys.governor.bits);
            let actuator = Actuator::new(sys.governor.clone(), ladder, n_total);
            // publish the boot operating points right away: a freshly
            // started fleet reports where its dies sit, not an empty
            // vector, before the first tick fires
            metrics.seed_gov_points(actuator.points());
            Some(Arc::new(GovernorCtx {
                cfg: sys.governor.clone(),
                inner: Mutex::new(GovernorInner {
                    actuator,
                    last_requests: 0,
                    last_queue: (0, 0),
                    last_latency: [0; BUCKETS],
                    last_tenant_latency: std::collections::BTreeMap::new(),
                }),
                slos: Mutex::new(std::collections::BTreeMap::new()),
                p99_slos: Mutex::new(std::collections::BTreeMap::new()),
                metrics: Arc::clone(&metrics),
                senders: senders.clone(),
                health: router.health.clone(),
                outstanding: router.outstanding.clone(),
            }))
        } else {
            None
        };
        let governor_thread = governor.as_ref().map(|g| {
            let stop = Arc::new(AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let g2 = Arc::clone(g);
            let period = g.cfg.tick;
            let handle = std::thread::Builder::new()
                .name("velm-governor".into())
                .spawn(move || {
                    let slice = std::time::Duration::from_millis(5).min(period);
                    let mut since_tick = std::time::Duration::ZERO;
                    // relaxed-ok: pure quit flag polled every slice;
                    // a stale read costs at most one extra 5 ms nap
                    // before exit, and `shutdown` joins the thread.
                    while !stop2.load(Ordering::Relaxed) {
                        std::thread::sleep(slice);
                        since_tick += slice;
                        if since_tick >= period {
                            governor_tick_impl(&g2);
                            since_tick = std::time::Duration::ZERO;
                        }
                    }
                })
                .expect("spawning governor");
            (stop, handle)
        });
        let auth = reactor::parse_auth_tokens(&sys.auth_tokens)?;
        // the ensure above pinned train_x's width to vd, so vd IS the
        // dimension submit() must validate against
        Ok(Coordinator {
            router,
            metrics,
            next_id: AtomicU64::new(0),
            workers,
            d: vd,
            passes,
            fleet,
            senders,
            registry: Mutex::new(ModelRegistry::new()),
            registration_gate: Mutex::new(()),
            auto_probe,
            governor,
            governor_thread,
            read_timeout: sys.read_timeout,
            auth,
            reactor_workers: sys.reactor_workers,
        })
    }

    /// Look up an auth token in the `SystemConfig::auth_tokens` table
    /// (DESIGN.md §20). `None` = unknown token; the caller should
    /// refuse the handshake and leave the connection's scope unchanged.
    pub fn resolve_token(&self, token: &str) -> Option<reactor::Scope> {
        self.auth.get(token).cloned()
    }

    /// The one typed entry point every caller shares (DESIGN.md §15):
    /// the TCP front end (both wire codecs), the in-process
    /// [`crate::client::Client`] and library callers all dispatch
    /// through here, so a request behaves identically no matter how it
    /// arrived. Errors come back as [`Response::Error`] carrying the
    /// full context chain — never as a panic or a dropped reply.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Stats => Response::Stats(self.metrics.report()),
            Request::Health => Response::Health(self.fleet_status()),
            Request::Models => Response::Models(self.models()),
            Request::Drain { die } => match self.drain_die(die) {
                Ok(()) => Response::Draining { die },
                Err(e) => Response::Error(format!("{e:#}")),
            },
            Request::Predict { tenant, features } => {
                match self.classify_tenant(tenant.as_deref(), features) {
                    Ok(resp) => Response::Predict(resp.to_prediction()),
                    Err(e) => Response::Error(format!("{e:#}")),
                }
            }
            Request::BatchPredict { rows } => match self.classify_batch(&rows) {
                Ok(resps) => {
                    Response::Batch(resps.iter().map(|r| r.to_prediction()).collect())
                }
                Err(e) => Response::Error(format!("{e:#}")),
            },
            Request::Register { name, dataset, seed } => {
                match TenantSpec::from_dataset(&name, &dataset, seed, self.d) {
                    Err(e) => Response::Error(e),
                    Ok(spec) => {
                        let task = spec.task;
                        match self.register_tenant(spec) {
                            Ok(score) => Response::Registered {
                                name,
                                task: task.to_string(),
                                score,
                            },
                            Err(e) => Response::Error(format!("{e:#}")),
                        }
                    }
                }
            }
            Request::Unregister { name } => match self.unregister_tenant(&name) {
                Ok(()) => Response::Unregistered { name },
                Err(e) => Response::Error(format!("{e:#}")),
            },
            Request::Trace { last } => Response::Trace(self.metrics.trace.dump(last)),
            Request::Snapshot => Response::Snapshot(self.snapshot()),
            Request::Governor => Response::Governor(self.governor_status()),
            Request::Timeline { last } => {
                Response::Timeline(self.metrics.timeline.recent(last))
            }
            Request::Hello { token } => match self.resolve_token(&token) {
                Some(scope) => Response::HelloOk { tenants: scope.listing() },
                None => Response::Error(reactor::UNKNOWN_TOKEN_MSG.into()),
            },
            Request::TenantUpdate { name, features, targets } => {
                match self.tenant_update(&name, &features, &targets) {
                    Ok(()) => Response::Updated { name },
                    Err(e) => Response::Error(format!("{e:#}")),
                }
            }
            // Blocking transports answer a stream request like a
            // buffered batch; only the reactor emits row-by-row frames
            // (DESIGN.md §20).
            Request::BatchStream { rows } => match self.classify_batch(&rows) {
                Ok(resps) => {
                    Response::Batch(resps.iter().map(|r| r.to_prediction()).collect())
                }
                Err(e) => Response::Error(format!("{e:#}")),
            },
        }
    }

    // --- governor surface (DESIGN.md §17) ---

    /// Run one governor control tick (tests, CLI; the background loop
    /// calls this on its own at the configured cadence). A no-op when
    /// the governor is disabled.
    pub fn governor_tick(&self) {
        if let Some(g) = &self.governor {
            governor_tick_impl(g);
        }
    }

    /// One-line governor status (the TCP `GOVERNOR` command):
    /// enabled/disabled, the rung ladder, move counters, energy saved
    /// and each die's current operating point.
    pub fn governor_status(&self) -> String {
        let Some(g) = &self.governor else {
            return "governor off (enable with SystemConfig.governor / velm serve --governor)"
                .to_string();
        };
        let ladder: Vec<u32> =
            g.inner.lock().unwrap().actuator.ladder().rungs().iter().map(|r| r.b).collect();
        let s = self.metrics.snapshot().governor;
        let points: Vec<String> = s
            .points
            .iter()
            .enumerate()
            .map(|(die, b)| format!("die{die}=b{b}"))
            .collect();
        format!(
            "governor on tick_ms={} ladder_b={ladder:?} ticks={} raises={} lowers={} \
             rejected={} fj_saved={} points=[{}]",
            g.cfg.tick.as_millis(),
            s.ticks,
            s.raises,
            s.lowers,
            s.rejected,
            s.fj_saved,
            points.join(" "),
        )
    }

    /// One consistent [`crate::protocol::StatsSnapshot`] of the serving
    /// fleet (DESIGN.md §16) — the structured form behind the `STATS`
    /// one-liner, the JSON/Prometheus exports and the v1
    /// `Request::Snapshot` frame.
    pub fn snapshot(&self) -> crate::protocol::StatsSnapshot {
        self.metrics.snapshot()
    }

    /// Start serving at an autotuned [`OperatingPoint`]
    /// (`velm tune` / `dse::Explorer` output): the point fixes the chip
    /// config (sigma_VT, saturation ratio, counter bits, hidden width)
    /// via `ChipConfig::from_operating_point` and the dynamic batcher's
    /// max batch — the closed loop from Fig. 7's methodology to the
    /// serving fleet.
    ///
    /// [`OperatingPoint`]: crate::dse::OperatingPoint
    pub fn start_tuned(
        sys: &SystemConfig,
        op: &crate::dse::OperatingPoint,
        train_x: &[Vec<f64>],
        train_y: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Coordinator> {
        let d = train_x.first().map_or(1, |x| x.len());
        let chip_cfg = ChipConfig::from_operating_point(op, d);
        let mut sys = sys.clone();
        sys.max_batch = op.batch.max(1);
        Coordinator::start(&sys, &chip_cfg, train_x, train_y, lambda, beta_bits)
    }

    /// Submit one request against the default head; returns the
    /// receiver for its response.
    pub fn submit(&self, features: Vec<f64>) -> Result<mpsc::Receiver<ClassifyResponse>> {
        self.submit_tenant(None, features)
    }

    /// Submit one request addressed to a tenant's model (`None` or
    /// `"default"` = the boot head). The tenant tag — name + metrics
    /// handle — is resolved here once; workers then resolve the actual
    /// head from their own lock-free tables (DESIGN.md §14).
    pub fn submit_tenant(
        &self,
        tenant: Option<&str>,
        features: Vec<f64>,
    ) -> Result<mpsc::Receiver<ClassifyResponse>> {
        anyhow::ensure!(
            features.len() == self.d,
            "expected {} features, got {}",
            self.d,
            features.len()
        );
        let tag = match tenant {
            None | Some("default") => None,
            Some(name) => {
                let reg = self.registry.lock().unwrap();
                let info = reg.get(name).ok_or_else(|| {
                    anyhow::anyhow!("unknown tenant {name} (REGISTER it first)")
                })?;
                info.metrics.record_request();
                Some(TenantTag {
                    name: Arc::clone(&info.tag),
                    metrics: Arc::clone(&info.metrics),
                })
            }
        };
        let (tx, rx) = mpsc::channel();
        let req = ClassifyRequest {
            // relaxed-ok: unique-id allocator; uniqueness needs only
            // the RMW's atomicity, not any cross-thread ordering.
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            features,
            tenant: tag,
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        };
        self.metrics.record_submission();
        self.metrics.record_request();
        self.router
            .route(req)
            .map_err(|e| anyhow::anyhow!("routing: {e}"))?;
        Ok(rx)
    }

    /// Submit many rows — each addressed to its own tenant — as ONE
    /// submission (the v1 `BatchPredict` entry, DESIGN.md §15): one
    /// `Metrics::submissions` tick for the whole batch, tenant tags
    /// resolved once per distinct tenant, and every row routed by the
    /// existing router so the batch fans across healthy dies and lands
    /// in the per-worker batch windows together — B rows amortise the
    /// hidden-layer pass instead of costing B independent round-trips.
    ///
    /// The batch is validated as a unit: a wrong-dimension row or an
    /// unknown tenant fails the whole call before anything is routed.
    /// After validation the only per-row failure left is the router
    /// finding no healthy die (a drain/quarantine racing the loop);
    /// that fails the call, and any rows already routed still execute
    /// — their receivers are simply dropped with the error. Returns
    /// one receiver per row, in row order.
    pub fn submit_batch(
        &self,
        rows: &[PredictRow],
    ) -> Result<Vec<mpsc::Receiver<ClassifyResponse>>> {
        anyhow::ensure!(!rows.is_empty(), "empty batch");
        for (i, row) in rows.iter().enumerate() {
            anyhow::ensure!(
                row.features.len() == self.d,
                "batch row {i}: expected {} features, got {}",
                self.d,
                row.features.len()
            );
        }
        // resolve each distinct tenant once, before any row is routed
        let mut tags: std::collections::BTreeMap<&str, TenantTag> =
            std::collections::BTreeMap::new();
        {
            let reg = self.registry.lock().unwrap();
            for row in rows {
                match row.tenant.as_deref() {
                    None | Some("default") => {}
                    Some(name) => {
                        if let std::collections::btree_map::Entry::Vacant(slot) =
                            tags.entry(name)
                        {
                            let info = reg.get(name).ok_or_else(|| {
                                anyhow::anyhow!("unknown tenant {name} (REGISTER it first)")
                            })?;
                            slot.insert(TenantTag {
                                name: Arc::clone(&info.tag),
                                metrics: Arc::clone(&info.metrics),
                            });
                        }
                    }
                }
            }
        }
        self.metrics.record_submission();
        let mut rxs = Vec::with_capacity(rows.len());
        for row in rows {
            let tag = match row.tenant.as_deref() {
                None | Some("default") => None,
                Some(name) => Some(tags[name].clone()),
            };
            if let Some(t) = &tag {
                t.metrics.record_request();
            }
            let (tx, rx) = mpsc::channel();
            let req = ClassifyRequest {
                // relaxed-ok: unique-id allocator (see `submit_tenant`).
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                features: row.features.clone(),
                tenant: tag,
                submitted: Instant::now(),
                collected: None,
                reply: tx,
            };
            self.metrics.record_request();
            self.router
                .route(req)
                .map_err(|e| anyhow::anyhow!("routing: {e}"))?;
            rxs.push(rx);
        }
        Ok(rxs)
    }

    /// Convenience: submit a batch and wait for every row, in order.
    pub fn classify_batch(&self, rows: &[PredictRow]) -> Result<Vec<ClassifyResponse>> {
        let rxs = self.submit_batch(rows)?;
        rxs.into_iter()
            .enumerate()
            .map(|(i, rx)| {
                rx.recv()
                    .with_context(|| format!("batch row {i}: worker dropped the request"))
            })
            .collect()
    }

    /// Convenience: submit against the default head and wait.
    pub fn classify(&self, features: Vec<f64>) -> Result<ClassifyResponse> {
        self.classify_tenant(None, features)
    }

    /// Convenience: submit against a tenant's model and wait.
    pub fn classify_tenant(
        &self,
        tenant: Option<&str>,
        features: Vec<f64>,
    ) -> Result<ClassifyResponse> {
        let rx = self.submit_tenant(tenant, features)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn n_workers(&self) -> usize {
        self.router.n_workers()
    }

    // --- model registry surface (DESIGN.md §14) ---

    /// Register a tenant fleet-wide: every die (actives *and* hot
    /// standbys, so promotions keep serving all models) trains the
    /// tenant's heads chip-in-the-loop from one shared H — one pass of
    /// the tenant's training set per die, one Cholesky for all of its
    /// heads. Returns the mean train-set score across dies (error rate
    /// for classification, RMSE for regression). On any die failure
    /// the partial installs are rolled back.
    pub fn register_tenant(&self, spec: TenantSpec) -> Result<f64> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(
            spec.d() == self.d,
            "tenant {} trains at dimension {}, fleet serves {}",
            spec.name,
            spec.d(),
            self.d
        );
        anyhow::ensure!(
            spec.name != "default",
            "'default' names the boot head and cannot be re-registered"
        );
        anyhow::ensure!(
            !spec.name.is_empty() && !spec.name.contains(char::is_whitespace),
            "tenant names must be non-empty and whitespace-free"
        );
        // serialise with other register/unregister calls: the duplicate
        // check below must stay valid until the directory insert
        let _gate = self.registration_gate.lock().unwrap();
        anyhow::ensure!(
            !self.registry.lock().unwrap().contains(&spec.name),
            "tenant {} is already registered (UNREGISTER it first)",
            spec.name
        );
        let spec = Arc::new(spec);
        let mut rxs = Vec::new();
        let mut failure: Option<String> = None;
        for (i, tx) in self.senders.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            let sent = tx.send(WorkerMsg::Control(ControlMsg::Register {
                spec: Arc::clone(&spec),
                reply: rtx,
            }));
            if sent.is_err() {
                // keep going into the rollback below — dies already
                // sent to must not keep heads the registry won't record
                failure = Some(format!("worker {i} is gone"));
                break;
            }
            rxs.push(rrx);
        }
        let mut die_scores = Vec::new();
        for (i, rrx) in rxs.into_iter().enumerate() {
            match rrx.recv() {
                Ok(Ok(score)) => die_scores.push(score),
                Ok(Err(e)) => failure = Some(format!("die {i}: {e}")),
                Err(_) => failure = Some(format!("die {i} dropped the registration")),
            }
        }
        if let Some(why) = failure {
            // no die may serve a tenant the registry does not record
            self.broadcast_unregister(&spec.name);
            anyhow::bail!("registering tenant {}: {why}", spec.name);
        }
        let mean = die_scores.iter().sum::<f64>() / die_scores.len().max(1) as f64;
        let tenant_metrics = self.metrics.register_tenant(&spec.name);
        tenant_metrics.set_score(mean);
        if let Some(g) = &self.governor {
            g.slos.lock().unwrap().insert(spec.name.clone(), spec.slo_max_err);
            g.p99_slos.lock().unwrap().insert(spec.name.clone(), spec.slo_p99_us);
        }
        self.registry.lock().unwrap().insert(TenantInfo {
            tag: Arc::from(spec.name.as_str()),
            spec: Arc::clone(&spec),
            die_scores,
            metrics: tenant_metrics,
        });
        Ok(mean)
    }

    /// Drop a tenant fleet-wide. In-flight requests carrying its tag
    /// may race the removal; workers drop those without replying (the
    /// client sees a closed channel), and tenant isolation holds — no
    /// other tenant's heads are touched.
    pub fn unregister_tenant(&self, name: &str) -> Result<()> {
        anyhow::ensure!(name != "default", "the boot head cannot be unregistered");
        let _gate = self.registration_gate.lock().unwrap();
        let removed = self.registry.lock().unwrap().remove(name);
        anyhow::ensure!(removed.is_some(), "unknown tenant {name}");
        self.broadcast_unregister(name);
        self.metrics.drop_tenant(name);
        if let Some(g) = &self.governor {
            g.slos.lock().unwrap().remove(name);
            g.p99_slos.lock().unwrap().remove(name);
        }
        Ok(())
    }

    fn broadcast_unregister(&self, name: &str) -> usize {
        let tenant: Arc<str> = Arc::from(name);
        let mut rxs = Vec::new();
        for tx in &self.senders {
            let (rtx, rrx) = mpsc::channel();
            if tx
                .send(WorkerMsg::Control(ControlMsg::Unregister {
                    tenant: Arc::clone(&tenant),
                    reply: rtx,
                }))
                .is_ok()
            {
                rxs.push(rrx);
            }
        }
        rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(true))).count()
    }

    /// OS-ELM incremental update for one tenant: each die drives the
    /// labelled sample through its own hidden layer and streams it into
    /// all of the tenant's heads (shared-P RLS — DESIGN.md §14).
    /// `targets` carries one value per head: the scalar for binary /
    /// regression tenants, the ±1 one-vs-all row for multi-class.
    pub fn tenant_update(&self, name: &str, x: &[f64], targets: &[f64]) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.d,
            "expected {} features, got {}",
            self.d,
            x.len()
        );
        let (tag, heads) = {
            let reg = self.registry.lock().unwrap();
            let info = reg
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown tenant {name}"))?;
            (Arc::clone(&info.tag), info.spec.task.heads())
        };
        anyhow::ensure!(
            targets.len() == heads,
            "tenant {name} has {heads} heads, update carries {} targets",
            targets.len()
        );
        let x = Arc::new(x.to_vec());
        let targets = Arc::new(targets.to_vec());
        let mut rxs = Vec::new();
        for (i, tx) in self.senders.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            tx.send(WorkerMsg::Control(ControlMsg::OnlineUpdate {
                tenant: Arc::clone(&tag),
                x: Arc::clone(&x),
                targets: Arc::clone(&targets),
                reply: rtx,
            }))
            .map_err(|_| anyhow::anyhow!("worker {i} is gone"))?;
            rxs.push(rrx);
        }
        for (i, rrx) in rxs.into_iter().enumerate() {
            rrx.recv()
                .with_context(|| format!("die {i} dropped the update"))?
                .map_err(|e| anyhow::anyhow!("die {i}: {e}"))?;
        }
        Ok(())
    }

    /// One-line tenant directory (the TCP `MODELS` command): the boot
    /// head plus every registered tenant with its mean train score.
    pub fn models(&self) -> String {
        let n = self.n_workers();
        let default_line =
            format!("default task=classification/2 heads=1 dies={n} train_score=boot");
        let reg = self.registry.lock().unwrap();
        if reg.is_empty() {
            default_line
        } else {
            format!("{default_line}; {}", reg.listing())
        }
    }

    /// Names of the registered tenants (without the boot head).
    pub fn tenant_names(&self) -> Vec<String> {
        self.registry
            .lock()
            .unwrap()
            .iter()
            .map(|(name, _)| name.clone())
            .collect()
    }

    // --- fleet-health surface (DESIGN.md §12) ---

    /// Run one probe/recovery pass over the fleet (tests, CLI; the
    /// background prober calls this on its own when a cadence is set).
    pub fn fleet_tick(&self) {
        self.fleet.lock().unwrap().tick();
    }

    /// One-line fleet status: per-die lifecycle gauges + recovery
    /// counters (the TCP `HEALTH` command). Reads only shared atomics —
    /// no manager lock — so it stays responsive while a tick is blocked
    /// on a slow worker reply.
    pub fn fleet_status(&self) -> String {
        crate::fleet::lifecycle::status_line(&self.router.health, &self.metrics)
    }

    /// The fleet manager's bounded human-readable event log.
    pub fn fleet_log(&self) -> Vec<String> {
        self.fleet.lock().unwrap().log().to_vec()
    }

    /// Per-die lifecycle snapshot (lock-free, see `fleet_status`).
    pub fn health_snapshot(&self) -> Vec<DieState> {
        self.router.health.snapshot()
    }

    /// Operator drain (the TCP `DRAIN <die>` command): pull a die from
    /// rotation; subsequent ticks recalibrate and re-admit it.
    pub fn drain_die(&self, die: usize) -> Result<()> {
        self.fleet
            .lock()
            .unwrap()
            .drain(die)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Install a drift-injection schedule (replayed by subsequent ticks).
    pub fn set_drift_schedule(&self, schedule: DriftSchedule) {
        self.fleet.lock().unwrap().set_schedule(schedule);
    }

    /// Immediately inject a drift event (Fig. 17/18-style perturbation)
    /// into one die or the whole fleet.
    pub fn inject_drift(
        &self,
        die: Option<usize>,
        vdd: Option<f64>,
        temp_k: Option<f64>,
        age_sigma_vt: Option<f64>,
    ) {
        self.fleet.lock().unwrap().inject(die, vdd, temp_k, age_sigma_vt);
    }

    /// Graceful shutdown: stop the prober, close the queues and join
    /// the worker threads.
    pub fn shutdown(self) {
        let Coordinator {
            router, workers, fleet, senders, auto_probe, governor_thread, ..
        } = self;
        if let Some((stop, handle)) = governor_thread {
            // relaxed-ok: quit flag; the join right below is the
            // synchronization point for everything the thread wrote.
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        if let Some((stop, handle)) = auto_probe {
            // relaxed-ok: quit flag; join below synchronizes.
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        drop(router); // drops the router's senders
        drop(fleet); // drops the manager's senders
        drop(senders); // drops the registry's senders -> workers drain and exit
        for w in workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transfer;
    use crate::util::prng::Prng;

    fn tiny_system() -> (SystemConfig, ChipConfig, Vec<Vec<f64>>, Vec<f64>) {
        let sys = SystemConfig {
            n_chips: 2,
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(1),
            artifact_dir: "/nonexistent".into(), // force chip-sim path
            pjrt_min_batch: 4,
            pjrt_max_failures: 3,
            seed: 99,
            normalize: false,
            standby_chips: 0,
            virtual_d: None,
            virtual_l: None,
            die_geoms: Vec::new(),
            read_timeout: None,
            trace_cap: 512,
            reactor_workers: 4,
            auth_tokens: Vec::new(),
            fleet: Default::default(),
            governor: Default::default(),
        };
        let chip = ChipConfig::default()
            .with_dims(6, 24)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let mut rng = Prng::new(5);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..120 {
            let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
            xs.push((0..6).map(|_| (0.4 * y + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0)).collect());
            ys.push(y);
        }
        (sys, chip, xs, ys)
    }

    #[test]
    fn end_to_end_classify_over_threads() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.n_workers(), 2);
        let mut correct = 0;
        for (x, &y) in xs.iter().take(60).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
            assert_eq!(resp.backend, Backend::ChipSim);
        }
        assert!(correct >= 50, "only {correct}/60 correct");
        assert!(coord.metrics.responses.load(Ordering::Relaxed) >= 60);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let rxs: Vec<_> = (0..40)
            .map(|i| coord.submit(xs[i % xs.len()].clone()).unwrap())
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "lost or duplicated responses");
        coord.shutdown();
    }

    #[test]
    fn start_tuned_applies_operating_point() {
        let (sys, _, xs, ys) = tiny_system();
        let op = crate::dse::OperatingPoint {
            sigma_vt: 0.016,
            ratio: 0.75,
            b: 10,
            l: 24,
            batch: 4,
        };
        let coord = Coordinator::start_tuned(&sys, &op, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.d, 6); // input dim follows the workload
        let mut correct = 0;
        for (x, &y) in xs.iter().take(40).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 correct at tuned point");
        coord.shutdown();
    }

    #[test]
    fn rejects_wrong_dimension() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert!(coord.submit(vec![0.0; 3]).is_err());
        coord.shutdown();
    }

    #[test]
    fn typed_dispatch_matches_the_direct_path() {
        // Coordinator::handle is the one entry point the wire codecs
        // and the in-process client share: its answers must be the
        // direct API's answers, and errors must come back typed
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1; // one die -> deterministic scores across calls
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.handle(Request::Ping), Response::Pong);
        match coord.handle(Request::Predict { tenant: None, features: xs[0].clone() }) {
            Response::Predict(p) => {
                let direct = coord.classify(xs[0].clone()).unwrap();
                assert_eq!(p.label, direct.label);
                assert_eq!(p.score.to_bits(), direct.score.to_bits());
                assert!(p.tenant.is_none());
            }
            other => panic!("predict dispatched to {other:?}"),
        }
        // wrong dimension and unknown tenant are typed errors
        assert!(matches!(
            coord.handle(Request::Predict { tenant: None, features: vec![0.0; 2] }),
            Response::Error(_)
        ));
        assert!(matches!(
            coord.handle(Request::Predict {
                tenant: Some("nosuch".into()),
                features: xs[0].clone()
            }),
            Response::Error(_)
        ));
        match coord.handle(Request::Stats) {
            Response::Stats(s) => assert!(s.contains("requests="), "{s}"),
            other => panic!("stats dispatched to {other:?}"),
        }
        match coord.handle(Request::Unregister { name: "nosuch".into() }) {
            Response::Error(e) => assert!(e.contains("unknown tenant"), "{e}"),
            other => panic!("unregister dispatched to {other:?}"),
        }
        // observability verbs (DESIGN.md §16): the flight recorder has
        // the answered request, the snapshot is self-consistent
        match coord.handle(Request::Trace { last: 8 }) {
            Response::Trace(ts) => {
                assert!(!ts.is_empty(), "the classify above must be traced");
                let t = &ts[0];
                assert_eq!(t.outcome, crate::protocol::TraceOutcome::Ok);
                assert!(t.queue_us + t.batch_us + t.compute_us <= t.total_us);
            }
            other => panic!("trace dispatched to {other:?}"),
        }
        match coord.handle(Request::Snapshot) {
            Response::Snapshot(s) => {
                assert!(s.responses <= s.requests);
                assert!(s.requests >= 1);
                assert!(s.energy_fj > 0, "served conversions must be priced");
            }
            other => panic!("snapshot dispatched to {other:?}"),
        }
        // the timeline profiler saw the request pass through the die:
        // events come back oldest first, ready for Chrome export
        match coord.handle(Request::Timeline { last: 64 }) {
            Response::Timeline(events) => {
                assert!(!events.is_empty(), "the classify above must be profiled");
                for pair in events.windows(2) {
                    assert!(pair[0].start_us <= pair[1].start_us, "oldest first");
                }
                let json = timeline::chrome_trace_json(&events);
                timeline::validate_chrome_trace(&json).unwrap();
            }
            other => panic!("timeline dispatched to {other:?}"),
        }
        coord.shutdown();
    }

    #[test]
    fn streaming_verbs_dispatch_through_handle() {
        // DESIGN.md §20: Hello resolves tokens against the auth table,
        // TenantUpdate rides the shared-P OS-ELM path, and BatchStream
        // on a blocking transport answers exactly like BatchPredict.
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1; // one die -> deterministic scores across calls
        sys.auth_tokens = vec!["admin=*".into(), "slope-key=slope,aux".into()];
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        coord
            .register_tenant(
                TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12).unwrap(),
            )
            .unwrap();
        match coord.handle(Request::Hello { token: "admin".into() }) {
            Response::HelloOk { tenants } => assert_eq!(tenants, vec!["*".to_string()]),
            other => panic!("hello dispatched to {other:?}"),
        }
        match coord.handle(Request::Hello { token: "slope-key".into() }) {
            // scope listings come back sorted (BTreeSet order)
            Response::HelloOk { tenants } => {
                assert_eq!(tenants, vec!["aux".to_string(), "slope".to_string()])
            }
            other => panic!("hello dispatched to {other:?}"),
        }
        match coord.handle(Request::Hello { token: "wrong".into() }) {
            Response::Error(e) => assert!(e.contains("unknown auth token"), "{e}"),
            other => panic!("bad hello dispatched to {other:?}"),
        }
        let rows: Vec<PredictRow> = (0..6)
            .map(|i| PredictRow {
                tenant: if i % 2 == 0 { None } else { Some("slope".into()) },
                features: xs[i].clone(),
            })
            .collect();
        let buffered = match coord.handle(Request::BatchPredict { rows: rows.clone() }) {
            Response::Batch(ps) => ps,
            other => panic!("batch dispatched to {other:?}"),
        };
        match coord.handle(Request::BatchStream { rows }) {
            Response::Batch(ps) => {
                assert_eq!(ps.len(), buffered.len());
                for (s, b) in ps.iter().zip(&buffered) {
                    assert_eq!(s.label, b.label);
                    assert_eq!(s.score.to_bits(), b.score.to_bits());
                }
            }
            other => panic!("stream dispatched to {other:?}"),
        }
        // live updates move the head: drag the fit toward an offset
        // target and watch the same row's score follow (DESIGN.md §14)
        let before = coord.classify_tenant(Some("slope"), xs[0].clone()).unwrap().score;
        let target = before + 5.0;
        for _ in 0..30 {
            match coord.handle(Request::TenantUpdate {
                name: "slope".into(),
                features: xs[0].clone(),
                targets: vec![target],
            }) {
                Response::Updated { name } => assert_eq!(name, "slope"),
                other => panic!("update dispatched to {other:?}"),
            }
        }
        let after = coord.classify_tenant(Some("slope"), xs[0].clone()).unwrap().score;
        assert!(
            (target - after).abs() < (target - before).abs(),
            "updates must pull the head toward the target: before={before} after={after}"
        );
        // typed errors: unknown tenant, wrong head count
        assert!(matches!(
            coord.handle(Request::TenantUpdate {
                name: "nosuch".into(),
                features: xs[0].clone(),
                targets: vec![0.0],
            }),
            Response::Error(_)
        ));
        assert!(matches!(
            coord.handle(Request::TenantUpdate {
                name: "slope".into(),
                features: xs[0].clone(),
                targets: vec![0.0, 1.0],
            }),
            Response::Error(_)
        ));
        coord.shutdown();
    }

    #[test]
    fn batch_submission_is_one_submission_with_per_row_answers() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1; // one die -> deterministic scores across calls
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        coord
            .register_tenant(
                TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12).unwrap(),
            )
            .unwrap();
        let rows: Vec<PredictRow> = (0..10)
            .map(|i| PredictRow {
                tenant: if i % 2 == 0 { None } else { Some("slope".into()) },
                features: xs[i].clone(),
            })
            .collect();
        let subs0 = coord.metrics.submissions.load(Ordering::Relaxed);
        let resps = coord.classify_batch(&rows).unwrap();
        // ONE submission, ten rows, answers in row order with the
        // right tenant's head applied per row
        assert_eq!(coord.metrics.submissions.load(Ordering::Relaxed) - subs0, 1);
        assert_eq!(resps.len(), 10);
        for (i, resp) in resps.iter().enumerate() {
            if i % 2 == 0 {
                assert!(resp.tenant.is_none());
                assert!(resp.label == 1 || resp.label == -1);
            } else {
                assert_eq!(resp.tenant.as_deref(), Some("slope"));
                assert_eq!(resp.label, 0, "regression rows answer label 0");
            }
        }
        // batch answers match single-row answers bit-exactly on a
        // deterministic fleet
        let single = coord.classify_tenant(Some("slope"), xs[1].clone()).unwrap();
        assert_eq!(resps[1].score.to_bits(), single.score.to_bits());
        // the whole batch is refused before routing when any row is bad
        let bad = vec![
            PredictRow { tenant: None, features: xs[0].clone() },
            PredictRow { tenant: None, features: vec![0.0; 2] },
        ];
        assert!(coord.submit_batch(&bad).is_err());
        let unknown = vec![PredictRow { tenant: Some("nosuch".into()), features: xs[0].clone() }];
        assert!(coord.submit_batch(&unknown).is_err());
        assert!(coord.submit_batch(&[]).is_err());
        coord.shutdown();
    }

    #[test]
    fn virtual_fleet_serves_and_prices_passes() {
        // 2 dies fabricated at 3x8 serving the d=6, L=24 projection:
        // every response costs hidden_blocks x input_chunks = 6 passes
        let (mut sys, _, xs, ys) = tiny_system();
        sys.virtual_d = Some(6);
        sys.virtual_l = Some(24);
        let chip = ChipConfig::default()
            .with_dims(3, 8)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.d, 6);
        assert_eq!(coord.passes, 6);
        let mut correct = 0;
        for (x, &y) in xs.iter().take(40).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            assert_eq!(resp.backend, Backend::ChipSim);
            assert_eq!(resp.passes, 6);
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 correct on the virtual fleet");
        // the metrics ledger books exactly passes() conversions/request
        let responses = coord.metrics.responses.load(Ordering::Relaxed);
        assert_eq!(
            coord.metrics.conversions.load(Ordering::Relaxed),
            responses * 6
        );
        coord.shutdown();
    }

    #[test]
    fn heterogeneous_fleet_prices_each_die_at_its_own_cost() {
        // die 0 is fabricated at the full 6x24 projection (1 pass per
        // request), die 1 at 3x8 (6 passes): both serve, and every
        // response carries its own die's real pass cost
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.virtual_d = Some(6);
        sys.virtual_l = Some(24);
        sys.die_geoms = vec![(6, 24), (3, 8)];
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.passes, 6, "fleet-level cost reports the worst die");
        let mut seen = [false; 2];
        let mut booked = 0u64;
        for (i, x) in xs.iter().take(60).enumerate() {
            let resp = coord.classify(x.clone()).unwrap();
            let expect = if resp.worker == 0 { 1 } else { 6 };
            assert_eq!(resp.passes, expect, "request {i} on die {}", resp.worker);
            seen[resp.worker] = true;
            booked += expect as u64;
        }
        assert!(seen[0] && seen[1], "both geometries must serve traffic");
        assert_eq!(coord.metrics.conversions.load(Ordering::Relaxed), booked);
        coord.shutdown();
    }

    #[test]
    fn heterogeneous_geometry_validation_fails_fast() {
        let (mut sys, chip, xs, ys) = tiny_system();
        // wrong arity: 2 dies, 1 geometry
        sys.die_geoms = vec![(6, 24)];
        assert!(Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).is_err());
        // a die larger than the served projection would be masked
        let (mut sys2, chip2, ..) = tiny_system();
        sys2.die_geoms = vec![(6, 24), (6, 48)]; // projection is 6x24
        assert!(Coordinator::start(&sys2, &chip2, &xs, &ys, 1e-2, 10).is_err());
    }

    #[test]
    fn virtual_fleet_survives_probe_ticks_and_recovers_health() {
        let (mut sys, _, xs, ys) = tiny_system();
        sys.virtual_d = Some(6);
        sys.virtual_l = Some(24);
        let chip = ChipConfig::default()
            .with_dims(3, 8)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        for _ in 0..3 {
            coord.fleet_tick();
        }
        assert!(
            coord.health_snapshot().iter().all(|&s| s == DieState::Healthy),
            "{}",
            coord.fleet_status()
        );
        assert!(coord.metrics.probes.load(Ordering::Relaxed) >= 6);
        // the refit path flows through the virtual forward: drain a die
        // and let the state machine walk it back to Healthy
        coord.drain_die(0).unwrap();
        coord.fleet_tick();
        coord.fleet_tick();
        assert_eq!(
            coord.health_snapshot()[0],
            DieState::Healthy,
            "virtual die not re-admitted: {}\n{}",
            coord.fleet_status(),
            coord.fleet_log().join("\n")
        );
        assert!(coord.metrics.refits.load(Ordering::Relaxed) >= 1);
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        coord.shutdown();
    }

    #[test]
    fn invalid_virtual_dims_fail_fast() {
        let (mut sys, chip, xs, ys) = tiny_system();
        // chip is 6x24: d beyond k*N cannot be served by rotation
        sys.virtual_d = Some(6 * 24 + 1);
        assert!(Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).is_err());
        // training set dimension must match the served dimension
        let mut sys2 = tiny_system().0;
        sys2.virtual_d = Some(12);
        assert!(Coordinator::start(&sys2, &chip, &xs, &ys, 1e-2, 10).is_err());
        // virtual dims below the fabricated die would silently mask
        // neurons — refuse instead of serving a crippled projection
        let mut sys3 = tiny_system().0;
        sys3.virtual_l = Some(12); // chip is 6x24
        assert!(Coordinator::start(&sys3, &chip, &xs, &ys, 1e-2, 10).is_err());
    }

    #[test]
    fn stable_fleet_ticks_keep_dies_healthy_and_serving() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        for _ in 0..3 {
            coord.fleet_tick();
        }
        assert!(
            coord.health_snapshot().iter().all(|&s| s == DieState::Healthy),
            "{}",
            coord.fleet_status()
        );
        assert!(coord.metrics.probes.load(Ordering::Relaxed) >= 6);
        assert_eq!(coord.metrics.renorms.load(Ordering::Relaxed), 0);
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        let status = coord.fleet_status();
        assert!(status.contains("die0=Healthy"), "{status}");
        coord.shutdown();
    }

    #[test]
    fn standby_dies_are_trained_but_not_routed() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.standby_chips = 1;
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.n_workers(), 3);
        assert_eq!(coord.health_snapshot()[2], DieState::Standby);
        for i in 0..30 {
            let resp = coord.classify(xs[i % xs.len()].clone()).unwrap();
            assert_ne!(resp.worker, 2, "standby die must not serve traffic");
        }
        coord.shutdown();
    }

    #[test]
    fn operator_drain_recalibrates_and_readmits() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        coord.drain_die(0).unwrap();
        assert_eq!(coord.health_snapshot()[0], DieState::Draining);
        // draining dies cannot be drained again
        assert!(coord.drain_die(0).is_err());
        assert!(coord.drain_die(99).is_err());
        // traffic keeps flowing on die 1 while die 0 is out
        for i in 0..10 {
            let resp = coord.classify(xs[i].clone()).unwrap();
            assert_eq!(resp.worker, 1);
        }
        // tick 1: drained (no outstanding) -> Recalibrating;
        // tick 2: refit -> Healthy again
        coord.fleet_tick();
        coord.fleet_tick();
        let snap = coord.health_snapshot();
        assert_eq!(snap[0], DieState::Healthy, "{}", coord.fleet_status());
        assert!(coord.metrics.refits.load(Ordering::Relaxed) >= 1);
        // and it serves traffic again
        let mut hit0 = false;
        for i in 0..20 {
            let resp = coord.classify(xs[i].clone()).unwrap();
            hit0 |= resp.worker == 0;
        }
        assert!(hit0, "re-admitted die should see traffic");
        coord.shutdown();
    }

    // --- governor surface (DESIGN.md §17) ---

    fn governor_cfg(bits: &[u32]) -> crate::governor::GovernorConfig {
        crate::governor::GovernorConfig {
            enabled: true,
            // park the background thread: these tests drive ticks by hand
            tick: std::time::Duration::from_secs(3600),
            cooldown_ticks: 0,
            window_ticks: 100,
            max_moves_per_window: 100,
            hot_queue_us: 0, // any traffic at all counts as hot
            bits: bits.to_vec(),
            ..Default::default()
        }
    }

    #[test]
    fn governor_disabled_is_off_and_manual_tick_is_a_noop() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert!(coord.governor_status().starts_with("governor off"), "{}", coord.governor_status());
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.ticks, 0);
        match coord.handle(Request::Governor) {
            Response::Governor(s) => assert!(s.contains("off"), "{s}"),
            other => panic!("governor dispatched to {other:?}"),
        }
        coord.shutdown();
    }

    #[test]
    fn governor_lowers_idle_fleet_and_restores_on_traffic() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1;
        sys.governor = governor_cfg(&[6, 8]); // ladder [6, 8, boot=10]
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let st = coord.governor_status();
        assert!(st.starts_with("governor on"), "{st}");
        assert!(st.contains("ladder_b=[6, 8, 10]"), "{st}");
        // idle ticks walk the die down the ladder one rung at a time,
        // then hold at the floor
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![6]);
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![6]);
        // a row served on the cheap rung still answers, and books its
        // savings vs the boot price into the ledger (the tick blocks on
        // the worker's retune ack, so the cheap price is already live)
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        assert!(coord.metrics.gov_fj_saved.load(Ordering::Relaxed) > 0);
        // the traffic shows up as a request delta on the next tick:
        // the die jumps straight back to the boot point
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![10]);
        let g = coord.snapshot().governor;
        assert_eq!((g.lowers, g.raises), (2, 1));
        assert!(g.ticks >= 4);
        // the transitions are on the flight recorder
        let trace = coord.metrics.trace.dump(16);
        assert!(trace
            .iter()
            .any(|t| t.outcome == crate::protocol::TraceOutcome::GovernorRaised));
        assert!(trace
            .iter()
            .any(|t| t.outcome == crate::protocol::TraceOutcome::GovernorLowered));
        match coord.handle(Request::Governor) {
            Response::Governor(s) => assert!(s.contains("raises=1"), "{s}"),
            other => panic!("governor dispatched to {other:?}"),
        }
        coord.shutdown();
    }

    #[test]
    fn governor_never_retunes_non_healthy_dies() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1;
        sys.standby_chips = 1;
        sys.governor = governor_cfg(&[6, 8]);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        coord.governor_tick();
        let g = coord.snapshot().governor;
        assert_eq!(g.points, vec![8, 10], "standby die must hold the boot point");
        assert!(g.rejected >= 1, "lifecycle deferral must be counted");
        coord.shutdown();
    }

    #[test]
    fn latency_slo_breach_raises_and_blocks_descent_at_idle() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1;
        sys.governor = governor_cfg(&[6, 8]); // ladder [6, 8, boot=10]
        sys.governor.p99_slo_us = 1_000; // 1 ms fleet p99 SLO
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        // a quiet, healthy fleet descends normally
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        // late rows land in the latency histogram with NO new requests:
        // requests_delta stays 0 (idle by every traffic signal), but the
        // windowed p99 over these rows breaches the 1 ms SLO
        for _ in 0..20 {
            coord.metrics.record_response(std::time::Duration::from_millis(50));
        }
        coord.governor_tick();
        let snap = coord.snapshot();
        assert_eq!(
            snap.governor.points,
            vec![10],
            "a p99 breach must jump the die back to boot, traffic or not"
        );
        assert!(snap.slo_breaches >= 1, "breach ticks are counted");
        // the window slides: the next tick sees no new late rows, the
        // breach clears, and the idle fleet is free to descend again
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        // rows served on the cheap rung still book exact fJ savings
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        assert!(coord.metrics.gov_fj_saved.load(Ordering::Relaxed) > 0);
        coord.shutdown();
    }

    #[test]
    fn tenant_latency_slo_breach_blocks_the_descent() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1;
        sys.governor = governor_cfg(&[8]);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        // a 1 us tenant p99 SLO no real serving latency can hold
        let spec = TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12)
            .unwrap()
            .with_slo(None, Some(1));
        coord.register_tenant(spec).unwrap();
        // no traffic, no late rows yet: the idle fleet descends
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        // late tenant rows land in ITS histogram with no fleet traffic:
        // only the per-tenant windowed p99 can see this breach
        let h = coord.metrics.tenant_handle("slope").unwrap();
        for _ in 0..5 {
            h.record_response(std::time::Duration::from_millis(5));
        }
        coord.governor_tick();
        assert_eq!(
            coord.snapshot().governor.points,
            vec![10],
            "the tenant's p99 breach must pin the die back at boot"
        );
        assert!(coord.snapshot().slo_breaches >= 1);
        // dropping the tenant (its cursor goes with it) frees the descent
        coord.unregister_tenant("slope").unwrap();
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        coord.shutdown();
    }

    #[test]
    fn served_fleet_occupancy_fractions_sum_to_one() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        coord
            .register_tenant(
                TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12).unwrap(),
            )
            .unwrap();
        // a mixed multi-tenant batch across both dies
        let rows: Vec<PredictRow> = (0..24)
            .map(|i| PredictRow {
                tenant: if i % 2 == 0 { None } else { Some("slope".into()) },
                features: xs[i].clone(),
            })
            .collect();
        coord.classify_batch(&rows).unwrap();
        let snap = coord.snapshot();
        assert!(!snap.occupancy.is_empty(), "served dies must report occupancy");
        for occ in &snap.occupancy {
            assert!(occ.total_us() > 0, "die {} profiled nothing", occ.die);
            let sum: f64 = occ.fractions().iter().sum();
            assert!(
                (sum - 1.0).abs() < 1e-9,
                "die {} fractions sum to {sum}",
                occ.die
            );
        }
        // tenant busy shares: both the default head and the tenant
        // worked, and the tenant's share is visible
        let slope = snap.tenants.iter().find(|t| t.name == "slope").unwrap();
        assert!(slope.busy_us > 0, "tenant rows must book busy time");
        coord.shutdown();
    }

    #[test]
    fn tenant_accuracy_slo_violation_blocks_the_descent() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.n_chips = 1;
        sys.governor = governor_cfg(&[8]);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        // an unsatisfiable accuracy SLO: train RMSE can never be <= 0
        let spec = TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12)
            .unwrap()
            .with_slo(Some(0.0), None);
        coord.register_tenant(spec).unwrap();
        coord.governor_tick();
        let g = coord.snapshot().governor;
        assert_eq!(g.points, vec![10], "SLO violation must pin the boot point");
        assert_eq!(g.lowers, 0);
        // dropping the violating tenant frees the descent
        coord.unregister_tenant("slope").unwrap();
        coord.governor_tick();
        assert_eq!(coord.snapshot().governor.points, vec![8]);
        coord.shutdown();
    }

    // --- registry surface ---

    fn regression_targets(xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| 0.5 * x[0] - 0.25 * x[1]).collect()
    }

    #[test]
    fn register_serve_and_unregister_a_tenant() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let reg_y = regression_targets(&xs);
        let spec =
            TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12).unwrap();
        let rmse = coord.register_tenant(spec).unwrap();
        assert!(rmse < 0.2, "train rmse {rmse}");
        assert_eq!(coord.tenant_names(), vec!["slope".to_string()]);
        let models = coord.models();
        assert!(models.contains("slope task=regression"), "{models}");
        // tenant traffic answers in target units, default still works
        for (x, &t) in xs.iter().take(20).zip(&reg_y) {
            let resp = coord.classify_tenant(Some("slope"), x.clone()).unwrap();
            assert_eq!(resp.label, 0);
            assert_eq!(resp.tenant.as_deref(), Some("slope"));
            assert!((resp.score - t).abs() < 0.4, "score {} target {t}", resp.score);
            let d = coord.classify(x.clone()).unwrap();
            assert!(d.tenant.is_none());
        }
        // per-tenant metrics accumulated
        let report = coord.metrics.report();
        assert!(report.contains("tenant[slope:"), "{report}");
        // unknown tenants are refused at submit
        assert!(coord.classify_tenant(Some("nosuch"), xs[0].clone()).is_err());
        // duplicate registration is refused
        let dup = TenantSpec::regression("slope", xs.clone(), &reg_y, 1e-3, 12).unwrap();
        assert!(coord.register_tenant(dup).is_err());
        // unregister removes it everywhere
        coord.unregister_tenant("slope").unwrap();
        assert!(coord.tenant_names().is_empty());
        assert!(coord.classify_tenant(Some("slope"), xs[0].clone()).is_err());
        assert!(coord.unregister_tenant("slope").is_err());
        assert!(coord.unregister_tenant("default").is_err());
        coord.shutdown();
    }

    #[test]
    fn register_refuses_bad_specs() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        // wrong dimension
        let bad = TenantSpec::regression("w", vec![vec![0.0; 3]; 4], &[0.0; 4], 1e-3, 10)
            .unwrap();
        assert!(coord.register_tenant(bad).is_err());
        // reserved / malformed names
        let reg_y = regression_targets(&xs);
        let named =
            TenantSpec::regression("default", xs.clone(), &reg_y, 1e-3, 10).unwrap();
        assert!(coord.register_tenant(named).is_err());
        let spaced =
            TenantSpec::regression("two words", xs.clone(), &reg_y, 1e-3, 10).unwrap();
        assert!(coord.register_tenant(spaced).is_err());
        coord.shutdown();
    }

    #[test]
    fn tenant_online_update_moves_the_heads() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        // a deliberately tiny training set leaves room to learn online
        let reg_y = regression_targets(&xs);
        let spec = TenantSpec::regression(
            "slope",
            xs[..8].to_vec(),
            &reg_y[..8],
            1e-2,
            12,
        )
        .unwrap();
        coord.register_tenant(spec).unwrap();
        let probe_x = xs[20].clone();
        let before = coord.classify_tenant(Some("slope"), probe_x.clone()).unwrap();
        // stream the rest of the set through OS-ELM updates
        for (x, &t) in xs.iter().zip(&reg_y).skip(8).take(60) {
            coord.tenant_update("slope", x, &[t]).unwrap();
        }
        let after = coord.classify_tenant(Some("slope"), probe_x.clone()).unwrap();
        let target = 0.5 * probe_x[0] - 0.25 * probe_x[1];
        assert!(
            (after.score - target).abs() <= (before.score - target).abs() + 0.05,
            "online updates must not wreck the head: before {} after {} target {target}",
            before.score,
            after.score
        );
        // arity and existence are validated
        assert!(coord.tenant_update("slope", &xs[0], &[1.0, 2.0]).is_err());
        assert!(coord.tenant_update("nosuch", &xs[0], &[1.0]).is_err());
        assert!(coord.tenant_update("slope", &[0.0; 2], &[1.0]).is_err());
        coord.shutdown();
    }
}
