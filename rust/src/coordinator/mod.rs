//! L3 serving coordinator: the paper's classifier chip recast as a
//! request pipeline (DESIGN.md §8, §12, §13).
//!
//! ```text
//! client -> Coordinator::submit -> Router (least pass-weighted
//!           outstanding work over HEALTHY dies)
//!        -> per-worker dynamic batcher (conversion budget)
//!        -> hidden layer (PJRT batched artifact | chip sim,
//!           through the Section V rotation plan on virtual dies)
//!        -> fixed-point second stage -> response + metrics
//!
//! fleet manager -> probe / renormalise / refit control messages
//!               -> per-die lifecycle state read by the router
//! ```
//!
//! Threads + channels from std only (no tokio in the offline vendor
//! set); one OS thread per die mirrors one physical chip per board.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;
pub mod workload;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::chip::ChipModel;
use crate::config::{ChipConfig, SystemConfig};
use crate::elm::secondstage::SecondStage;
use crate::elm::train::{assemble_h, solve_head};
use crate::extension::{RotationPlan, ServeChip, ServeHidden};
use crate::fleet::{
    DieState, DriftSchedule, FleetManager, FleetSetup, FleetState, ProbeSet,
};

pub use metrics::Metrics;
pub use request::{Backend, ClassifyRequest, ClassifyResponse};
pub use router::Router;

/// A running serving system: router + one thread per fabricated die
/// (actives and hot standbys) + the fleet-health manager.
pub struct Coordinator {
    router: Router,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
    pub d: usize,
    /// Physical conversions each request costs on a die: 1 for physical
    /// serving, `RotationPlan::passes()` when the fleet serves virtual
    /// dims (DESIGN.md §13).
    pub passes: usize,
    fleet: Arc<Mutex<FleetManager>>,
    /// Background prober (only when `fleet.probe_period` is set).
    auto_probe: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
}

impl Coordinator {
    /// Fabricate `sys.n_chips + sys.standby_chips` dies, train each
    /// die's head on the given training set (per-die mismatch means
    /// per-die weights — exactly the chip-in-the-loop training of
    /// Section VI-C), enrol a fleet-health baseline per die, then start
    /// serving. Standby dies are fully trained but held out of rotation
    /// until a quarantine promotes them.
    ///
    /// When `sys.virtual_d` / `sys.virtual_l` exceed the fabricated
    /// dims, every die is wrapped in the Section V rotation plan
    /// (DESIGN.md §13): training, probing, recalibration and serving
    /// all flow through the virtual forward, and each request costs
    /// [`RotationPlan::passes`] physical conversions — priced into the
    /// router's load accounting and the batcher's conversion budget.
    pub fn start(
        sys: &SystemConfig,
        chip_cfg: &ChipConfig,
        train_x: &[Vec<f64>],
        train_y: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::new());
        let n_total = sys.n_chips + sys.standby_chips;
        // validate the virtual geometry once, before fabricating anything.
        // Virtual dims are *extensions* of the die: serving below the
        // fabricated dims would silently mask neurons (and disable the
        // PJRT fast path) when the right move is fabricating smaller dies
        let vd = sys.virtual_d.unwrap_or(chip_cfg.d);
        let vl = sys.virtual_l.unwrap_or(chip_cfg.l);
        anyhow::ensure!(
            vd >= chip_cfg.d && vl >= chip_cfg.l,
            "virtual dims {vd}x{vl} must extend the fabricated die {}x{}",
            chip_cfg.d,
            chip_cfg.l
        );
        let plan = RotationPlan::new(chip_cfg.d, chip_cfg.l, vd, vl)
            .map_err(|e| anyhow::anyhow!("virtual dims: {e}"))?;
        let passes = plan.passes();
        if let Some(x) = train_x.first() {
            anyhow::ensure!(
                x.len() == vd,
                "training set dimension {} != served dimension {vd}",
                x.len()
            );
        }
        let probe = Arc::new(ProbeSet::from_training(
            train_x,
            train_y,
            sys.fleet.probe_n,
            chip_cfg,
        ));
        let mut senders = Vec::new();
        let mut setups = Vec::new();
        let mut baselines = Vec::new();
        for i in 0..n_total {
            let seed = sys.seed + i as u64;
            let chip = ChipModel::fabricate(chip_cfg.clone(), seed);
            let die = ServeChip::new(chip, vd, vl)
                .map_err(|e| anyhow::anyhow!("wrapping die {i}: {e}"))?;
            // chip-in-the-loop training on this die, through the serving
            // plan (virtual dies train on their virtual projection)
            let mut hidden = ServeHidden { die, normalize: sys.normalize };
            let h = assemble_h(&mut hidden, train_x);
            let head = solve_head(&h, train_y, lambda)
                .map_err(|e| anyhow::anyhow!("training die {i}: {e}"))?;
            let second = SecondStage::new(&head.beta, beta_bits, sys.normalize);
            // fleet enrolment: baseline probe on the freshly trained die
            let mut die = hidden.die;
            baselines.push(crate::fleet::probe::run_probe(&mut die, &second, &probe));
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            setups.push((i, die, second, rx));
        }
        let state = FleetState::new(n_total, sys.n_chips);
        let router =
            Router::with_costs(senders.clone(), state.clone(), vec![passes; n_total]);
        let mut workers = Vec::new();
        for (i, die, second, rx) in setups {
            let setup = worker::WorkerSetup {
                index: i,
                die,
                second,
                artifact_dir: worker::usable_artifact_dir(sys),
                rx,
                metrics: Arc::clone(&metrics),
                outstanding: router.outstanding.clone(),
                max_batch: sys.max_batch,
                max_wait: sys.max_wait,
                pjrt_min_batch: sys.pjrt_min_batch,
                normalize: sys.normalize,
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("velm-worker-{i}"))
                    .spawn(move || worker::run(setup))
                    .context("spawning worker")?,
            );
        }
        let manager = FleetManager::new(FleetSetup {
            senders,
            state,
            outstanding: router.outstanding.clone(),
            metrics: Arc::clone(&metrics),
            cfg: sys.fleet.clone(),
            probe,
            baselines,
            refit_x: Arc::new(train_x.to_vec()),
            refit_y: Arc::new(train_y.to_vec()),
            lambda,
            beta_bits,
        });
        let fleet = Arc::new(Mutex::new(manager));
        let auto_probe = sys.fleet.probe_period.map(|period| {
            let stop = Arc::new(AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let fleet2 = Arc::clone(&fleet);
            let handle = std::thread::Builder::new()
                .name("velm-fleet-prober".into())
                .spawn(move || {
                    let slice = std::time::Duration::from_millis(5).min(period);
                    let mut since_tick = std::time::Duration::ZERO;
                    while !stop2.load(Ordering::Relaxed) {
                        std::thread::sleep(slice);
                        since_tick += slice;
                        if since_tick >= period {
                            fleet2.lock().unwrap().tick();
                            since_tick = std::time::Duration::ZERO;
                        }
                    }
                })
                .expect("spawning fleet prober");
            (stop, handle)
        });
        // the ensure above pinned train_x's width to vd, so vd IS the
        // dimension submit() must validate against
        Ok(Coordinator {
            router,
            metrics,
            next_id: AtomicU64::new(0),
            workers,
            d: vd,
            passes,
            fleet,
            auto_probe,
        })
    }

    /// Start serving at an autotuned [`OperatingPoint`]
    /// (`velm tune` / `dse::Explorer` output): the point fixes the chip
    /// config (sigma_VT, saturation ratio, counter bits, hidden width)
    /// via `ChipConfig::from_operating_point` and the dynamic batcher's
    /// max batch — the closed loop from Fig. 7's methodology to the
    /// serving fleet.
    ///
    /// [`OperatingPoint`]: crate::dse::OperatingPoint
    pub fn start_tuned(
        sys: &SystemConfig,
        op: &crate::dse::OperatingPoint,
        train_x: &[Vec<f64>],
        train_y: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Coordinator> {
        let d = train_x.first().map_or(1, |x| x.len());
        let chip_cfg = ChipConfig::from_operating_point(op, d);
        let mut sys = sys.clone();
        sys.max_batch = op.batch.max(1);
        Coordinator::start(&sys, &chip_cfg, train_x, train_y, lambda, beta_bits)
    }

    /// Submit one request; returns the receiver for its response.
    pub fn submit(&self, features: Vec<f64>) -> Result<mpsc::Receiver<ClassifyResponse>> {
        anyhow::ensure!(
            features.len() == self.d,
            "expected {} features, got {}",
            self.d,
            features.len()
        );
        let (tx, rx) = mpsc::channel();
        let req = ClassifyRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            features,
            submitted: Instant::now(),
            reply: tx,
        };
        self.metrics.record_request();
        self.router
            .route(req)
            .map_err(|e| anyhow::anyhow!("routing: {e}"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn classify(&self, features: Vec<f64>) -> Result<ClassifyResponse> {
        let rx = self.submit(features)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn n_workers(&self) -> usize {
        self.router.n_workers()
    }

    // --- fleet-health surface (DESIGN.md §12) ---

    /// Run one probe/recovery pass over the fleet (tests, CLI; the
    /// background prober calls this on its own when a cadence is set).
    pub fn fleet_tick(&self) {
        self.fleet.lock().unwrap().tick();
    }

    /// One-line fleet status: per-die lifecycle gauges + recovery
    /// counters (the TCP `HEALTH` command). Reads only shared atomics —
    /// no manager lock — so it stays responsive while a tick is blocked
    /// on a slow worker reply.
    pub fn fleet_status(&self) -> String {
        crate::fleet::lifecycle::status_line(&self.router.health, &self.metrics)
    }

    /// The fleet manager's bounded human-readable event log.
    pub fn fleet_log(&self) -> Vec<String> {
        self.fleet.lock().unwrap().log().to_vec()
    }

    /// Per-die lifecycle snapshot (lock-free, see `fleet_status`).
    pub fn health_snapshot(&self) -> Vec<DieState> {
        self.router.health.snapshot()
    }

    /// Operator drain (the TCP `DRAIN <die>` command): pull a die from
    /// rotation; subsequent ticks recalibrate and re-admit it.
    pub fn drain_die(&self, die: usize) -> Result<()> {
        self.fleet
            .lock()
            .unwrap()
            .drain(die)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Install a drift-injection schedule (replayed by subsequent ticks).
    pub fn set_drift_schedule(&self, schedule: DriftSchedule) {
        self.fleet.lock().unwrap().set_schedule(schedule);
    }

    /// Immediately inject a drift event (Fig. 17/18-style perturbation)
    /// into one die or the whole fleet.
    pub fn inject_drift(
        &self,
        die: Option<usize>,
        vdd: Option<f64>,
        temp_k: Option<f64>,
        age_sigma_vt: Option<f64>,
    ) {
        self.fleet.lock().unwrap().inject(die, vdd, temp_k, age_sigma_vt);
    }

    /// Graceful shutdown: stop the prober, close the queues and join
    /// the worker threads.
    pub fn shutdown(self) {
        let Coordinator { router, workers, fleet, auto_probe, .. } = self;
        if let Some((stop, handle)) = auto_probe {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        drop(router); // drops the router's senders
        drop(fleet); // drops the manager's senders -> workers drain and exit
        for w in workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transfer;
    use crate::util::prng::Prng;

    fn tiny_system() -> (SystemConfig, ChipConfig, Vec<Vec<f64>>, Vec<f64>) {
        let sys = SystemConfig {
            n_chips: 2,
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(1),
            artifact_dir: "/nonexistent".into(), // force chip-sim path
            pjrt_min_batch: 4,
            seed: 99,
            normalize: false,
            standby_chips: 0,
            virtual_d: None,
            virtual_l: None,
            fleet: Default::default(),
        };
        let chip = ChipConfig::default()
            .with_dims(6, 24)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let mut rng = Prng::new(5);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..120 {
            let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
            xs.push((0..6).map(|_| (0.4 * y + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0)).collect());
            ys.push(y);
        }
        (sys, chip, xs, ys)
    }

    #[test]
    fn end_to_end_classify_over_threads() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.n_workers(), 2);
        let mut correct = 0;
        for (x, &y) in xs.iter().take(60).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
            assert_eq!(resp.backend, Backend::ChipSim);
        }
        assert!(correct >= 50, "only {correct}/60 correct");
        assert!(coord.metrics.responses.load(Ordering::Relaxed) >= 60);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        let rxs: Vec<_> = (0..40)
            .map(|i| coord.submit(xs[i % xs.len()].clone()).unwrap())
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "lost or duplicated responses");
        coord.shutdown();
    }

    #[test]
    fn start_tuned_applies_operating_point() {
        let (sys, _, xs, ys) = tiny_system();
        let op = crate::dse::OperatingPoint {
            sigma_vt: 0.016,
            ratio: 0.75,
            b: 10,
            l: 24,
            batch: 4,
        };
        let coord = Coordinator::start_tuned(&sys, &op, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.d, 6); // input dim follows the workload
        let mut correct = 0;
        for (x, &y) in xs.iter().take(40).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 correct at tuned point");
        coord.shutdown();
    }

    #[test]
    fn rejects_wrong_dimension() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert!(coord.submit(vec![0.0; 3]).is_err());
        coord.shutdown();
    }

    #[test]
    fn virtual_fleet_serves_and_prices_passes() {
        // 2 dies fabricated at 3x8 serving the d=6, L=24 projection:
        // every response costs hidden_blocks x input_chunks = 6 passes
        let (mut sys, _, xs, ys) = tiny_system();
        sys.virtual_d = Some(6);
        sys.virtual_l = Some(24);
        let chip = ChipConfig::default()
            .with_dims(3, 8)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.d, 6);
        assert_eq!(coord.passes, 6);
        let mut correct = 0;
        for (x, &y) in xs.iter().take(40).zip(&ys) {
            let resp = coord.classify(x.clone()).unwrap();
            assert_eq!(resp.backend, Backend::ChipSim);
            assert_eq!(resp.passes, 6);
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 correct on the virtual fleet");
        // the metrics ledger books exactly passes() conversions/request
        let responses = coord.metrics.responses.load(Ordering::Relaxed);
        assert_eq!(
            coord.metrics.conversions.load(Ordering::Relaxed),
            responses * 6
        );
        coord.shutdown();
    }

    #[test]
    fn virtual_fleet_survives_probe_ticks_and_recovers_health() {
        let (mut sys, _, xs, ys) = tiny_system();
        sys.virtual_d = Some(6);
        sys.virtual_l = Some(24);
        let chip = ChipConfig::default()
            .with_dims(3, 8)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        for _ in 0..3 {
            coord.fleet_tick();
        }
        assert!(
            coord.health_snapshot().iter().all(|&s| s == DieState::Healthy),
            "{}",
            coord.fleet_status()
        );
        assert!(coord.metrics.probes.load(Ordering::Relaxed) >= 6);
        // the refit path flows through the virtual forward: drain a die
        // and let the state machine walk it back to Healthy
        coord.drain_die(0).unwrap();
        coord.fleet_tick();
        coord.fleet_tick();
        assert_eq!(
            coord.health_snapshot()[0],
            DieState::Healthy,
            "virtual die not re-admitted: {}\n{}",
            coord.fleet_status(),
            coord.fleet_log().join("\n")
        );
        assert!(coord.metrics.refits.load(Ordering::Relaxed) >= 1);
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        coord.shutdown();
    }

    #[test]
    fn invalid_virtual_dims_fail_fast() {
        let (mut sys, chip, xs, ys) = tiny_system();
        // chip is 6x24: d beyond k*N cannot be served by rotation
        sys.virtual_d = Some(6 * 24 + 1);
        assert!(Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).is_err());
        // training set dimension must match the served dimension
        let mut sys2 = tiny_system().0;
        sys2.virtual_d = Some(12);
        assert!(Coordinator::start(&sys2, &chip, &xs, &ys, 1e-2, 10).is_err());
        // virtual dims below the fabricated die would silently mask
        // neurons — refuse instead of serving a crippled projection
        let mut sys3 = tiny_system().0;
        sys3.virtual_l = Some(12); // chip is 6x24
        assert!(Coordinator::start(&sys3, &chip, &xs, &ys, 1e-2, 10).is_err());
    }

    #[test]
    fn stable_fleet_ticks_keep_dies_healthy_and_serving() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        for _ in 0..3 {
            coord.fleet_tick();
        }
        assert!(
            coord.health_snapshot().iter().all(|&s| s == DieState::Healthy),
            "{}",
            coord.fleet_status()
        );
        assert!(coord.metrics.probes.load(Ordering::Relaxed) >= 6);
        assert_eq!(coord.metrics.renorms.load(Ordering::Relaxed), 0);
        let resp = coord.classify(xs[0].clone()).unwrap();
        assert!(resp.label == 1 || resp.label == -1);
        let status = coord.fleet_status();
        assert!(status.contains("die0=Healthy"), "{status}");
        coord.shutdown();
    }

    #[test]
    fn standby_dies_are_trained_but_not_routed() {
        let (mut sys, chip, xs, ys) = tiny_system();
        sys.standby_chips = 1;
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        assert_eq!(coord.n_workers(), 3);
        assert_eq!(coord.health_snapshot()[2], DieState::Standby);
        for i in 0..30 {
            let resp = coord.classify(xs[i % xs.len()].clone()).unwrap();
            assert_ne!(resp.worker, 2, "standby die must not serve traffic");
        }
        coord.shutdown();
    }

    #[test]
    fn operator_drain_recalibrates_and_readmits() {
        let (sys, chip, xs, ys) = tiny_system();
        let coord = Coordinator::start(&sys, &chip, &xs, &ys, 1e-2, 10).unwrap();
        coord.drain_die(0).unwrap();
        assert_eq!(coord.health_snapshot()[0], DieState::Draining);
        // draining dies cannot be drained again
        assert!(coord.drain_die(0).is_err());
        assert!(coord.drain_die(99).is_err());
        // traffic keeps flowing on die 1 while die 0 is out
        for i in 0..10 {
            let resp = coord.classify(xs[i].clone()).unwrap();
            assert_eq!(resp.worker, 1);
        }
        // tick 1: drained (no outstanding) -> Recalibrating;
        // tick 2: refit -> Healthy again
        coord.fleet_tick();
        coord.fleet_tick();
        let snap = coord.health_snapshot();
        assert_eq!(snap[0], DieState::Healthy, "{}", coord.fleet_status());
        assert!(coord.metrics.refits.load(Ordering::Relaxed) >= 1);
        // and it serves traffic again
        let mut hit0 = false;
        for i in 0..20 {
            let resp = coord.classify(xs[i].clone()).unwrap();
            hit0 |= resp.worker == 0;
        }
        assert!(hit0, "re-admitted die should see traffic");
        coord.shutdown();
    }
}
