//! Chip worker: one thread owning one fabricated die, its trained head
//! and (optionally) a PJRT engine. Batches arrive from the router via
//! the dynamic batcher; the hidden layer runs on the batched AOT
//! artifact when the batch is large enough, else on the scalar chip
//! simulator; the fixed-point second stage produces the score.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::chip::{dac, ChipModel};
use crate::config::SystemConfig;
use crate::elm::secondstage::{codes_sum, SecondStage};
use crate::runtime::PjrtEngine;

use super::batcher::collect_batch;
use super::metrics::Metrics;
use super::request::{Backend, ClassifyRequest, ClassifyResponse};
use super::router::Outstanding;

/// Everything one worker needs, bundled for the spawn.
pub struct WorkerSetup {
    pub index: usize,
    pub chip: ChipModel,
    pub second: SecondStage,
    /// Artifact directory; the engine itself is created *inside* the
    /// worker thread (PJRT handles are not `Send`).
    pub artifact_dir: Option<String>,
    pub rx: Receiver<ClassifyRequest>,
    pub metrics: Arc<Metrics>,
    pub outstanding: Outstanding,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub pjrt_min_batch: usize,
    pub normalize: bool,
}

/// Worker main loop; returns when the request channel closes.
pub fn run(mut s: WorkerSetup) {
    // PJRT engine lives entirely on this thread (handles are not Send)
    let mut engine: Option<PjrtEngine> = s.artifact_dir.as_deref().and_then(open_engine);
    // weight matrix for the PJRT path, frozen at spawn temperature
    let w_f32: Vec<f32> = s.chip.weights().to_f32();
    let d = s.chip.cfg.d;
    let l = s.chip.cfg.l;
    while let Some(batch) = collect_batch(&s.rx, s.max_batch, s.max_wait) {
        let n = batch.len();
        let use_pjrt = engine.is_some() && n >= s.pjrt_min_batch;
        s.metrics.record_batch(n, use_pjrt);
        // DAC quantisation happens once, shared by both paths
        let codes: Vec<Vec<u16>> = batch
            .iter()
            .map(|r| dac::features_to_codes(&r.features, &s.chip.cfg))
            .collect();
        let hidden: Vec<Vec<u32>> = if use_pjrt {
            let engine = engine.as_mut().unwrap();
            let flat: Vec<f32> = codes
                .iter()
                .flat_map(|c| c.iter().map(|&v| v as f32))
                .collect();
            match engine.hidden(&flat, n, d, l, &w_f32, false) {
                Ok(out) => out
                    .chunks(l)
                    .map(|row| row.iter().map(|&v| v.max(0.0) as u32).collect())
                    .collect(),
                Err(e) => {
                    // artifact trouble: fall back to the simulator
                    eprintln!("worker {}: pjrt failed ({e:#}); falling back", s.index);
                    codes.iter().map(|c| s.chip.forward(c)).collect()
                }
            }
        } else {
            codes.iter().map(|c| s.chip.forward(c)).collect()
        };
        let backend = if use_pjrt { Backend::Pjrt } else { Backend::ChipSim };
        for ((req, code), h) in batch.iter().zip(&codes).zip(&hidden) {
            let score = s.second.score(h, codes_sum(code));
            let resp = ClassifyResponse {
                id: req.id,
                score,
                label: if score >= 0.0 { 1 } else { -1 },
                worker: s.index,
                backend,
                latency: req.submitted.elapsed(),
            };
            s.metrics.record_response(resp.latency);
            s.outstanding.dec(s.index);
            // receiver may have hung up; that's the client's business
            let _ = req.reply.send(resp);
        }
    }
}

/// Open the PJRT engine for a directory, logging (not failing) on error.
fn open_engine(dir: &str) -> Option<PjrtEngine> {
    let path = std::path::Path::new(dir);
    if !crate::runtime::artifacts_available(path) {
        return None;
    }
    match PjrtEngine::new(path) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("pjrt engine unavailable ({err:#}); serving via chip sim");
            None
        }
    }
}

/// Artifact dir to pass into a worker, if it looks usable.
pub fn usable_artifact_dir(sys: &SystemConfig) -> Option<String> {
    let dir = std::path::Path::new(&sys.artifact_dir);
    if crate::runtime::artifacts_available(dir) {
        Some(sys.artifact_dir.clone())
    } else {
        None
    }
}
