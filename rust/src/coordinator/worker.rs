//! Chip worker: one thread owning one fabricated die (physical, or
//! wrapped in the Section V rotation plan when the fleet serves virtual
//! dims — DESIGN.md §13), its trained default head, its tenant table
//! (DESIGN.md §14) and (optionally) a PJRT engine. Batches arrive from
//! the router via the dynamic batcher; the hidden layer runs on the
//! batched AOT artifact when the batch is large enough (physical dies
//! only — the artifact's shape is the fabricated array), else on the
//! scalar chip simulator through the serving plan. The hidden
//! computation is tenant-agnostic, so one pass covers every tenant's
//! rows in the batch; each row is then scored by its own tenant's
//! fixed-point head, resolved from the thread-owned tenant table — no
//! lock on the serve path. Fleet-health and registry control messages
//! (probe / drift injection / renormalise / refit / register /
//! unregister / OS-ELM update) ride the same channel and execute here,
//! because this thread owns the die.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::chip::dac;
use crate::config::SystemConfig;
use crate::elm::secondstage::{codes_sum, SecondStage};
use crate::extension::ServeChip;
use crate::fleet::{calibrate, probe};
use crate::protocol::stats::{Segment, TraceEntry, TraceOutcome};
use crate::registry::TenantEntry;
use crate::runtime::PjrtEngine;

use super::batcher::collect_batch;
use super::metrics::Metrics;
use super::request::{Backend, ClassifyRequest, ClassifyResponse, ControlMsg, WorkerMsg};
use super::router::Outstanding;
use super::timeline::Stamper;

/// Everything one worker needs, bundled for the spawn.
pub struct WorkerSetup {
    pub index: usize,
    pub die: ServeChip,
    /// The boot ("default") head — also the head fleet probes score.
    pub second: SecondStage,
    /// Registered tenants' per-die heads, owned by this thread and
    /// updated only through control messages — the lock-free registry
    /// snapshot the serve path reads (DESIGN.md §14).
    pub tenants: BTreeMap<String, TenantEntry>,
    /// Artifact directory; the engine itself is created *inside* the
    /// worker thread (PJRT handles are not `Send`).
    pub artifact_dir: Option<String>,
    pub rx: Receiver<WorkerMsg>,
    pub metrics: Arc<Metrics>,
    /// This worker's segment clock over the fleet timeline (DESIGN.md
    /// §19): consecutive marks tile the thread's wall clock into idle /
    /// batch-wait / convert / rotation-pass / transfer / control /
    /// probe-refit, so the exported occupancy fractions sum to 1.0 by
    /// construction.
    pub stamper: Stamper,
    pub outstanding: Outstanding,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub pjrt_min_batch: usize,
    /// Consecutive engine failures after which the engine is dropped
    /// for good — stop paying the flatten+attempt cost on every batch.
    pub pjrt_max_failures: u32,
    pub normalize: bool,
    /// Modelled energy of one physical conversion on THIS die at its
    /// operating point (`chip::energy::conversion_price_fj`), in
    /// femtojoules — the worker prices every booked conversion with it
    /// so the fleet ledger is `sum(conversions_i * price_i)` exactly
    /// (DESIGN.md §16). A governor retune re-prices it live.
    pub energy_fj_per_conversion: u64,
    /// The spawn-time (boot operating point) price. While the governor
    /// holds the die on a cheaper rung, every booked conversion also
    /// books `baseline - current` fJ into the governor's saved-energy
    /// ledger — the same integer arithmetic as the energy ledger, so
    /// the saving is exact, not estimated (DESIGN.md §17).
    pub baseline_fj_per_conversion: u64,
}

/// Once-per-worker log latches + the engine failure streak: a hot
/// serving loop must not flood stderr at batch or request rate, so each
/// condition warns on its first occurrence only.
#[derive(Default)]
pub(crate) struct LogOnce {
    /// PJRT engine failed and the batch fell back to the simulator.
    pub pjrt_fallback: bool,
    /// A malformed request was dropped instead of answered.
    pub dropped_request: bool,
    /// A request named a tenant this die has no head for.
    pub unknown_tenant: bool,
    /// Consecutive engine failures (reset by any successful PJRT
    /// batch); at `pjrt_max_failures` the engine is dropped entirely.
    pub pjrt_fail_streak: u32,
}

/// The batched hidden-layer engine as the worker drives it. `PjrtEngine`
/// is the production implementation; the seam exists so the fallback
/// path (engine present but failing) is testable without artifacts.
pub(crate) trait BatchEngine {
    #[allow(clippy::too_many_arguments)]
    fn hidden(
        &mut self,
        flat: &[f32],
        n: usize,
        d: usize,
        l: usize,
        weights: &[f32],
        normalized: bool,
    ) -> anyhow::Result<Vec<f32>>;
}

impl BatchEngine for PjrtEngine {
    fn hidden(
        &mut self,
        flat: &[f32],
        n: usize,
        d: usize,
        l: usize,
        weights: &[f32],
        normalized: bool,
    ) -> anyhow::Result<Vec<f32>> {
        PjrtEngine::hidden(self, flat, n, d, l, weights, normalized)
    }
}

/// Worker main loop; returns when the request channel closes.
pub fn run(mut s: WorkerSetup) {
    // PJRT engine lives entirely on this thread (handles are not Send).
    // Only a physical die can use it: the AOT artifact is compiled at
    // the fabricated k x N shape, which a rotation plan outgrows.
    let mut engine: Option<PjrtEngine> = if s.die.is_physical() {
        s.artifact_dir.as_deref().and_then(open_engine)
    } else {
        None
    };
    // weight matrix for the PJRT path, frozen at spawn conditions
    let w_f32: Vec<f32> = if engine.is_some() {
        s.die.chip_mut().weights().to_f32()
    } else {
        Vec::new()
    };
    // The AOT artifact bakes the nominal corner (spawn-time weights,
    // fabricated T_neu, nominal VDD). Once drift injection or a
    // renormalisation changes the die underneath it, the artifact no
    // longer matches the chip's physics — scoring small batches on the
    // sim and large ones on a stale artifact would split one die into
    // two inconsistent classifiers. So the first such control message
    // pins this die to the simulator for good.
    let mut artifact_stale = false;
    let mut logs = LogOnce::default();
    let passes = s.die.passes();
    // rows fair admission parked for the next window (batcher carry);
    // still served after the channel closes — collect_batch only
    // returns None once both the channel and the carry are drained
    let mut carry = VecDeque::new();
    while let Some(batch) = collect_batch(&s.rx, &mut carry, s.max_batch, s.max_wait, passes) {
        // timeline (DESIGN.md §19): the collect span splits at the
        // first row's batcher stamp — idle until a message arrived,
        // batch-wait while the window filled. A control-only tick is
        // all idle: the wait ended the moment there was work to do.
        match batch.requests.iter().filter_map(|r| r.collected).min() {
            Some(first) => {
                s.stamper.mark_until(Segment::Idle, first, None);
                s.stamper.mark(Segment::BatchWait, batch.requests.first().map(|r| r.id));
            }
            None => {
                s.stamper.mark(Segment::Idle, None);
            }
        }
        if !batch.requests.is_empty() {
            serve_batch(
                &mut s,
                &mut engine,
                &mut logs,
                &w_f32,
                &batch.requests,
                artifact_stale,
            );
        }
        for ctl in batch.control {
            let seg = match &ctl {
                ControlMsg::Probe { .. } | ControlMsg::Refit { .. } => Segment::ProbeRefit,
                _ => Segment::Control,
            };
            handle_control(&mut s, &mut artifact_stale, ctl);
            s.stamper.mark(seg, None);
        }
    }
}

/// Serve one classify batch through PJRT or the chip simulator. The
/// response `backend` and the batch metrics reflect the path that
/// *actually* served — when the engine errors mid-batch the batch falls
/// back to the simulator and is labelled and counted as `ChipSim`.
/// After `pjrt_max_failures` consecutive engine errors the engine is
/// dropped entirely, so subsequent batches skip the flatten+attempt
/// cost and go straight to the simulator.
pub(crate) fn serve_batch<E: BatchEngine>(
    s: &mut WorkerSetup,
    engine: &mut Option<E>,
    logs: &mut LogOnce,
    w_f32: &[f32],
    requests: &[ClassifyRequest],
    artifact_stale: bool,
) {
    // Stage boundary (DESIGN.md §16): batch-wait ends — and compute
    // begins — when the collected batch reaches the engine dispatch.
    let compute_start = Instant::now();
    let n = requests.len();
    let d = s.die.input_dim();
    let l = s.die.hidden_dim();
    let cap = s.die.chip().cfg.cap();
    // a malformed request must never reach the engine: the flattened
    // PJRT input assumes n x d, and a wrong-length row would shift every
    // row after it (the engine asserts on the total length). Send such
    // batches through the sim path, which Errs per request instead.
    let all_well_formed = requests.iter().all(|r| r.features.len() == d);
    let want_pjrt = engine.is_some()
        && s.die.is_physical()
        && !artifact_stale
        && all_well_formed
        && n >= s.pjrt_min_batch;
    // DAC quantisation happens once, shared by both paths
    let codes: Vec<Vec<u16>> = requests
        .iter()
        .map(|r| dac::features_to_codes(&r.features, &s.die.chip().cfg))
        .collect();
    let conversions_before = s.die.chip().ledger.conversions;
    let mut served_pjrt = false;
    let mut engine_failed = false;
    let hidden: Vec<Result<Vec<u32>, String>> = if want_pjrt {
        let eng = engine.as_mut().unwrap();
        let flat: Vec<f32> = codes
            .iter()
            .flat_map(|c| c.iter().map(|&v| v as f32))
            .collect();
        match eng.hidden(&flat, n, d, l, w_f32, false) {
            Ok(out) => {
                served_pjrt = true;
                out.chunks(l)
                    .map(|row| {
                        // clamp to the counter saturation value: the sim
                        // path saturates at 2^b (counter::count_window),
                        // so a hot artifact output must not exceed it
                        Ok(row
                            .iter()
                            .map(|&v| (v.max(0.0) as u32).min(cap))
                            .collect())
                    })
                    .collect()
            }
            Err(e) => {
                // artifact trouble: fall back to the simulator
                engine_failed = true;
                if !logs.pjrt_fallback {
                    eprintln!(
                        "worker {}: pjrt failed ({e:#}); falling back to chip sim",
                        s.index
                    );
                    logs.pjrt_fallback = true;
                }
                codes.iter().map(|c| s.die.forward(c)).collect()
            }
        }
    } else {
        codes.iter().map(|c| s.die.forward(c)).collect()
    };
    // engine hardening: a streak of failures means the artifact is not
    // coming back — drop the engine instead of re-attempting per batch
    if engine_failed {
        logs.pjrt_fail_streak += 1;
        if logs.pjrt_fail_streak >= s.pjrt_max_failures.max(1) {
            *engine = None;
            eprintln!(
                "worker {}: dropping pjrt engine after {} consecutive failures; \
                 serving via chip sim from here on",
                s.index, logs.pjrt_fail_streak
            );
        }
    } else if served_pjrt {
        logs.pjrt_fail_streak = 0;
    }
    // timeline (DESIGN.md §19): DAC quantisation + the hidden-layer
    // pass is the conversion span; a rotation-plan die labels it
    // rotation-pass (several physical passes per row). The first row's
    // id carries the Chrome flow linkage batch-wait -> conversion.
    let conv_seg =
        if s.die.passes() > 1 { Segment::RotationPass } else { Segment::Convert };
    s.stamper.mark(conv_seg, requests.first().map(|r| r.id));
    // count the batch on the path that served it, after any fallback
    s.metrics.record_batch(n, served_pjrt);
    // book physical conversions before any reply goes out (a client must
    // never observe its response ahead of the conversions it cost): the
    // ledger delta for sim conversions — all forwards above are done —
    // or one per request for the artifact path, which bypasses the ledger
    let booked = if served_pjrt {
        n as u64
    } else {
        s.die.chip().ledger.conversions - conversions_before
    };
    s.metrics.record_conversions(booked);
    // energy ledger (DESIGN.md §16): price the booked conversions at
    // this die's operating point; each physical conversion performs
    // d x L MACs on the fabricated array
    let phys_macs = (s.die.chip().cfg.d * s.die.chip().cfg.l) as u64;
    s.metrics.record_energy(
        booked * s.energy_fj_per_conversion,
        booked * phys_macs,
    );
    // governor saved-energy ledger (DESIGN.md §17): while the die sits
    // on a rung cheaper than its boot point, the saving per conversion
    // is exactly the integer price difference
    if s.energy_fj_per_conversion < s.baseline_fj_per_conversion {
        s.metrics.record_gov_fj_saved(
            booked * (s.baseline_fj_per_conversion - s.energy_fj_per_conversion),
        );
    }
    let backend = if served_pjrt { Backend::Pjrt } else { Backend::ChipSim };
    let passes = s.die.passes();
    // training scaled H by 1/2^b, so tenant scores are rescaled into
    // training units (sign/argmax-invariant; regression needs it)
    let scale = 1.0 / cap as f64;
    // span math (DESIGN.md §16): queue / batch-wait / compute partition
    // the end-to-end span exactly in Duration arithmetic — only the
    // per-stage flooring to whole micros makes the exported sum
    // undershoot the exported total (by < 3 us). Saturating everywhere:
    // a request that bypassed the batcher (collected = None) reads as
    // zero queue-wait, never as a panic.
    // per-tenant utilization share (DESIGN.md §19): the batch's compute
    // span so far splits evenly across its rows — rows on one die are
    // homogeneous (same dims, same pass cost). Clamped to 1 us so even
    // a sub-microsecond batch books a visible share.
    let row_busy_us =
        ((compute_start.elapsed().as_micros() as u64) / n.max(1) as u64).max(1);
    let stage_spans = |req: &ClassifyRequest| {
        let now = Instant::now();
        let collected = req.collected.unwrap_or(compute_start);
        (
            collected.saturating_duration_since(req.submitted),
            compute_start.saturating_duration_since(collected),
            now.saturating_duration_since(compute_start),
            now.saturating_duration_since(req.submitted),
        )
    };
    for ((req, code), h) in requests.iter().zip(&codes).zip(&hidden) {
        let mut trace = TraceEntry {
            id: req.id,
            tenant: req.tenant.as_ref().map(|t| t.name.as_ref().to_string()),
            die: s.index as u32,
            pjrt: served_pjrt,
            passes: passes as u32,
            queue_us: 0,
            batch_us: 0,
            compute_us: 0,
            total_us: 0,
            outcome: TraceOutcome::Ok,
        };
        match h {
            Ok(h) => {
                let cs = codes_sum(code);
                // resolve this row's head: the default head, or the
                // tenant's entry from the thread-owned table
                let outcome: Option<(i8, f64)> = match &req.tenant {
                    None => {
                        let score = s.second.score(h, cs);
                        Some((if score >= 0.0 { 1 } else { -1 }, score))
                    }
                    Some(tag) => s
                        .tenants
                        .get(tag.name.as_ref())
                        .map(|entry| entry.score_row(h, cs, scale)),
                };
                match outcome {
                    Some((label, score)) => {
                        let (queue_d, batch_d, compute_d, total_d) = stage_spans(req);
                        let resp = ClassifyResponse {
                            id: req.id,
                            score,
                            label,
                            tenant: req.tenant.as_ref().map(|t| Arc::clone(&t.name)),
                            worker: s.index,
                            backend,
                            passes,
                            latency: total_d,
                        };
                        s.metrics.record_response(total_d);
                        s.metrics.record_stages(queue_d, batch_d, compute_d);
                        if let Some(tag) = &req.tenant {
                            tag.metrics.record_response(total_d);
                            // per-tenant energy share: this row cost
                            // `passes` physical conversions on this die
                            tag.metrics
                                .record_energy(passes as u64 * s.energy_fj_per_conversion);
                            tag.metrics.record_busy_us(row_busy_us);
                        }
                        trace.queue_us = queue_d.as_micros() as u64;
                        trace.batch_us = batch_d.as_micros() as u64;
                        trace.compute_us = compute_d.as_micros() as u64;
                        trace.total_us = total_d.as_micros() as u64;
                        s.metrics.trace.push(trace);
                        s.outstanding.dec(s.index);
                        // receiver may have hung up; that's the client's business
                        let _ = req.reply.send(resp);
                    }
                    None => {
                        // tenant unknown on this die (an unregister
                        // raced the request): drop the reply, keep the
                        // ledger balanced, warn once
                        if !logs.unknown_tenant {
                            let name = req
                                .tenant
                                .as_ref()
                                .map(|t| t.name.as_ref().to_string())
                                .unwrap_or_default();
                            eprintln!(
                                "worker {}: dropping request {} for unknown tenant \
                                 '{name}'; further drops are silent",
                                s.index, req.id
                            );
                            logs.unknown_tenant = true;
                        }
                        let (queue_d, batch_d, compute_d, total_d) = stage_spans(req);
                        trace.queue_us = queue_d.as_micros() as u64;
                        trace.batch_us = batch_d.as_micros() as u64;
                        trace.compute_us = compute_d.as_micros() as u64;
                        trace.total_us = total_d.as_micros() as u64;
                        trace.outcome = TraceOutcome::DroppedUnknownTenant;
                        s.metrics.trace.push(trace);
                        s.outstanding.dec(s.index);
                    }
                }
            }
            Err(e) => {
                // a malformed request must not kill the thread that owns
                // the die: drop the reply (the client's recv fails) but
                // keep the outstanding ledger balanced so drains finish.
                // Warn once per worker — a misbehaving client would
                // otherwise flood stderr at request rate.
                if !logs.dropped_request {
                    eprintln!(
                        "worker {}: dropping malformed request {} ({e}); \
                         further drops are silent",
                        s.index, req.id
                    );
                    logs.dropped_request = true;
                }
                let (queue_d, batch_d, compute_d, total_d) = stage_spans(req);
                trace.queue_us = queue_d.as_micros() as u64;
                trace.batch_us = batch_d.as_micros() as u64;
                trace.compute_us = compute_d.as_micros() as u64;
                trace.total_us = total_d.as_micros() as u64;
                trace.outcome = TraceOutcome::DroppedMalformed;
                s.metrics.trace.push(trace);
                s.outstanding.dec(s.index);
            }
        }
    }
    // scoring + reply fan-out closes the batch as the transfer span
    s.stamper.mark(Segment::Transfer, requests.first().map(|r| r.id));
}

/// Execute one fleet-health or registry control message on the die this
/// thread owns.
fn handle_control(s: &mut WorkerSetup, artifact_stale: &mut bool, ctl: ControlMsg) {
    match ctl {
        ControlMsg::Probe { probe: set, reply } => {
            // tenant-aware pass: the default head AND every registered
            // tenant's deployed heads are scored, so a harder task
            // degrading first raises worst_err for the drift detector
            let rep =
                probe::run_probe_all(&mut s.die, &s.second, &s.tenants, s.normalize, &set);
            let _ = reply.send(rep);
        }
        ControlMsg::SetEnv { vdd, temp_k, age_sigma_vt, seed } => {
            let chip = s.die.chip_mut();
            if let Some(v) = vdd {
                chip.set_vdd(v);
            }
            if let Some(t) = temp_k {
                chip.set_temp(t);
            }
            if let Some(sigma) = age_sigma_vt {
                chip.age_mismatch(sigma, seed);
            }
            *artifact_stale = true; // the artifact's corner is gone
        }
        ControlMsg::Renormalize { gain, reply } => {
            let t_neu = calibrate::renormalize(s.die.chip_mut(), gain);
            *artifact_stale = true; // artifact counts keep the old T_neu
            let _ = reply.send(t_neu);
        }
        ControlMsg::Refit { xs, ys, lambda, beta_bits, probe: set, reply } => {
            // tenant-aware recovery (DESIGN.md §14): the default head
            // re-solves first, then every registered tenant's heads
            // re-solve chip-in-the-loop against the same drifted die —
            // a refit must never leave some models on stale weights
            let res = calibrate::refit_head(&mut s.die, s.normalize, &xs, &ys, lambda, beta_bits)
                .and_then(|second| {
                    s.second = second;
                    let scores =
                        calibrate::refit_tenants(&mut s.die, s.normalize, &mut s.tenants)?;
                    Ok((probe::run_probe(&mut s.die, &s.second, &set), scores))
                });
            // the refit heads were solved against the *current* (drifted)
            // die, which the frozen artifact does not model
            *artifact_stale = true;
            let _ = reply.send(res);
        }
        ControlMsg::Register { spec, reply } => {
            // chip-in-the-loop tenant training: one shared H on this
            // die, every head of the tenant from one Cholesky
            let res = crate::registry::fit_on_die(&mut s.die, s.normalize, &spec).map(
                |(entry, score)| {
                    s.tenants.insert(spec.name.clone(), entry);
                    score
                },
            );
            let _ = reply.send(res);
        }
        ControlMsg::Unregister { tenant, reply } => {
            let _ = reply.send(s.tenants.remove(tenant.as_ref()).is_some());
        }
        ControlMsg::OnlineUpdate { tenant, x, targets, reply } => {
            let res = match s.tenants.get_mut(tenant.as_ref()) {
                None => Err(format!("no tenant {tenant} on die {}", s.index)),
                Some(entry) => s
                    .die
                    .assemble_row(&x, s.normalize)
                    .and_then(|row| entry.absorb(&row, &targets)),
            };
            let _ = reply.send(res);
        }
        ControlMsg::Retune { b, reply } => {
            // governor actuation (DESIGN.md §17): reprogram the counter
            // MSB and scale the counting window by the cap ratio, so the
            // eq. 19 relation `count == 2^b at I_sat^z` holds at the new
            // bits — the die's transfer shape is preserved, only its
            // resolution (and hence conversion energy) changes.
            let chip = s.die.chip_mut();
            let old_cap = chip.cfg.cap() as f64;
            chip.cfg.b = b.clamp(1, 31);
            let new_price = {
                chip.t_neu_set *= chip.cfg.cap() as f64 / old_cap;
                crate::chip::energy::conversion_price_fj(&chip.cfg)
            };
            s.energy_fj_per_conversion = new_price;
            // the AOT artifact was compiled at the boot cap; a retuned
            // die must serve from the simulator until re-deployed
            *artifact_stale = true;
            let _ = reply.send(new_price);
        }
    }
}

/// Open the PJRT engine for a directory, logging (not failing) on error.
fn open_engine(dir: &str) -> Option<PjrtEngine> {
    let path = std::path::Path::new(dir);
    if !crate::runtime::artifacts_available(path) {
        return None;
    }
    match PjrtEngine::new(path) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("pjrt engine unavailable ({err:#}); serving via chip sim");
            None
        }
    }
}

/// Artifact dir to pass into a worker, if it looks usable.
pub fn usable_artifact_dir(sys: &SystemConfig) -> Option<String> {
    let dir = std::path::Path::new(&sys.artifact_dir);
    if crate::runtime::artifacts_available(dir) {
        Some(sys.artifact_dir.clone())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipModel;
    use crate::config::ChipConfig;
    use crate::coordinator::metrics::TenantMetrics;
    use crate::coordinator::request::TenantTag;
    use crate::registry::{fit_on_die, TenantSpec};
    use crate::sync::Ordering;
    use std::sync::mpsc;
    use std::time::Instant;

    const D: usize = 4;
    const L: usize = 8;

    /// Engine that always errors — the broken-artifact scenario.
    struct FailEngine;
    impl BatchEngine for FailEngine {
        fn hidden(
            &mut self,
            _flat: &[f32],
            _n: usize,
            _d: usize,
            _l: usize,
            _w: &[f32],
            _norm: bool,
        ) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("artifact corrupted")
        }
    }

    /// Engine returning values far beyond the counter range — exercises
    /// the cap clamp on the PJRT mapping.
    struct HotEngine;
    impl BatchEngine for HotEngine {
        fn hidden(
            &mut self,
            _flat: &[f32],
            n: usize,
            _d: usize,
            l: usize,
            _w: &[f32],
            _norm: bool,
        ) -> anyhow::Result<Vec<f32>> {
            Ok(vec![1e12; n * l])
        }
    }

    fn setup() -> WorkerSetup {
        let cfg = ChipConfig::default().with_dims(D, L).with_b(10);
        let chip = ChipModel::fabricate(cfg, 1);
        let (_tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        WorkerSetup {
            index: 0,
            die: ServeChip::physical(chip),
            // beta all-ones: QuantBeta codes are all the max level, so
            // score == sum(h) exactly — the clamp is directly observable
            second: SecondStage::new(&[1.0; L], 10, false),
            tenants: BTreeMap::new(),
            artifact_dir: None,
            rx,
            stamper: metrics.timeline.stamper(0),
            metrics,
            outstanding: Outstanding::new(1),
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pjrt_min_batch: 1,
            pjrt_max_failures: 3,
            normalize: false,
            // a fixed 100 fJ/conversion makes the ledger assertions
            // exact: energy_fj == 100 * conversions, always
            energy_fj_per_conversion: 100,
            baseline_fj_per_conversion: 100,
        }
    }

    fn requests(s: &WorkerSetup, n: usize) -> (Vec<ClassifyRequest>, Vec<mpsc::Receiver<ClassifyResponse>>) {
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..n {
            let (tx, rx) = mpsc::channel();
            s.outstanding.inc(0);
            reqs.push(ClassifyRequest {
                id: i as u64,
                features: vec![0.3; D],
                tenant: None,
                submitted: Instant::now(),
                collected: None,
                reply: tx,
            });
            rxs.push(rx);
        }
        (reqs, rxs)
    }

    fn tag(name: &str) -> TenantTag {
        TenantTag { name: Arc::from(name), metrics: Arc::new(TenantMetrics::default()) }
    }

    #[test]
    fn failing_engine_falls_back_and_labels_chip_sim() {
        // bugfix: the fallback batch must be labelled AND counted as the
        // simulator, not as PJRT, and the warning fires once per engine
        let mut s = setup();
        let mut engine = Some(FailEngine);
        let mut logs = LogOnce::default();
        let (reqs, rxs) = requests(&s, 4);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert!(logs.pjrt_fallback, "first fallback must log");
        for rx in &rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.backend, Backend::ChipSim, "fallback mislabeled");
            assert_eq!(resp.passes, 1);
        }
        assert_eq!(s.metrics.pjrt_batches.load(Ordering::Relaxed), 0);
        assert_eq!(s.metrics.sim_batches.load(Ordering::Relaxed), 1);
        // the sim path books real ledger conversions into the metrics
        assert_eq!(s.metrics.conversions.load(Ordering::Relaxed), 4);
        assert_eq!(s.outstanding.load(0), 0);
        // a second failing batch stays silent (once per engine)
        let (reqs, _rxs) = requests(&s, 4);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert!(logs.pjrt_fallback);
        assert_eq!(s.metrics.sim_batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.metrics.pjrt_batches.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn engine_is_dropped_after_consecutive_failures() {
        // PJRT hardening: at pjrt_max_failures consecutive errors the
        // worker stops re-attempting the engine entirely
        let mut s = setup();
        s.pjrt_max_failures = 2;
        let mut engine = Some(FailEngine);
        let mut logs = LogOnce::default();
        let (reqs, _rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert!(engine.is_some(), "one failure must not drop the engine");
        assert_eq!(logs.pjrt_fail_streak, 1);
        let (reqs, _rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert!(engine.is_none(), "second consecutive failure drops it");
        // further batches serve the simulator without an engine
        let (reqs, rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert_eq!(rxs[0].recv().unwrap().backend, Backend::ChipSim);
        assert_eq!(s.outstanding.load(0), 0);
    }

    #[test]
    fn a_success_resets_the_failure_streak() {
        let mut s = setup();
        s.pjrt_max_failures = 2;
        let mut logs = LogOnce::default();
        // one failure...
        let mut fail = Some(FailEngine);
        let (reqs, _rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut fail, &mut logs, &[], &reqs, false);
        assert_eq!(logs.pjrt_fail_streak, 1);
        // ...then a success on a healthy engine resets the streak
        let mut hot = Some(HotEngine);
        let (reqs, _rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut hot, &mut logs, &[], &reqs, false);
        assert_eq!(logs.pjrt_fail_streak, 0);
        assert!(hot.is_some());
    }

    #[test]
    fn pjrt_hidden_is_clamped_to_the_counter_cap() {
        // bugfix: a hot artifact output can never exceed 2^b; with an
        // all-ones head the score is exactly sum(h) = L * cap
        let mut s = setup();
        let cap = s.die.chip().cfg.cap(); // 2^10
        let mut engine = Some(HotEngine);
        let mut logs = LogOnce::default();
        let (reqs, rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        for rx in &rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.backend, Backend::Pjrt);
            assert!(
                (resp.score - (L as u32 * cap) as f64).abs() < 1e-3,
                "unclamped score {}",
                resp.score
            );
        }
        assert_eq!(s.metrics.pjrt_batches.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.sim_batches.load(Ordering::Relaxed), 0);
        // one physical conversion per request on the artifact path
        assert_eq!(s.metrics.conversions.load(Ordering::Relaxed), 2);
        assert!(!logs.pjrt_fallback);
    }

    #[test]
    fn small_batches_and_stale_artifacts_use_the_simulator() {
        let mut s = setup();
        s.pjrt_min_batch = 8;
        let mut engine = Some(HotEngine);
        let mut logs = LogOnce::default();
        let (reqs, rxs) = requests(&s, 2); // below pjrt_min_batch
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        assert_eq!(rxs[0].recv().unwrap().backend, Backend::ChipSim);
        s.pjrt_min_batch = 1;
        let (reqs, rxs) = requests(&s, 2);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, true); // stale
        assert_eq!(rxs[0].recv().unwrap().backend, Backend::ChipSim);
        assert_eq!(s.metrics.pjrt_batches.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn malformed_request_is_dropped_without_killing_the_worker() {
        // a wrong-dimension request (past the submit-side validation,
        // e.g. a future protocol bug) must not panic the die's thread
        let mut s = setup();
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 2);
        reqs[1].features = vec![0.1; D + 3]; // malformed
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        // drop the batch as run() does, releasing the unanswered reply
        drop(reqs);
        // the good request is answered, the bad one dropped
        assert!(rxs[0].recv().is_ok());
        assert!(rxs[1].recv().is_err(), "malformed request must get no reply");
        // outstanding stays balanced so a drain can complete
        assert_eq!(s.outstanding.load(0), 0);
        assert_eq!(s.metrics.responses.load(Ordering::Relaxed), 1);
        assert!(logs.dropped_request, "drop must latch its once-per-worker log");
    }

    #[test]
    fn malformed_request_never_reaches_the_engine() {
        // a wrong-length row would shift every row after it in the
        // flattened PJRT input (and the real engine asserts on total
        // length): the whole batch must take the sim path instead
        let mut s = setup();
        let mut engine = Some(HotEngine);
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 3);
        reqs[2].features = vec![0.1; D - 1]; // malformed
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        drop(reqs);
        // good requests answered by the simulator, bad one dropped
        assert_eq!(rxs[0].recv().unwrap().backend, Backend::ChipSim);
        assert_eq!(rxs[1].recv().unwrap().backend, Backend::ChipSim);
        assert!(rxs[2].recv().is_err());
        assert_eq!(s.metrics.pjrt_batches.load(Ordering::Relaxed), 0);
        assert_eq!(s.outstanding.load(0), 0);
    }

    #[test]
    fn virtual_die_serves_with_pass_cost_in_responses_and_conversions() {
        let cfg = ChipConfig::default().with_dims(D, L).with_b(10);
        let chip = ChipModel::fabricate(cfg, 2);
        let mut s = setup();
        s.die = ServeChip::new(chip, 2 * D, 2 * L).unwrap(); // 4 passes
        s.second = SecondStage::new(&[1.0; 2 * L], 10, false);
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 3);
        for r in &mut reqs {
            r.features = vec![0.3; 2 * D];
        }
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        for rx in &rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.backend, Backend::ChipSim);
            assert_eq!(resp.passes, 4);
        }
        // the ledger delta books exactly passes() conversions/request
        assert_eq!(s.metrics.conversions.load(Ordering::Relaxed), 12);
    }

    /// Install a regression tenant whose single head is all-ones: its
    /// training-unit score is exactly sum(h)/2^b, directly observable.
    fn install_ones_regression(s: &mut WorkerSetup, name: &str) {
        let spec = Arc::new(
            TenantSpec::regression(name, vec![vec![0.0; D]; 2], &[0.0, 0.0], 1.0, 10).unwrap(),
        );
        let (mut entry, _) = fit_on_die(&mut s.die, false, &spec).unwrap();
        entry.rls.betas = vec![vec![1.0; L]];
        entry.rebuild_heads(false);
        s.tenants.insert(name.to_string(), entry);
    }

    #[test]
    fn cross_tenant_batch_scores_each_row_with_its_own_head() {
        // one hidden-layer pass per batch, many heads: a default row
        // and a tenant row in the same batch get different scores from
        // the same hidden activations
        let mut s = setup();
        install_ones_regression(&mut s, "bright");
        let cap = s.die.chip().cfg.cap();
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 2);
        reqs[1].tenant = Some(tag("bright"));
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        let default_resp = rxs[0].recv().unwrap();
        let tenant_resp = rxs[1].recv().unwrap();
        assert!(default_resp.tenant.is_none());
        assert_eq!(tenant_resp.tenant.as_deref(), Some("bright"));
        assert_eq!(tenant_resp.label, 0, "regression label");
        // same input row -> same hidden counts: the tenant score is the
        // default (all-ones, unscaled) score divided by the counter cap
        assert!(
            (tenant_resp.score - default_resp.score / cap as f64).abs() < 1e-9,
            "default {} tenant {}",
            default_resp.score,
            tenant_resp.score
        );
        // tenant metrics recorded via the tag handle
        let m = &reqs[1].tenant.as_ref().unwrap().metrics;
        assert_eq!(m.responses.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.responses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unknown_tenant_request_is_dropped_and_balanced() {
        let mut s = setup();
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 2);
        reqs[0].tenant = Some(tag("nosuch"));
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        drop(reqs);
        assert!(rxs[0].recv().is_err(), "unknown tenant gets no reply");
        assert!(rxs[1].recv().is_ok(), "default row still answered");
        assert!(logs.unknown_tenant);
        assert_eq!(s.outstanding.load(0), 0);
        // the drop still leaves a trace, labelled with its outcome
        let dropped: Vec<_> = s
            .metrics
            .trace
            .dump(16)
            .into_iter()
            .filter(|t| t.outcome == TraceOutcome::DroppedUnknownTenant)
            .collect();
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].tenant.as_deref(), Some("nosuch"));
    }

    #[test]
    fn serving_books_energy_and_macs_at_the_die_price() {
        // 3 sim requests on a physical die book 3 conversions, each
        // priced at the setup's fixed 100 fJ and D*L MACs
        let mut s = setup();
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (reqs, _rxs) = requests(&s, 3);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.conversions, 3);
        assert_eq!(snap.energy_fj, 300, "3 conversions x 100 fJ");
        assert_eq!(snap.macs, 3 * (D * L) as u64);
        assert!((snap.pj_per_mac() - 300.0e-3 / (3.0 * (D * L) as f64)).abs() < 1e-12);
    }

    #[test]
    fn virtual_die_books_pass_weighted_energy_per_tenant_row() {
        // a 4-pass virtual die books 4 conversions per answered row;
        // the tenant's share is passes * price for its own rows only
        let cfg = ChipConfig::default().with_dims(D, L).with_b(10);
        let chip = ChipModel::fabricate(cfg, 2);
        let mut s = setup();
        s.die = ServeChip::new(chip, 2 * D, 2 * L).unwrap(); // 4 passes
        s.second = SecondStage::new(&[1.0; 2 * L], 10, false);
        install_ones_regression_virtual(&mut s, "bright");
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, _rxs) = requests(&s, 2);
        for r in &mut reqs {
            r.features = vec![0.3; 2 * D];
        }
        reqs[1].tenant = Some(tag("bright"));
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.conversions, 8, "2 rows x 4 passes");
        assert_eq!(snap.energy_fj, 800);
        let tenant = &reqs[1].tenant.as_ref().unwrap().metrics;
        assert_eq!(tenant.energy_fj.load(Ordering::Relaxed), 400, "4 passes x 100 fJ");
    }

    /// `install_ones_regression` for a virtual (2D x 2L) die.
    fn install_ones_regression_virtual(s: &mut WorkerSetup, name: &str) {
        let spec = Arc::new(
            TenantSpec::regression(name, vec![vec![0.0; 2 * D]; 2], &[0.0, 0.0], 1.0, 10)
                .unwrap(),
        );
        let (mut entry, _) = fit_on_die(&mut s.die, false, &spec).unwrap();
        entry.rls.betas = vec![vec![1.0; 2 * L]];
        entry.rebuild_heads(false);
        s.tenants.insert(name.to_string(), entry);
    }

    #[test]
    fn serving_stamps_the_timeline_and_books_tenant_busy_time() {
        let mut s = setup();
        install_ones_regression(&mut s, "bright");
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 2);
        reqs[1].tenant = Some(tag("bright"));
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        for rx in &rxs {
            rx.recv().unwrap();
        }
        // the tenant row's utilization share: at least the 1 us clamp,
        // booked exactly once per answered row
        let m = &reqs[1].tenant.as_ref().unwrap().metrics;
        assert!(m.busy_us.load(Ordering::Relaxed) >= 1, "tenant busy share");
        // serve_batch closed a conversion mark and a transfer mark on
        // this die's ledger; whatever width they had, the fractions
        // still tile (sub-microsecond spans drop and count nothing)
        let occ = s.metrics.timeline.occupancy();
        assert_eq!(occ.len(), 1);
        assert_eq!(occ[0].die, 0);
        let sum: f64 = occ[0].fractions().iter().sum();
        assert!(sum == 0.0 || (sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
        // a 4-pass virtual die labels its conversion span rotation-pass
        let cfg = ChipConfig::default().with_dims(D, L).with_b(10);
        let chip = ChipModel::fabricate(cfg, 2);
        let mut v = setup();
        v.die = ServeChip::new(chip, 2 * D, 2 * L).unwrap();
        v.second = SecondStage::new(&[1.0; 2 * L], 10, false);
        let (mut reqs, _rxs) = requests(&v, 1);
        reqs[0].features = vec![0.3; 2 * D];
        std::thread::sleep(Duration::from_millis(2));
        serve_batch(&mut v, &mut engine, &mut logs, &[], &reqs, false);
        let occ = &v.metrics.timeline.occupancy()[0];
        assert!(
            occ.seg_us[Segment::RotationPass.code() as usize] >= 1000,
            "rotation-pass span must absorb the pre-batch sleep: {occ:?}"
        );
        assert_eq!(occ.seg_us[Segment::Convert.code() as usize], 0);
    }

    #[test]
    fn retune_reprograms_bits_window_and_price() {
        // governor actuation: fewer counter bits -> proportionally
        // shorter window, cheaper conversion, stale artifact
        let mut s = setup(); // b = 10
        let t0 = s.die.chip().t_neu_set;
        let price0 = crate::chip::energy::conversion_price_fj(&s.die.chip().cfg);
        let (tx, rx) = mpsc::channel();
        let mut stale = false;
        handle_control(&mut s, &mut stale, ControlMsg::Retune { b: 6, reply: tx });
        let new_price = rx.recv().unwrap();
        assert!(stale, "retuned die must pin to the simulator");
        assert_eq!(s.die.chip().cfg.b, 6);
        assert!(
            (s.die.chip().t_neu_set - t0 / 16.0).abs() / t0 < 1e-12,
            "window scales by the cap ratio 2^6/2^10"
        );
        assert_eq!(new_price, s.energy_fj_per_conversion, "worker re-prices its ledger");
        assert!(new_price < price0, "fewer bits must be cheaper");
        // retuning back restores the window exactly
        let (tx, rx) = mpsc::channel();
        handle_control(&mut s, &mut stale, ControlMsg::Retune { b: 10, reply: tx });
        rx.recv().unwrap();
        assert!((s.die.chip().t_neu_set - t0).abs() / t0 < 1e-12);
    }

    #[test]
    fn cheaper_rung_books_exact_fj_saved() {
        let mut s = setup(); // baseline 100 fJ/conversion
        s.energy_fj_per_conversion = 40; // governor holds a low rung
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (reqs, _rxs) = requests(&s, 3);
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.energy_fj, 120, "3 conversions x 40 fJ at the low rung");
        assert_eq!(snap.governor.fj_saved, 180, "3 x (100 - 40) fJ saved, exactly");
    }

    #[test]
    fn traces_decompose_the_end_to_end_span() {
        let mut s = setup();
        let mut engine: Option<FailEngine> = None;
        let mut logs = LogOnce::default();
        let (mut reqs, rxs) = requests(&s, 2);
        // simulate the batcher's stamp so queue-wait is observable
        std::thread::sleep(Duration::from_millis(2));
        for r in &mut reqs {
            r.collected = Some(Instant::now());
        }
        serve_batch(&mut s, &mut engine, &mut logs, &[], &reqs, false);
        for rx in &rxs {
            rx.recv().unwrap();
        }
        let traces = s.metrics.trace.dump(16);
        assert_eq!(traces.len(), 2);
        for t in &traces {
            assert_eq!(t.outcome, TraceOutcome::Ok);
            assert_eq!(t.die, 0);
            assert!(!t.pjrt);
            assert!(t.queue_us >= 1000, "slept 2ms before collect: {t}");
            let sum = t.queue_us + t.batch_us + t.compute_us;
            assert!(sum <= t.total_us, "stage sum overshoots total: {t}");
            assert!(t.total_us - sum <= 3, "stage sum undershoots total: {t}");
        }
        // stage histograms populated once per answered request
        let snap = s.metrics.snapshot();
        assert_eq!(snap.queue.count, 2);
        assert_eq!(snap.batch_wait.count, 2);
        assert_eq!(snap.compute.count, 2);
        assert!(snap.queue.p50_us >= 1000, "{:?}", snap.queue);
    }
}
