//! Chip worker: one thread owning one fabricated die, its trained head
//! and (optionally) a PJRT engine. Batches arrive from the router via
//! the dynamic batcher; the hidden layer runs on the batched AOT
//! artifact when the batch is large enough, else on the scalar chip
//! simulator; the fixed-point second stage produces the score.
//! Fleet-health control messages (probe / drift injection / renormalise
//! / refit — DESIGN.md §12) ride the same channel and execute here,
//! because this thread owns the die.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::chip::{dac, ChipModel};
use crate::config::SystemConfig;
use crate::elm::secondstage::{codes_sum, SecondStage};
use crate::fleet::{calibrate, probe};
use crate::runtime::PjrtEngine;

use super::batcher::collect_batch;
use super::metrics::Metrics;
use super::request::{Backend, ClassifyRequest, ClassifyResponse, ControlMsg, WorkerMsg};
use super::router::Outstanding;

/// Everything one worker needs, bundled for the spawn.
pub struct WorkerSetup {
    pub index: usize,
    pub chip: ChipModel,
    pub second: SecondStage,
    /// Artifact directory; the engine itself is created *inside* the
    /// worker thread (PJRT handles are not `Send`).
    pub artifact_dir: Option<String>,
    pub rx: Receiver<WorkerMsg>,
    pub metrics: Arc<Metrics>,
    pub outstanding: Outstanding,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub pjrt_min_batch: usize,
    pub normalize: bool,
}

/// Worker main loop; returns when the request channel closes.
pub fn run(mut s: WorkerSetup) {
    // PJRT engine lives entirely on this thread (handles are not Send)
    let mut engine: Option<PjrtEngine> = s.artifact_dir.as_deref().and_then(open_engine);
    // weight matrix for the PJRT path, frozen at spawn conditions
    let w_f32: Vec<f32> = s.chip.weights().to_f32();
    // The AOT artifact bakes the nominal corner (spawn-time weights,
    // fabricated T_neu, nominal VDD). Once drift injection or a
    // renormalisation changes the die underneath it, the artifact no
    // longer matches the chip's physics — scoring small batches on the
    // sim and large ones on a stale artifact would split one die into
    // two inconsistent classifiers. So the first such control message
    // pins this die to the simulator for good.
    let mut artifact_stale = false;
    let d = s.chip.cfg.d;
    let l = s.chip.cfg.l;
    while let Some(batch) = collect_batch(&s.rx, s.max_batch, s.max_wait) {
        if !batch.requests.is_empty() {
            serve_batch(&mut s, &mut engine, &w_f32, d, l, &batch.requests, artifact_stale);
        }
        for ctl in batch.control {
            handle_control(&mut s, &mut artifact_stale, ctl);
        }
    }
}

/// Serve one classify batch through PJRT or the chip simulator.
fn serve_batch(
    s: &mut WorkerSetup,
    engine: &mut Option<PjrtEngine>,
    w_f32: &[f32],
    d: usize,
    l: usize,
    requests: &[ClassifyRequest],
    artifact_stale: bool,
) {
    let n = requests.len();
    let use_pjrt = engine.is_some() && !artifact_stale && n >= s.pjrt_min_batch;
    s.metrics.record_batch(n, use_pjrt);
    // DAC quantisation happens once, shared by both paths
    let codes: Vec<Vec<u16>> = requests
        .iter()
        .map(|r| dac::features_to_codes(&r.features, &s.chip.cfg))
        .collect();
    let hidden: Vec<Vec<u32>> = if use_pjrt {
        let engine = engine.as_mut().unwrap();
        let flat: Vec<f32> = codes
            .iter()
            .flat_map(|c| c.iter().map(|&v| v as f32))
            .collect();
        match engine.hidden(&flat, n, d, l, w_f32, false) {
            Ok(out) => out
                .chunks(l)
                .map(|row| row.iter().map(|&v| v.max(0.0) as u32).collect())
                .collect(),
            Err(e) => {
                // artifact trouble: fall back to the simulator
                eprintln!("worker {}: pjrt failed ({e:#}); falling back", s.index);
                codes.iter().map(|c| s.chip.forward(c)).collect()
            }
        }
    } else {
        codes.iter().map(|c| s.chip.forward(c)).collect()
    };
    let backend = if use_pjrt { Backend::Pjrt } else { Backend::ChipSim };
    for ((req, code), h) in requests.iter().zip(&codes).zip(&hidden) {
        let score = s.second.score(h, codes_sum(code));
        let resp = ClassifyResponse {
            id: req.id,
            score,
            label: if score >= 0.0 { 1 } else { -1 },
            worker: s.index,
            backend,
            latency: req.submitted.elapsed(),
        };
        s.metrics.record_response(resp.latency);
        s.outstanding.dec(s.index);
        // receiver may have hung up; that's the client's business
        let _ = req.reply.send(resp);
    }
}

/// Execute one fleet-health control message on the die this thread owns.
fn handle_control(s: &mut WorkerSetup, artifact_stale: &mut bool, ctl: ControlMsg) {
    match ctl {
        ControlMsg::Probe { probe: set, reply } => {
            let rep = probe::run_probe(&mut s.chip, &s.second, &set);
            let _ = reply.send(rep);
        }
        ControlMsg::SetEnv { vdd, temp_k, age_sigma_vt, seed } => {
            if let Some(v) = vdd {
                s.chip.set_vdd(v);
            }
            if let Some(t) = temp_k {
                s.chip.set_temp(t);
            }
            if let Some(sigma) = age_sigma_vt {
                s.chip.age_mismatch(sigma, seed);
            }
            *artifact_stale = true; // the artifact's corner is gone
        }
        ControlMsg::Renormalize { gain, reply } => {
            let t_neu = calibrate::renormalize(&mut s.chip, gain);
            *artifact_stale = true; // artifact counts keep the old T_neu
            let _ = reply.send(t_neu);
        }
        ControlMsg::Refit { xs, ys, lambda, beta_bits, probe: set, reply } => {
            let res = calibrate::refit_head(&mut s.chip, s.normalize, &xs, &ys, lambda, beta_bits)
                .map(|second| {
                    s.second = second;
                    probe::run_probe(&mut s.chip, &s.second, &set)
                });
            // the refit head was solved against the *current* (drifted)
            // die, which the frozen artifact does not model
            *artifact_stale = true;
            let _ = reply.send(res);
        }
    }
}

/// Open the PJRT engine for a directory, logging (not failing) on error.
fn open_engine(dir: &str) -> Option<PjrtEngine> {
    let path = std::path::Path::new(dir);
    if !crate::runtime::artifacts_available(path) {
        return None;
    }
    match PjrtEngine::new(path) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("pjrt engine unavailable ({err:#}); serving via chip sim");
            None
        }
    }
}

/// Artifact dir to pass into a worker, if it looks usable.
pub fn usable_artifact_dir(sys: &SystemConfig) -> Option<String> {
    let dir = std::path::Path::new(&sys.artifact_dir);
    if crate::runtime::artifacts_available(dir) {
        Some(sys.artifact_dir.clone())
    } else {
        None
    }
}
