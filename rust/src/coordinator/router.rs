//! Request router: spreads classification requests across the worker
//! (die) pool by least outstanding work, falling back to round-robin on
//! ties — each worker owns one fabricated chip and its own trained head.
//! Routing is health-aware (DESIGN.md §12): only dies the fleet manager
//! marks `Healthy` are candidates, so drained / recalibrating /
//! quarantined dies and cold standbys never see traffic. Load is
//! *pass-weighted* (DESIGN.md §13): a request on a die serving a
//! virtual projection costs `RotationPlan::passes()` physical
//! conversions, so one outstanding request there counts as `passes`
//! units against the die when comparing loads.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::sync::{AtomicU64, AtomicUsize, Ordering};

use crate::fleet::FleetState;

use super::request::{ClassifyRequest, WorkerMsg};

/// Shared outstanding-work counters, decremented by workers on reply.
/// The fleet manager reads them to decide when a draining die is idle.
#[derive(Clone)]
pub struct Outstanding(pub Arc<Vec<AtomicUsize>>);

impl Outstanding {
    // relaxed-ok: independent per-die load gauges used as routing and
    // drain *hints*; a stale read only skews a tiebreak or delays one
    // drain poll, and no other memory is inferred from the values.
    pub fn new(n: usize) -> Self {
        Outstanding(Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect()))
    }

    pub fn inc(&self, w: usize) {
        self.0[w].fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self, w: usize) {
        self.0[w].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn load(&self, w: usize) -> usize {
        self.0[w].load(Ordering::Relaxed)
    }
}

pub struct Router {
    senders: Vec<Sender<WorkerMsg>>,
    pub outstanding: Outstanding,
    /// Per-die lifecycle gauges; only `Healthy` dies are routable.
    pub health: FleetState,
    /// Physical conversions one request costs on each die (1 for a
    /// physical die, the rotation plan's passes for a virtual one);
    /// outstanding work is compared in these units.
    costs: Vec<usize>,
    rr: AtomicU64,
}

impl Router {
    /// Router over an all-healthy pool (no standbys) — tests and callers
    /// that don't run the fleet manager.
    pub fn new(senders: Vec<Sender<WorkerMsg>>) -> Self {
        let n = senders.len();
        Router::with_health(senders, FleetState::new(n, n))
    }

    /// Router sharing the fleet manager's health state (unit pass cost).
    pub fn with_health(senders: Vec<Sender<WorkerMsg>>, health: FleetState) -> Self {
        let costs = vec![1; senders.len()];
        Router::with_costs(senders, health, costs)
    }

    /// Router with explicit per-die pass costs (DESIGN.md §13).
    pub fn with_costs(
        senders: Vec<Sender<WorkerMsg>>,
        health: FleetState,
        costs: Vec<usize>,
    ) -> Self {
        assert_eq!(senders.len(), costs.len());
        let outstanding = Outstanding::new(senders.len());
        Router { senders, outstanding, health, costs, rr: AtomicU64::new(0) }
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Pick the *healthy* worker with the least outstanding work in
    /// physical-conversion units (round-robin tiebreak) and enqueue.
    /// Errors when no die is in the `Healthy` state.
    pub fn route(&self, req: ClassifyRequest) -> Result<usize, String> {
        let n = self.senders.len();
        if n == 0 {
            return Err("no workers".into());
        }
        // relaxed-ok: round-robin cursor; any interleaving of the
        // increments still spreads ties, which is all it promises.
        let start = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % n;
        let mut best = usize::MAX;
        let mut best_load = usize::MAX;
        for k in 0..n {
            let w = (start + k) % n;
            if !self.health.routable(w) {
                continue;
            }
            let load = self.outstanding.load(w).saturating_mul(self.costs[w]);
            if load < best_load {
                best = w;
                best_load = load;
            }
        }
        if best == usize::MAX {
            return Err("no healthy workers".into());
        }
        self.outstanding.inc(best);
        self.senders[best]
            .send(WorkerMsg::Classify(req))
            .map_err(|_| format!("worker {best} is gone"))?;
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::DieState;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> ClassifyRequest {
        let (tx, _rx) = mpsc::channel();
        ClassifyRequest {
            id,
            features: vec![],
            tenant: None,
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        }
    }

    fn queued_ids(rx: &mpsc::Receiver<WorkerMsg>) -> Vec<u64> {
        rx.try_iter()
            .filter_map(|m| match m {
                WorkerMsg::Classify(r) => Some(r.id),
                WorkerMsg::Control(_) => None,
            })
            .collect()
    }

    #[test]
    fn spreads_load_evenly_when_idle() {
        let (t0, r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        let mut counts = [0usize; 2];
        for i in 0..10 {
            let w = router.route(req(i)).unwrap();
            counts[w] += 1;
            // simulate completion so load stays balanced
            router.outstanding.dec(w);
        }
        assert_eq!(counts[0] + counts[1], 10);
        assert!(counts[0] >= 4 && counts[1] >= 4, "{counts:?}");
        assert_eq!(queued_ids(&r0).len() + queued_ids(&r1).len(), 10);
    }

    #[test]
    fn prefers_less_loaded_worker() {
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        // worker 0 is busy with 5 outstanding
        for _ in 0..5 {
            router.outstanding.inc(0);
        }
        for i in 0..5 {
            let w = router.route(req(i)).unwrap();
            assert_eq!(w, 1, "request {i} should go to idle worker");
            router.outstanding.dec(w);
        }
    }

    #[test]
    fn conservation_under_routing() {
        // every routed request lands in exactly one queue
        let (t0, r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let (t2, r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        for i in 0..100 {
            router.route(req(i)).unwrap();
        }
        let mut ids: Vec<u64> = queued_ids(&r0);
        ids.extend(queued_ids(&r1));
        ids.extend(queued_ids(&r2));
        ids.sort_unstable();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn round_robin_tiebreak_spreads_all_equal_loads() {
        // with every load equal, the rotating start index must spread
        // requests across ALL workers instead of piling onto worker 0
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let (t2, _r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        let mut counts = [0usize; 3];
        for i in 0..9 {
            let w = router.route(req(i)).unwrap();
            counts[w] += 1;
            router.outstanding.dec(w); // complete immediately: stay tied
        }
        assert_eq!(counts, [3, 3, 3], "{counts:?}");
    }

    #[test]
    fn routes_to_global_minimum_under_skewed_load() {
        // loads [3, 1, 2]: every new request must land on worker 1
        // until it catches up with worker 2
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let (t2, _r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        for _ in 0..3 {
            router.outstanding.inc(0);
        }
        router.outstanding.inc(1);
        router.outstanding.inc(2);
        router.outstanding.inc(2);
        for i in 0..8 {
            // worker 1 is the unique minimum every time because each
            // request completes (dec) before the next arrives
            let w = router.route(req(i)).unwrap();
            assert_eq!(w, 1, "request {i} should go to the least-loaded worker");
            router.outstanding.dec(w);
        }
    }

    #[test]
    fn outstanding_tracks_inflight_work() {
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        for i in 0..6 {
            router.route(req(i)).unwrap();
        }
        let total: usize = (0..2).map(|w| router.outstanding.load(w)).sum();
        assert_eq!(total, 6, "every routed request must be counted in-flight");
        router.outstanding.dec(0);
        let total: usize = (0..2).map(|w| router.outstanding.load(w)).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn pass_weighted_routing_prices_virtual_work() {
        // worker 0 serves a 9-pass virtual projection, worker 1 a
        // physical die: a single outstanding virtual request outweighs
        // up to 8 outstanding physical ones
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router =
            Router::with_costs(vec![t0, t1], FleetState::new(2, 2), vec![9, 1]);
        router.outstanding.inc(0); // one virtual request in flight = 9 units
        for i in 0..8 {
            // physical load grows 0..=7 units, always below 9
            assert_eq!(router.route(req(i)).unwrap(), 1, "request {i}");
        }
        // once the physical die owes more conversions than the virtual
        // one, the virtual die wins again
        for _ in 0..2 {
            router.outstanding.inc(1); // 10 physical units vs 9 virtual
        }
        assert_eq!(router.route(req(99)).unwrap(), 0);
    }

    #[test]
    fn unit_costs_reduce_to_plain_least_outstanding() {
        // with every cost 1 the weighted router is exactly the old one
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router =
            Router::with_costs(vec![t0, t1], FleetState::new(2, 2), vec![1, 1]);
        for _ in 0..3 {
            router.outstanding.inc(0);
        }
        for i in 0..4 {
            assert_eq!(router.route(req(i)).unwrap(), 1);
            router.outstanding.dec(1);
        }
    }

    #[test]
    fn dead_worker_reports_error() {
        let (t0, r0) = mpsc::channel();
        drop(r0);
        let router = Router::new(vec![t0]);
        assert!(router.route(req(1)).is_err());
    }

    #[test]
    fn skips_non_healthy_dies() {
        let (t0, r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        router.health.set(0, DieState::Draining);
        for i in 0..6 {
            let w = router.route(req(i)).unwrap();
            assert_eq!(w, 1, "request {i} must avoid the draining die");
            router.outstanding.dec(w);
        }
        assert!(queued_ids(&r0).is_empty());
        assert_eq!(queued_ids(&r1).len(), 6);
        // recovery re-admits the die into rotation
        router.health.set(0, DieState::Healthy);
        let mut hit0 = false;
        for i in 0..6 {
            let w = router.route(req(i)).unwrap();
            hit0 |= w == 0;
            router.outstanding.dec(w);
        }
        assert!(hit0, "re-admitted die must receive traffic again");
    }

    #[test]
    fn standby_pool_is_never_routed_until_promoted() {
        let (t0, _r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let health = FleetState::new(2, 1); // die 1 is a hot standby
        let router = Router::with_health(vec![t0, t1], health);
        for i in 0..4 {
            assert_eq!(router.route(req(i)).unwrap(), 0);
            router.outstanding.dec(0);
        }
        assert!(queued_ids(&r1).is_empty());
        // promotion makes it routable
        router.health.set(1, DieState::Healthy);
        let mut hit1 = false;
        for i in 0..6 {
            let w = router.route(req(i)).unwrap();
            hit1 |= w == 1;
            router.outstanding.dec(w);
        }
        assert!(hit1);
    }

    #[test]
    fn no_healthy_workers_is_an_error() {
        let (t0, _r0) = mpsc::channel();
        let router = Router::new(vec![t0]);
        router.health.set(0, DieState::Quarantined);
        assert!(router.route(req(1)).is_err());
    }
}
