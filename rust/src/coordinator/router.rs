//! Request router: spreads classification requests across the worker
//! (die) pool by least outstanding work, falling back to round-robin on
//! ties — each worker owns one fabricated chip and its own trained head.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use super::request::ClassifyRequest;

/// Shared outstanding-work counters, decremented by workers on reply.
#[derive(Clone)]
pub struct Outstanding(pub Arc<Vec<AtomicUsize>>);

impl Outstanding {
    pub fn new(n: usize) -> Self {
        Outstanding(Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect()))
    }

    pub fn inc(&self, w: usize) {
        self.0[w].fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self, w: usize) {
        self.0[w].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn load(&self, w: usize) -> usize {
        self.0[w].load(Ordering::Relaxed)
    }
}

pub struct Router {
    senders: Vec<Sender<ClassifyRequest>>,
    pub outstanding: Outstanding,
    rr: AtomicU64,
}

impl Router {
    pub fn new(senders: Vec<Sender<ClassifyRequest>>) -> Self {
        let outstanding = Outstanding::new(senders.len());
        Router { senders, outstanding, rr: AtomicU64::new(0) }
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Pick the least-loaded worker (round-robin tiebreak) and enqueue.
    pub fn route(&self, req: ClassifyRequest) -> Result<usize, String> {
        let n = self.senders.len();
        if n == 0 {
            return Err("no workers".into());
        }
        let start = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % n;
        let mut best = start;
        let mut best_load = usize::MAX;
        for k in 0..n {
            let w = (start + k) % n;
            let load = self.outstanding.load(w);
            if load < best_load {
                best = w;
                best_load = load;
            }
        }
        self.outstanding.inc(best);
        self.senders[best]
            .send(req)
            .map_err(|_| format!("worker {best} is gone"))?;
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> ClassifyRequest {
        let (tx, _rx) = mpsc::channel();
        ClassifyRequest { id, features: vec![], submitted: Instant::now(), reply: tx }
    }

    #[test]
    fn spreads_load_evenly_when_idle() {
        let (t0, r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        let mut counts = [0usize; 2];
        for i in 0..10 {
            let w = router.route(req(i)).unwrap();
            counts[w] += 1;
            // simulate completion so load stays balanced
            router.outstanding.dec(w);
        }
        assert_eq!(counts[0] + counts[1], 10);
        assert!(counts[0] >= 4 && counts[1] >= 4, "{counts:?}");
        assert_eq!(r0.try_iter().count() + r1.try_iter().count(), 10);
    }

    #[test]
    fn prefers_less_loaded_worker() {
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        // worker 0 is busy with 5 outstanding
        for _ in 0..5 {
            router.outstanding.inc(0);
        }
        for i in 0..5 {
            let w = router.route(req(i)).unwrap();
            assert_eq!(w, 1, "request {i} should go to idle worker");
            router.outstanding.dec(w);
        }
    }

    #[test]
    fn conservation_under_routing() {
        // every routed request lands in exactly one queue
        let (t0, r0) = mpsc::channel();
        let (t1, r1) = mpsc::channel();
        let (t2, r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        for i in 0..100 {
            router.route(req(i)).unwrap();
        }
        let mut ids: Vec<u64> = r0
            .try_iter()
            .chain(r1.try_iter())
            .chain(r2.try_iter())
            .map(|r| r.id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn round_robin_tiebreak_spreads_all_equal_loads() {
        // with every load equal, the rotating start index must spread
        // requests across ALL workers instead of piling onto worker 0
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let (t2, _r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        let mut counts = [0usize; 3];
        for i in 0..9 {
            let w = router.route(req(i)).unwrap();
            counts[w] += 1;
            router.outstanding.dec(w); // complete immediately: stay tied
        }
        assert_eq!(counts, [3, 3, 3], "{counts:?}");
    }

    #[test]
    fn routes_to_global_minimum_under_skewed_load() {
        // loads [3, 1, 2]: every new request must land on worker 1
        // until it catches up with worker 2
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let (t2, _r2) = mpsc::channel();
        let router = Router::new(vec![t0, t1, t2]);
        for _ in 0..3 {
            router.outstanding.inc(0);
        }
        router.outstanding.inc(1);
        router.outstanding.inc(2);
        router.outstanding.inc(2);
        for i in 0..8 {
            // worker 1 is the unique minimum every time because each
            // request completes (dec) before the next arrives
            let w = router.route(req(i)).unwrap();
            assert_eq!(w, 1, "request {i} should go to the least-loaded worker");
            router.outstanding.dec(w);
        }
    }

    #[test]
    fn outstanding_tracks_inflight_work() {
        let (t0, _r0) = mpsc::channel();
        let (t1, _r1) = mpsc::channel();
        let router = Router::new(vec![t0, t1]);
        for i in 0..6 {
            router.route(req(i)).unwrap();
        }
        let total: usize = (0..2).map(|w| router.outstanding.load(w)).sum();
        assert_eq!(total, 6, "every routed request must be counted in-flight");
        router.outstanding.dec(0);
        let total: usize = (0..2).map(|w| router.outstanding.load(w)).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn dead_worker_reports_error() {
        let (t0, r0) = mpsc::channel();
        drop(r0);
        let router = Router::new(vec![t0]);
        assert!(router.route(req(1)).is_err());
    }
}
