//! Multiplexed connection reactor (DESIGN.md §20): the v1 serve path.
//!
//! The historic front end burned one OS thread per TCP connection and
//! answered one request at a time per connection, so the fleet's
//! throughput ceiling was the transport, not the hardware. This module
//! replaces it with a readiness-polling reactor:
//!
//!   * an **accept thread** that hands fresh sockets — switched to
//!     nonblocking mode — to the poll loop over a channel;
//!   * one **poll loop** thread owning every connection: it drains
//!     readable bytes into per-connection buffers, cuts complete frames
//!     out with [`frame::take_frame`], dispatches decoded requests to
//!     the worker pool, and drains completed replies back out through
//!     buffered partial writes;
//!   * a small **fixed worker pool** (`SystemConfig::reactor_workers`)
//!     sharing one job channel — the only threads that ever block on
//!     the batcher.
//!
//! Total thread count is `workers + 2` no matter how many connections
//! are open. Connections whose first byte is not [`frame::FRAME_MAGIC`]
//! are handed to the legacy blocking v0 path in `server.rs` (those
//! sockets leave nonblocking mode first and do cost a thread each —
//! the compatibility tax is metered in [`ReactorGauges::legacy_conns`]).
//!
//! **Correlation ids.** A v1 client may wrap any request in a
//! `T_CORR` envelope carrying a caller-chosen `u64` id; the reactor
//! dispatches envelopes immediately — many may be in flight on one
//! connection — and answers each with an `R_CORR` envelope echoing the
//! id, in *completion* order. Bare (uncorrelated) requests keep the
//! historic strict ordering: a per-connection FIFO dispatches one at a
//! time so replies land in request order.
//!
//! **Streaming batches.** A correlated `BatchStream` request answers
//! with one `R_STREAM_ROW` frame per row *as each die finishes*
//! (completion order, row index inside the frame), terminated by an
//! `R_STREAM_END` frame carrying the row count and total conversion
//! passes. An uncorrelated `BatchStream` (or one on a blocking
//! transport) degrades to a buffered `Response::Batch`.
//!
//! **Auth scoping.** `Hello{token}` binds the connection to the
//! [`Scope`] its token grants (`SystemConfig::auth_tokens`);
//! REGISTER / UNREGISTER / TenantUpdate outside the granted tenant set
//! and DRAIN outside an unrestricted scope are refused before they
//! reach the dispatcher. Connections that never shake hands stay
//! unrestricted, preserving the pre-auth surface.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::protocol::frame;
use crate::protocol::{Request, Response};
use crate::sync::{AtomicU64, AtomicUsize, Mutex, Ordering};

use super::request::ClassifyResponse;
use super::Coordinator;

/// Refusal message for an unknown `Hello` token — shared with
/// `Coordinator::handle` so the wire and in-process paths agree.
pub const UNKNOWN_TOKEN_MSG: &str =
    "unknown auth token (configure SystemConfig::auth_tokens / velm serve --auth-token)";

/// The tenant scope an auth token grants a connection (DESIGN.md §20).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Scope {
    /// Full surface: every tenant plus admin verbs (DRAIN).
    Unrestricted,
    /// Mutating verbs allowed only for the named tenants; admin verbs
    /// refused. Prediction stays open — scoping guards writes.
    Tenants(BTreeSet<String>),
}

impl Scope {
    /// May this scope mutate (register/unregister/update) `name`?
    pub fn allows_tenant(&self, name: &str) -> bool {
        match self {
            Scope::Unrestricted => true,
            Scope::Tenants(set) => set.contains(name),
        }
    }

    /// May this scope use admin verbs (DRAIN)?
    pub fn allows_admin(&self) -> bool {
        matches!(self, Scope::Unrestricted)
    }

    /// The scope as the handshake reports it: `["*"]` when
    /// unrestricted, the sorted tenant names otherwise.
    pub fn listing(&self) -> Vec<String> {
        match self {
            Scope::Unrestricted => vec!["*".to_string()],
            Scope::Tenants(set) => set.iter().cloned().collect(),
        }
    }

    /// `Some(message)` when this scope refuses `req`, `None` when the
    /// request may proceed to the dispatcher.
    pub fn refusal(&self, req: &Request) -> Option<String> {
        match req {
            Request::Register { name, .. }
            | Request::Unregister { name }
            | Request::TenantUpdate { name, .. } => {
                if self.allows_tenant(name) {
                    None
                } else {
                    Some(format!(
                        "tenant '{name}' is outside this connection's scope; \
                         present a token that grants it (HELLO)"
                    ))
                }
            }
            Request::Drain { .. } => {
                if self.allows_admin() {
                    None
                } else {
                    Some(
                        "DRAIN needs an unrestricted connection (admin token, \
                         or a server with no auth table)"
                            .to_string(),
                    )
                }
            }
            _ => None,
        }
    }
}

/// Parse `SystemConfig::auth_tokens` entries (`"token=name,name"` or
/// `"token=*"`) into the token table `Coordinator::resolve_token`
/// consults. An empty slice yields an empty table: no handshake is
/// possible and every connection stays unrestricted.
pub fn parse_auth_tokens(entries: &[String]) -> Result<BTreeMap<String, Scope>> {
    let mut table = BTreeMap::new();
    for entry in entries {
        let (token, grant) = entry.split_once('=').with_context(|| {
            format!("auth token entry '{entry}' is not 'token=name,...' or 'token=*'")
        })?;
        let token = token.trim();
        anyhow::ensure!(!token.is_empty(), "auth token entry '{entry}' has an empty token");
        let grant = grant.trim();
        let scope = if grant == "*" {
            Scope::Unrestricted
        } else {
            let mut set = BTreeSet::new();
            for name in grant.split(',') {
                let name = name.trim();
                anyhow::ensure!(
                    !name.is_empty(),
                    "auth token entry '{entry}' names an empty tenant"
                );
                set.insert(name.to_string());
            }
            Scope::Tenants(set)
        };
        anyhow::ensure!(
            table.insert(token.to_string(), scope).is_none(),
            "duplicate auth token '{token}'"
        );
    }
    Ok(table)
}

/// Observability mirrors maintained by the poll loop (single writer;
/// readers are tests, the bench harness and operators).
#[derive(Debug, Default)]
pub struct ReactorGauges {
    /// Connections currently registered with the poll loop.
    pub open_conns: AtomicUsize,
    /// High-water mark of `open_conns` over the reactor's lifetime.
    pub peak_conns: AtomicUsize,
    /// Requests dispatched and not yet fully answered, summed across
    /// connections (correlated in flight + FIFO backlog).
    pub in_flight: AtomicUsize,
    /// High-water mark of `in_flight`.
    pub peak_in_flight: AtomicUsize,
    /// Idle connections reaped by `read_timeout`.
    pub reaped: AtomicU64,
    /// Connections handed to the legacy blocking v0 path (each costs a
    /// thread — the compatibility tax the reactor retires for v1).
    pub legacy_conns: AtomicU64,
}

/// How the reactor is shaped; `server.rs` builds this from
/// `SystemConfig` (`reactor_workers`, `read_timeout`).
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Worker pool width (floored at 1).
    pub workers: usize,
    /// Idle-connection reaping: a connection with no in-flight work,
    /// an empty write buffer and no bytes read for this long is
    /// closed. `None` = never reap.
    pub read_timeout: Option<Duration>,
    /// Accept exactly this many connections then stop (tests/bench);
    /// `None` = serve forever.
    pub max_conns: Option<usize>,
}

/// A running reactor: its bound address, gauges, and threads.
pub struct ReactorHandle {
    /// The listener's bound address (ephemeral port resolved).
    pub addr: SocketAddr,
    /// Live observability mirrors.
    pub gauges: Arc<ReactorGauges>,
    workers: usize,
    threads: Vec<JoinHandle<()>>,
}

impl ReactorHandle {
    /// Threads this reactor runs: the worker pool plus the accept and
    /// poll threads. Constant in the number of connections — the bound
    /// the bench validator asserts (DESIGN.md §20).
    pub fn thread_count(&self) -> usize {
        self.workers + 2
    }

    /// Tear the handle into its join handles (for `server::serve_n`'s
    /// historic return shape).
    pub fn into_threads(self) -> Vec<JoinHandle<()>> {
        self.threads
    }

    /// Block until the reactor drains: only meaningful with
    /// `max_conns` set, otherwise this never returns.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// One unit of work for the pool: a decoded request plus the routing
/// facts the completion needs to find its way back.
struct Job {
    conn: u64,
    corr: Option<u64>,
    /// True when this job occupies its connection's uncorrelated FIFO
    /// slot (its completion releases the slot).
    fifo: bool,
    req: Request,
}

/// One completion flowing back to the poll loop: encoded frame bytes
/// ready for the connection's write buffer. Streamed rows arrive with
/// `last == false`; the frame that ends the request (normal reply,
/// error, or stream end) has `last == true` and releases the in-flight
/// accounting.
struct Done {
    conn: u64,
    bytes: Vec<u8>,
    last: bool,
    fifo: bool,
}

/// Per-connection state owned by the poll loop.
struct Conn {
    stream: TcpStream,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    write_pos: usize,
    scope: Scope,
    /// First byte seen and it was v1 magic.
    sniffed: bool,
    /// Correlated requests dispatched, reply pending.
    in_flight: usize,
    /// Uncorrelated backlog: dispatched one at a time so replies keep
    /// the historic request order.
    fifo: VecDeque<Request>,
    fifo_busy: bool,
    /// Peer sent quit: stop reading, flush, then close.
    closing: bool,
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream, now: Instant) -> Self {
        Conn {
            stream,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            write_pos: 0,
            scope: Scope::Unrestricted,
            sniffed: false,
            in_flight: 0,
            fifo: VecDeque::new(),
            fifo_busy: false,
            closing: false,
            last_activity: now,
        }
    }

    /// No request is anywhere between decode and final reply.
    fn idle(&self) -> bool {
        self.in_flight == 0 && !self.fifo_busy && self.fifo.is_empty()
    }

    /// Satellite 1 (ISSUE 10): a connection with in-flight correlated
    /// requests — or unflushed reply bytes — is ACTIVE, never reaped,
    /// even when the socket itself has been quiet past the timeout
    /// (a slow batch in the batcher window must not kill its reply).
    fn reapable(&self, now: Instant, timeout: Duration) -> bool {
        !self.closing
            && self.idle()
            && self.write_buf.is_empty()
            && now.duration_since(self.last_activity) >= timeout
    }

    fn depth(&self) -> usize {
        self.in_flight + usize::from(self.fifo_busy) + self.fifo.len()
    }
}

enum Verdict {
    Keep,
    Close,
    /// First byte was not v1 magic: hand the socket (plus any buffered
    /// bytes) to the legacy blocking v0 path.
    Legacy,
}

/// Bind `addr` and start the reactor: `workers + 2` threads total.
pub fn spawn(coord: Arc<Coordinator>, addr: &str, cfg: ReactorConfig) -> Result<ReactorHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("resolving bound address")?;
    let gauges = Arc::new(ReactorGauges::default());
    let workers = cfg.workers.max(1);

    let (accept_tx, accept_rx) = mpsc::channel::<TcpStream>();
    let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let jobs_rx = Arc::new(Mutex::new(jobs_rx));

    let mut threads = Vec::with_capacity(workers + 2);
    for i in 0..workers {
        let coord2 = Arc::clone(&coord);
        let jobs2 = Arc::clone(&jobs_rx);
        let done2 = done_tx.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("velm-reactor-worker-{i}"))
                .spawn(move || worker_loop(coord2, jobs2, done2))
                .context("spawning reactor worker")?,
        );
    }
    drop(done_tx); // the poll loop detects worker death via Disconnected

    let max_conns = cfg.max_conns;
    threads.push(
        std::thread::Builder::new()
            .name("velm-reactor-accept".into())
            .spawn(move || accept_loop(listener, accept_tx, max_conns))
            .context("spawning reactor accept thread")?,
    );

    let gauges2 = Arc::clone(&gauges);
    let read_timeout = cfg.read_timeout;
    threads.push(
        std::thread::Builder::new()
            .name("velm-reactor-poll".into())
            .spawn(move || poll_loop(coord, accept_rx, jobs_tx, done_rx, read_timeout, gauges2))
            .context("spawning reactor poll thread")?,
    );

    Ok(ReactorHandle { addr: local, gauges, workers, threads })
}

/// Accept thread: the only place that blocks on the listener. Sockets
/// go nonblocking before the poll loop ever sees them.
fn accept_loop(listener: TcpListener, tx: mpsc::Sender<TcpStream>, max: Option<usize>) {
    let mut accepted = 0usize;
    loop {
        if let Some(m) = max {
            if accepted >= m {
                return; // dropping `tx` tells the poll loop to drain
            }
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true); // request/reply: defeat Nagle
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                accepted += 1;
                if tx.send(stream).is_err() {
                    return; // poll loop is gone
                }
            }
            // Transient accept failures (e.g. the peer aborting in the
            // backlog) should not kill the listener.
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Worker: pull one job, answer it, push encoded completion frames.
/// The shared-receiver lock is held only for the duration of `recv`.
fn worker_loop(
    coord: Arc<Coordinator>,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    done_tx: mpsc::Sender<Done>,
) {
    loop {
        let job = match jobs.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // poll loop dropped the sender: drain done
        };
        let Job { conn, corr, fifo, req } = job;
        match (corr, req) {
            (Some(corr), Request::BatchStream { rows }) => {
                stream_batch(&coord, conn, corr, fifo, rows, &done_tx);
            }
            (corr, req) => {
                let resp = coord.handle(req);
                let bytes = respond_bytes(corr, &resp);
                let _ = done_tx.send(Done { conn, bytes, last: true, fifo });
            }
        }
    }
}

/// Streamed batch: submit once, then emit one `R_STREAM_ROW` per row
/// in *completion* order as dies finish, closing with `R_STREAM_END`
/// (rows emitted + total conversion passes). DESIGN.md §20.
fn stream_batch(
    coord: &Coordinator,
    conn: u64,
    corr: u64,
    fifo: bool,
    rows: Vec<crate::protocol::PredictRow>,
    done_tx: &mpsc::Sender<Done>,
) {
    let rxs = match coord.submit_batch(&rows) {
        Ok(rxs) => rxs,
        Err(e) => {
            let bytes = respond_bytes(Some(corr), &Response::Error(format!("{e:#}")));
            let _ = done_tx.send(Done { conn, bytes, last: true, fifo });
            return;
        }
    };
    let mut pending: Vec<Option<mpsc::Receiver<ClassifyResponse>>> =
        rxs.into_iter().map(Some).collect();
    let mut open = pending.len();
    let mut emitted: u32 = 0;
    let mut passes: u64 = 0;
    while open > 0 {
        let mut progressed = false;
        for (i, slot) in pending.iter_mut().enumerate() {
            let Some(rx) = slot else { continue };
            match rx.try_recv() {
                Ok(resp) => {
                    passes += resp.passes as u64;
                    let (ty, payload) =
                        frame::encode_stream_row(corr, i as u32, &resp.to_prediction());
                    let bytes = frame_or_error(ty, &payload, Some(corr));
                    let _ = done_tx.send(Done { conn, bytes, last: false, fifo: false });
                    emitted += 1;
                    *slot = None;
                    open -= 1;
                    progressed = true;
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    // A die dropped the row mid-flight; the end frame's
                    // row count tells the client how many arrived.
                    *slot = None;
                    open -= 1;
                    progressed = true;
                }
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let (ty, payload) = frame::encode_stream_end(corr, emitted, passes);
    let bytes = frame_or_error(ty, &payload, Some(corr));
    let _ = done_tx.send(Done { conn, bytes, last: true, fifo });
}

/// Encode `resp` as a bare or correlation-wrapped reply frame.
fn respond_bytes(corr: Option<u64>, resp: &Response) -> Vec<u8> {
    let (ty, payload) = match corr {
        Some(c) => frame::encode_correlated_response(c, resp),
        None => frame::encode_response(resp),
    };
    frame_or_error(ty, &payload, corr)
}

/// Render a frame, degrading an oversize payload to a (small) typed
/// error so the connection keeps its framing instead of dying.
fn frame_or_error(ty: u8, payload: &[u8], corr: Option<u64>) -> Vec<u8> {
    match frame::frame_bytes(ty, payload) {
        Ok(b) => b,
        Err(_) => {
            let resp = Response::Error(format!(
                "reply exceeds the {} MiB frame cap",
                frame::MAX_FRAME_LEN / (1024 * 1024)
            ));
            let (ty2, p2) = match corr {
                Some(c) => frame::encode_correlated_response(c, &resp),
                None => frame::encode_response(&resp),
            };
            frame::frame_bytes(ty2, &p2).expect("error frames are small")
        }
    }
}

/// The poll loop: sole owner of the connection table. Every iteration
/// admits new sockets, drains completions into write buffers, services
/// each connection's nonblocking reads/writes, and reaps idle peers.
fn poll_loop(
    coord: Arc<Coordinator>,
    accept_rx: mpsc::Receiver<TcpStream>,
    jobs_tx: mpsc::Sender<Job>,
    done_rx: mpsc::Receiver<Done>,
    read_timeout: Option<Duration>,
    gauges: Arc<ReactorGauges>,
) {
    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut legacy: Vec<JoinHandle<()>> = Vec::new();
    let mut next_id: u64 = 0;
    let mut accept_open = true;
    let mut peak_conns = 0usize;
    let mut peak_in_flight = 0usize;
    loop {
        let mut progress = false;
        let now = Instant::now();
        // 1. admit fresh sockets
        while accept_open {
            match accept_rx.try_recv() {
                Ok(stream) => {
                    conns.insert(next_id, Conn::new(stream, now));
                    next_id += 1;
                    progress = true;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => accept_open = false,
            }
        }
        // 2. drain completions into write buffers
        while let Ok(done) = done_rx.try_recv() {
            progress = true;
            // the connection may have died while its job was in flight
            let Some(conn) = conns.get_mut(&done.conn) else { continue };
            conn.write_buf.extend_from_slice(&done.bytes);
            conn.last_activity = now;
            if done.last {
                if done.fifo {
                    conn.fifo_busy = false;
                    pump_fifo(done.conn, conn, &jobs_tx);
                } else {
                    conn.in_flight = conn.in_flight.saturating_sub(1);
                }
            }
        }
        // 3. service every connection
        let ids: Vec<u64> = conns.keys().copied().collect();
        for id in ids {
            let conn = conns.get_mut(&id).expect("id harvested this iteration");
            let (verdict, moved) = service_conn(id, conn, &coord, &jobs_tx, now);
            progress |= moved;
            match verdict {
                Verdict::Keep => {
                    if let Some(timeout) = read_timeout {
                        if conn.reapable(now, timeout) {
                            conns.remove(&id);
                            // relaxed-ok: monotone observability counter;
                            // no reader orders other state by it.
                            gauges.reaped.fetch_add(1, Ordering::Relaxed);
                            progress = true;
                        }
                    }
                }
                Verdict::Close => {
                    conns.remove(&id);
                    progress = true;
                }
                Verdict::Legacy => {
                    let conn = conns.remove(&id).expect("id harvested this iteration");
                    // relaxed-ok: monotone observability counter;
                    // no reader orders other state by it.
                    gauges.legacy_conns.fetch_add(1, Ordering::Relaxed);
                    progress = true;
                    if conn.stream.set_nonblocking(false).is_ok() {
                        let coord2 = Arc::clone(&coord);
                        let (stream, prefix) = (conn.stream, conn.read_buf);
                        if let Ok(h) = std::thread::Builder::new()
                            .name("velm-v0-conn".into())
                            .spawn(move || super::server::serve_v0_conn(coord2, stream, prefix))
                        {
                            legacy.push(h);
                        }
                    }
                }
            }
        }
        // 4. refresh gauges (single writer: this loop)
        let in_flight: usize = conns.values().map(Conn::depth).sum();
        peak_conns = peak_conns.max(conns.len());
        peak_in_flight = peak_in_flight.max(in_flight);
        // relaxed-ok: observability mirrors of poll-loop-local state;
        // readers (tests, bench, operators) tolerate a stale value and
        // order nothing by them.
        gauges.open_conns.store(conns.len(), Ordering::Relaxed);
        gauges.peak_conns.store(peak_conns, Ordering::Relaxed);
        gauges.in_flight.store(in_flight, Ordering::Relaxed);
        gauges.peak_in_flight.store(peak_in_flight, Ordering::Relaxed);
        if !accept_open && conns.is_empty() {
            break; // bounded serve drained (accept thread exited)
        }
        if !progress {
            // nothing readable, writable or completed: nap briefly
            // instead of spinning a core
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    drop(jobs_tx); // workers drain outstanding jobs and exit
    for h in legacy {
        let _ = h.join();
    }
}

/// Dispatch the next queued uncorrelated request if the slot is free.
fn pump_fifo(id: u64, conn: &mut Conn, jobs_tx: &mpsc::Sender<Job>) {
    if conn.fifo_busy {
        return;
    }
    let Some(req) = conn.fifo.pop_front() else { return };
    conn.fifo_busy = true;
    if jobs_tx.send(Job { conn: id, corr: None, fifo: true, req }).is_err() {
        conn.fifo_busy = false;
        queue_response(conn, None, &Response::Error("reactor is shutting down".into()));
    }
}

/// Append one encoded reply frame to the connection's write buffer.
fn queue_response(conn: &mut Conn, corr: Option<u64>, resp: &Response) {
    let bytes = respond_bytes(corr, resp);
    conn.write_buf.extend_from_slice(&bytes);
}

/// One connection's turn: nonblocking read into the buffer, cut and
/// dispatch complete frames, then flush as much of the write buffer as
/// the socket accepts. Returns the verdict plus whether anything moved.
fn service_conn(
    id: u64,
    conn: &mut Conn,
    coord: &Coordinator,
    jobs_tx: &mpsc::Sender<Job>,
    now: Instant,
) -> (Verdict, bool) {
    let mut progress = false;
    // read: drain the socket into the partial-frame buffer
    let mut tmp = [0u8; 8192];
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => return (Verdict::Close, true), // peer hung up
            Ok(n) => {
                conn.read_buf.extend_from_slice(&tmp[..n]);
                conn.last_activity = now;
                progress = true;
                if n < tmp.len() {
                    break; // likely drained; next iteration catches more
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return (Verdict::Close, true),
        }
    }
    // sniff: the first byte selects v1 (stay here) or v0 (legacy path)
    if !conn.sniffed {
        match conn.read_buf.first() {
            Some(&b) if b == frame::FRAME_MAGIC => conn.sniffed = true,
            Some(_) => return (Verdict::Legacy, true),
            None => {}
        }
    }
    // parse: cut complete frames out of the buffer and dispatch
    if conn.sniffed && !conn.closing {
        loop {
            match frame::take_frame(&conn.read_buf) {
                // bad magic or oversize: the stream is desynced beyond
                // recovery — no reply could be framed reliably
                Err(_) => return (Verdict::Close, true),
                Ok(None) => break, // partial frame: wait for more bytes
                Ok(Some((ty, payload, used))) => {
                    conn.read_buf.drain(..used);
                    progress = true;
                    if !handle_frame(id, conn, ty, &payload, coord, jobs_tx) {
                        break; // quit: flush and close below
                    }
                }
            }
        }
    }
    // write: flush as much as the socket accepts, tracking the offset
    while conn.write_pos < conn.write_buf.len() {
        match conn.stream.write(&conn.write_buf[conn.write_pos..]) {
            Ok(0) => return (Verdict::Close, true),
            Ok(n) => {
                conn.write_pos += n;
                conn.last_activity = now;
                progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return (Verdict::Close, true),
        }
    }
    if conn.write_pos > 0 && conn.write_pos == conn.write_buf.len() {
        conn.write_buf.clear();
        conn.write_pos = 0;
    }
    if conn.closing && conn.idle() && conn.write_buf.is_empty() {
        return (Verdict::Close, true); // quit acknowledged, all flushed
    }
    (Verdict::Keep, progress)
}

/// Route one decoded frame: correlated envelopes dispatch immediately
/// (many in flight), Hello binds the scope inline, quit marks the
/// connection closing, everything else queues on the strict FIFO.
/// Returns false when reading should stop (quit).
fn handle_frame(
    id: u64,
    conn: &mut Conn,
    ty: u8,
    payload: &[u8],
    coord: &Coordinator,
    jobs_tx: &mpsc::Sender<Job>,
) -> bool {
    if ty == frame::T_CORR {
        match frame::decode_correlated_request(payload) {
            Err(msg) => queue_response(conn, None, &Response::Error(msg)),
            Ok((corr, req)) => {
                if let Some(msg) = conn.scope.refusal(&req) {
                    queue_response(conn, Some(corr), &Response::Error(msg));
                } else {
                    conn.in_flight += 1;
                    let job = Job { conn: id, corr: Some(corr), fifo: false, req };
                    if jobs_tx.send(job).is_err() {
                        conn.in_flight -= 1;
                        queue_response(
                            conn,
                            Some(corr),
                            &Response::Error("reactor is shutting down".into()),
                        );
                    }
                }
            }
        }
        return true;
    }
    match frame::decode_request(ty, payload) {
        Err(msg) => queue_response(conn, None, &Response::Error(msg)),
        Ok(None) => {
            conn.closing = true;
            return false;
        }
        Ok(Some(Request::Hello { token })) => match coord.resolve_token(&token) {
            Some(scope) => {
                let tenants = scope.listing();
                conn.scope = scope;
                queue_response(conn, None, &Response::HelloOk { tenants });
            }
            None => queue_response(conn, None, &Response::Error(UNKNOWN_TOKEN_MSG.into())),
        },
        Ok(Some(req)) => {
            if let Some(msg) = conn.scope.refusal(&req) {
                queue_response(conn, None, &Response::Error(msg));
            } else {
                conn.fifo.push_back(req);
                pump_fifo(id, conn, jobs_tx);
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auth_tokens_parse_into_scopes() {
        let table = parse_auth_tokens(&[
            "root=*".to_string(),
            "lab= alpha , beta ".to_string(),
        ])
        .unwrap();
        assert_eq!(table.len(), 2);
        assert_eq!(table["root"], Scope::Unrestricted);
        let Scope::Tenants(set) = &table["lab"] else { panic!("scoped token") };
        assert!(set.contains("alpha") && set.contains("beta"));
        assert_eq!(table["lab"].listing(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(table["root"].listing(), vec!["*".to_string()]);
        // empty config = empty table (no handshake possible)
        assert!(parse_auth_tokens(&[]).unwrap().is_empty());
    }

    #[test]
    fn malformed_auth_tokens_are_refused() {
        for bad in ["no-equals", "=alpha", "tok=", "tok=a,,b", " =x"] {
            assert!(
                parse_auth_tokens(&[bad.to_string()]).is_err(),
                "entry '{bad}' must be refused"
            );
        }
        let dup = ["t=*".to_string(), "t=alpha".to_string()];
        let err = parse_auth_tokens(&dup).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn scope_gates_mutating_verbs_only() {
        let mut set = BTreeSet::new();
        set.insert("mine".to_string());
        let scoped = Scope::Tenants(set);
        // writes to the granted tenant pass
        assert!(scoped
            .refusal(&Request::TenantUpdate {
                name: "mine".into(),
                features: vec![],
                targets: vec![],
            })
            .is_none());
        // writes to any other tenant are refused
        let msg = scoped
            .refusal(&Request::Unregister { name: "other".into() })
            .expect("out-of-scope write refused");
        assert!(msg.contains("outside this connection's scope"), "{msg}");
        assert!(scoped.refusal(&Request::Register {
            name: "other".into(),
            dataset: "d".into(),
            seed: 1,
        }).is_some());
        // admin verbs need an unrestricted scope
        assert!(scoped.refusal(&Request::Drain { die: 0 }).is_some());
        assert!(Scope::Unrestricted.refusal(&Request::Drain { die: 0 }).is_none());
        // reads stay open: scoping guards writes, not predictions
        assert!(scoped
            .refusal(&Request::Predict { tenant: Some("other".into()), features: vec![] })
            .is_none());
        assert!(scoped.refusal(&Request::Stats).is_none());
    }

    #[test]
    fn oversize_replies_degrade_to_typed_errors() {
        // A payload over the frame cap must not kill the framing: the
        // helper swaps in a small typed error, correlated or not.
        let huge = vec![0u8; frame::MAX_FRAME_LEN as usize + 1];
        let bytes = frame_or_error(frame::R_CORR, &huge, Some(7));
        let (ty, payload) = frame::read_frame(&mut std::io::BufReader::new(&bytes[..]))
            .unwrap()
            .expect("a frame");
        assert_eq!(ty, frame::R_CORR);
        let (corr, resp) = frame::decode_correlated_response(&payload).unwrap();
        assert_eq!(corr, 7);
        assert!(matches!(resp, Response::Error(e) if e.contains("frame cap")));
    }
}
