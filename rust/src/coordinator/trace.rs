//! Flight recorder (DESIGN.md §16): a fixed-size ring of the last N
//! completed request traces, always on.
//!
//! Hot-path cost is one relaxed `fetch_add` to claim a slot plus one
//! *uncontended* `try_lock` to write it — a worker never blocks on the
//! recorder. If a dump (or a lapped writer) holds the slot at that
//! instant the trace is dropped, not queued: the recorder is a
//! diagnostic window, not a reliable log, and the serving path always
//! wins the trade.

use crate::sync::{AtomicU64, Mutex, Ordering, TryLockError};

use crate::protocol::stats::TraceEntry;

/// Ring capacity used by `Metrics` (last 512 requests — enough to hold
/// several max-size batches from every die without measurable memory).
pub const DEFAULT_TRACE_CAPACITY: usize = 512;

/// Lock-free-on-the-hot-path ring buffer of completed request traces.
pub struct FlightRecorder {
    /// Monotone claim counter; slot = claim % capacity.
    head: AtomicU64,
    slots: Vec<Mutex<Option<TraceEntry>>>,
}

impl FlightRecorder {
    /// A recorder holding the last `capacity.max(1)` traces.
    pub fn new(capacity: usize) -> Self {
        FlightRecorder {
            head: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total traces claimed since start (including any dropped to
    /// slot contention).
    pub fn recorded(&self) -> u64 {
        // relaxed-ok: standalone monotone counter read; no other
        // memory is inferred from its value.
        self.head.load(Ordering::Relaxed)
    }

    /// Record one completed trace (best effort, never blocks).
    pub fn push(&self, entry: TraceEntry) {
        // relaxed-ok: `head` only allocates slot numbers. The entry
        // itself is published by the slot mutex (lock/unlock is an
        // acquire/release pair), and a Release fetch_add here would
        // not order the *subsequent* slot write anyway. Dump readers
        // tolerate a `head` that lags or leads the slot contents.
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        match self.slots[slot].try_lock() {
            Ok(mut guard) => *guard = Some(entry),
            // A previous writer panicked mid-store: the slot is still
            // structurally sound (it holds either their entry or the
            // older occupant), so clear the poison by overwriting.
            Err(TryLockError::Poisoned(poisoned)) => *poisoned.into_inner() = Some(entry),
            // Contended slot: drop the trace rather than stall a worker.
            Err(TryLockError::WouldBlock) => {}
        }
    }

    /// The most recent `last` traces, newest first. Entries a writer
    /// is lapping mid-dump may surface as their older occupant (or be
    /// skipped) — the dump is a consistent-enough diagnostic window,
    /// never a blocking snapshot.
    pub fn dump(&self, last: usize) -> Vec<TraceEntry> {
        // relaxed-ok: `head` is only a slot-count hint here. Entry
        // *contents* are synchronized by each slot's mutex, so a stale
        // head can at worst make the dump visit an empty or older
        // slot — outcomes the dump contract already allows.
        let head = self.head.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let n = (last.min(self.slots.len()) as u64).min(head);
        let mut out = Vec::with_capacity(n as usize);
        for i in 0..n {
            let seq = head - 1 - i;
            let slot = (seq % cap) as usize;
            // A poisoned slot still holds a structurally sound entry
            // (the panicked writer either completed its `*guard =` or
            // left the older occupant): recover it rather than blind
            // the diagnostic window.
            let guard = match self.slots[slot].lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            if let Some(entry) = guard.as_ref() {
                out.push(entry.clone());
            }
        }
        out
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.recorded())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::stats::TraceOutcome;

    fn entry(id: u64) -> TraceEntry {
        TraceEntry {
            id,
            tenant: None,
            die: 0,
            pjrt: false,
            passes: 1,
            queue_us: 1,
            batch_us: 1,
            compute_us: 1,
            total_us: 3,
            outcome: TraceOutcome::Ok,
        }
    }

    #[test]
    fn dump_returns_newest_first_and_respects_limit() {
        let r = FlightRecorder::new(8);
        for id in 0..5 {
            r.push(entry(id));
        }
        let all = r.dump(100);
        assert_eq!(all.iter().map(|e| e.id).collect::<Vec<_>>(), vec![4, 3, 2, 1, 0]);
        let two = r.dump(2);
        assert_eq!(two.iter().map(|e| e.id).collect::<Vec<_>>(), vec![4, 3]);
        assert!(r.dump(0).is_empty());
    }

    #[test]
    fn ring_wraps_and_keeps_only_the_last_capacity() {
        let r = FlightRecorder::new(4);
        for id in 0..10 {
            r.push(entry(id));
        }
        assert_eq!(r.recorded(), 10);
        let ids: Vec<u64> = r.dump(100).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![9, 8, 7, 6]);
    }

    #[test]
    fn empty_recorder_dumps_nothing() {
        let r = FlightRecorder::new(4);
        assert!(r.dump(4).is_empty());
        assert_eq!(r.recorded(), 0);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let r = FlightRecorder::new(0);
        assert_eq!(r.capacity(), 1);
        r.push(entry(1));
        r.push(entry(2));
        let ids: Vec<u64> = r.dump(10).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![2]);
    }

    #[test]
    fn ring_never_allocates_after_startup() {
        // `--trace-cap` sizes the ring once at construction; lapping it
        // many times over must neither grow the slot vector nor move it
        let r = FlightRecorder::new(8);
        assert_eq!(r.capacity(), 8, "configured capacity is honoured");
        let slots_ptr = r.slots.as_ptr();
        let slots_cap = r.slots.capacity();
        for id in 0..100 {
            r.push(entry(id));
            if id % 10 == 0 {
                let _ = r.dump(8);
            }
        }
        assert_eq!(r.capacity(), 8);
        assert_eq!(r.slots.as_ptr(), slots_ptr, "slot storage must not move");
        assert_eq!(r.slots.capacity(), slots_cap, "slot storage must not grow");
        // overwrites land in place even when entries carry owned data
        for id in 0..16 {
            r.push(TraceEntry { tenant: Some("t".into()), ..entry(id) });
        }
        assert_eq!(r.slots.as_ptr(), slots_ptr);
        assert_eq!(r.slots.capacity(), slots_cap);
        let ids: Vec<u64> = r.dump(100).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![15, 14, 13, 12, 11, 10, 9, 8]);
    }

    #[test]
    fn poisoned_slot_is_recovered_not_skipped() {
        let r = std::sync::Arc::new(FlightRecorder::new(1));
        r.push(entry(7));
        let r2 = std::sync::Arc::clone(&r);
        // Poison the single slot: panic while holding its guard.
        let poisoner = std::thread::spawn(move || {
            let _guard = r2.slots[0].lock().unwrap();
            panic!("poison the slot");
        })
        .join();
        assert!(poisoner.is_err(), "poisoner must have panicked");
        let ids: Vec<u64> = r.dump(1).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![7], "dump must recover the poisoned entry");
        r.push(entry(8));
        let ids: Vec<u64> = r.dump(1).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![8], "push must clear the poison by overwriting");
    }

    #[test]
    fn concurrent_pushes_and_dumps_never_panic() {
        // Miri executes this interpreter-slow; shrink the schedule but
        // keep the shape (4 writers racing 1 dumper over a small ring).
        const PUSHES: u64 = if cfg!(miri) { 25 } else { 500 };
        const DUMPS: usize = if cfg!(miri) { 10 } else { 200 };
        let r = std::sync::Arc::new(FlightRecorder::new(16));
        std::thread::scope(|s| {
            for t in 0..4 {
                let r = std::sync::Arc::clone(&r);
                s.spawn(move || {
                    for i in 0..PUSHES {
                        r.push(entry(t * 1000 + i));
                    }
                });
            }
            let r = std::sync::Arc::clone(&r);
            s.spawn(move || {
                for _ in 0..DUMPS {
                    let d = r.dump(16);
                    assert!(d.len() <= 16);
                }
            });
        });
        assert_eq!(r.recorded(), 4 * PUSHES);
    }
}
