//! Reusable lock-free log2 latency histogram (DESIGN.md §16).
//!
//! Factored out of `Metrics` so every distribution the observability
//! layer tracks — end-to-end latency, queue-wait, batch-wait, compute,
//! per-tenant latency — shares one implementation and one percentile
//! estimator. Recording is two relaxed atomic adds; all math happens
//! at snapshot time over a single copy of the buckets, so the three
//! percentiles of one [`StageStats`] are always mutually monotone even
//! under concurrent recording.

use std::time::Duration;

use crate::sync::{AtomicU64, Ordering};

use crate::protocol::stats::StageStats;

/// Number of log2 buckets: bucket i covers [2^i, 2^(i+1)) us.
pub const BUCKETS: usize = 32;

/// One latency distribution: 32 log2 buckets + a running sum.
/// Sub-microsecond samples are clamped to 1 us (bucket 0).
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl LatencyHist {
    // relaxed-ok: buckets and sum are independent monotone counters;
    // a snapshot racing a recorder may see the sum without the bucket
    // (or vice versa), which the exports tolerate — each StageStats is
    // computed from ONE bucket copy, so its percentiles stay mutually
    // monotone regardless of ordering.
    pub fn new() -> Self {
        LatencyHist::default()
    }

    /// Record one duration (clamped to >= 1 us).
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().max(1) as u64);
    }

    /// Record one sample in microseconds (0 is clamped to 1).
    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// One relaxed copy of the buckets (the unit of consistency).
    fn load(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// One relaxed copy of the buckets, for callers that window the
    /// histogram themselves: the governor's sliding-window p99 diffs
    /// two copies and feeds the delta to [`percentile_from`]
    /// (DESIGN.md §19).
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        self.load()
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.load().iter().sum()
    }

    /// Approximate percentile, interpolated within the bucket (see
    /// [`percentile_from`]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_from(&self.load(), p)
    }

    /// Mean sample, microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Reduce to exportable [`StageStats`]: one bucket copy feeds the
    /// count and all three percentiles, so `p50 <= p90 <= p99` holds
    /// even while writers are racing the snapshot.
    pub fn snapshot(&self) -> StageStats {
        let buckets = self.load();
        StageStats {
            count: buckets.iter().sum(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            p50_us: percentile_from(&buckets, 50.0),
            p90_us: percentile_from(&buckets, 90.0),
            p99_us: percentile_from(&buckets, 99.0),
        }
    }
}

/// Approximate percentile from a log2 histogram, interpolated within
/// the bucket: the k-th of `count` samples in bucket [2^i, 2^(i+1)) is
/// placed at `2^i * (1 + (k - 0.5)/count)` — uniform-within-bucket
/// assumption. (Reporting the upper bucket edge, as `Metrics` once
/// did, biases the estimate up to 2x high.)
pub fn percentile_from(buckets: &[u64; BUCKETS], p: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
    let mut acc = 0u64;
    for (i, &count) in buckets.iter().enumerate() {
        if count == 0 {
            continue;
        }
        if acc + count >= target {
            let k = (target - acc) as f64; // k-th sample inside this bucket
            let lower = (1u64 << i) as f64;
            let frac = ((k - 0.5) / count as f64).clamp(0.0, 1.0);
            return (lower + lower * frac).round() as u64;
        }
        acc += count;
    }
    1u64 << BUCKETS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_brackets_percentiles() {
        let h = LatencyHist::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 10);
        let p50 = h.percentile_us(50.0);
        assert!((128..256).contains(&p50), "p50 {p50}");
        let p99 = h.percentile_us(99.0);
        assert!((65536..131072).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn single_sample_interpolates_to_bucket_midpoint() {
        let h = LatencyHist::new();
        h.record(Duration::from_micros(3000)); // bucket [2048, 4096)
        assert_eq!(h.percentile_us(50.0), 3072);
    }

    #[test]
    fn zero_samples_clamp_to_one_microsecond() {
        let h = LatencyHist::new();
        h.record_us(0);
        h.record(Duration::from_nanos(10));
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile_us(99.0), 1);
        assert!((h.mean_us() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_internally_monotone_and_complete() {
        let h = LatencyHist::new();
        for us in [100u64, 200, 400, 800, 1600] {
            h.record_us(us);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_us, 3100);
        assert!(s.p50_us <= s.p90_us && s.p90_us <= s.p99_us, "{s:?}");
        assert!((s.mean_us() - 620.0).abs() < 1e-12);
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(50.0), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.snapshot(), StageStats::default());
    }

    // --- exact-reference oracle tests: the log2 + interpolation
    // estimate vs a sorted vector of the same samples ---

    /// The k-th order statistic the estimator targets — the same
    /// `ceil(p/100 * n).max(1)` rank, answered exactly.
    fn oracle(samples: &mut Vec<u64>, p: f64) -> u64 {
        samples.sort_unstable();
        let target = ((p / 100.0) * samples.len() as f64).ceil().max(1.0) as usize;
        samples[target - 1]
    }

    fn hist_of(samples: &[u64]) -> LatencyHist {
        let h = LatencyHist::new();
        for &us in samples {
            h.record_us(us);
        }
        h
    }

    /// The estimate must land inside the oracle sample's log2 bucket:
    /// never below its lower edge, never above its upper edge — the
    /// tightest bound within-bucket interpolation can honour.
    fn assert_within_bucket(est: u64, exact: u64, what: &str) {
        let lower = 1u64 << (63 - exact.max(1).leading_zeros());
        assert!(
            est >= lower && est <= lower * 2,
            "{what}: estimate {est} outside the oracle bucket [{lower}, {}] of {exact}",
            lower * 2
        );
    }

    #[test]
    fn uniform_distribution_tracks_the_sorted_oracle() {
        // deterministic LCG spread over [1, 10_000] us
        let mut x = 0x2545_F491_4F6C_DD1Du64;
        let samples: Vec<u64> = (0..1000)
            .map(|_| {
                x = x
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                1 + (x >> 33) % 10_000
            })
            .collect();
        let h = hist_of(&samples);
        for p in [50.0, 90.0, 99.0] {
            let est = h.percentile_us(p);
            let exact = oracle(&mut samples.clone(), p);
            assert_within_bucket(est, exact, &format!("uniform p{p}"));
        }
    }

    #[test]
    fn bimodal_distribution_tracks_the_sorted_oracle() {
        // 900 fast rows at 80 us, 100 slow at 20_000 us: p50 must read
        // the fast mode and p99 the slow one — the shape the windowed
        // SLO tracker alarms on
        let mut samples = vec![80u64; 900];
        samples.extend(std::iter::repeat(20_000u64).take(100));
        let h = hist_of(&samples);
        let p50 = h.percentile_us(50.0);
        assert_within_bucket(p50, oracle(&mut samples.clone(), 50.0), "bimodal p50");
        let p99 = h.percentile_us(99.0);
        let exact = oracle(&mut samples.clone(), 99.0);
        assert_eq!(exact, 20_000);
        assert_within_bucket(p99, exact, "bimodal p99");
        assert!(p99 > 8 * p50, "p99 {p99} must expose the slow mode over p50 {p50}");
    }

    #[test]
    fn single_bucket_distribution_is_exact_to_interpolation() {
        // all samples inside [1024, 2048): the only error source left
        // is within-bucket interpolation, bounded by the bucket width
        let samples: Vec<u64> = (0..100).map(|i| 1024 + 10 * i).collect();
        let h = hist_of(&samples);
        for p in [50.0, 90.0, 99.0] {
            let est = h.percentile_us(p);
            let exact = oracle(&mut samples.clone(), p);
            assert!((1024..2048).contains(&est), "p{p} estimate {est} left the bucket");
            assert!(
                est.abs_diff(exact) < 1024,
                "p{p}: |{est} - {exact}| must stay under one bucket width"
            );
        }
    }

    #[test]
    fn bucket_counts_expose_one_windowable_copy() {
        let h = LatencyHist::new();
        h.record_us(1); // bucket 0
        h.record_us(3); // bucket 1
        h.record_us(3000); // bucket 11
        let before = h.bucket_counts();
        assert_eq!((before[0], before[1], before[11]), (1, 1, 1));
        assert_eq!(before.iter().sum::<u64>(), 3);
        // the governor's windowed view: diff two copies and feed the
        // delta to the shared estimator
        h.record_us(3000);
        let after = h.bucket_counts();
        let window: [u64; BUCKETS] = std::array::from_fn(|i| after[i] - before[i]);
        assert_eq!(percentile_from(&window, 50.0), 3072);
    }
}
