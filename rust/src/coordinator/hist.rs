//! Reusable lock-free log2 latency histogram (DESIGN.md §16).
//!
//! Factored out of `Metrics` so every distribution the observability
//! layer tracks — end-to-end latency, queue-wait, batch-wait, compute,
//! per-tenant latency — shares one implementation and one percentile
//! estimator. Recording is two relaxed atomic adds; all math happens
//! at snapshot time over a single copy of the buckets, so the three
//! percentiles of one [`StageStats`] are always mutually monotone even
//! under concurrent recording.

use std::time::Duration;

use crate::sync::{AtomicU64, Ordering};

use crate::protocol::stats::StageStats;

/// Number of log2 buckets: bucket i covers [2^i, 2^(i+1)) us.
pub const BUCKETS: usize = 32;

/// One latency distribution: 32 log2 buckets + a running sum.
/// Sub-microsecond samples are clamped to 1 us (bucket 0).
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl LatencyHist {
    // relaxed-ok: buckets and sum are independent monotone counters;
    // a snapshot racing a recorder may see the sum without the bucket
    // (or vice versa), which the exports tolerate — each StageStats is
    // computed from ONE bucket copy, so its percentiles stay mutually
    // monotone regardless of ordering.
    pub fn new() -> Self {
        LatencyHist::default()
    }

    /// Record one duration (clamped to >= 1 us).
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().max(1) as u64);
    }

    /// Record one sample in microseconds (0 is clamped to 1).
    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// One relaxed copy of the buckets (the unit of consistency).
    fn load(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.load().iter().sum()
    }

    /// Approximate percentile, interpolated within the bucket (see
    /// [`percentile_from`]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_from(&self.load(), p)
    }

    /// Mean sample, microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Reduce to exportable [`StageStats`]: one bucket copy feeds the
    /// count and all three percentiles, so `p50 <= p90 <= p99` holds
    /// even while writers are racing the snapshot.
    pub fn snapshot(&self) -> StageStats {
        let buckets = self.load();
        StageStats {
            count: buckets.iter().sum(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            p50_us: percentile_from(&buckets, 50.0),
            p90_us: percentile_from(&buckets, 90.0),
            p99_us: percentile_from(&buckets, 99.0),
        }
    }
}

/// Approximate percentile from a log2 histogram, interpolated within
/// the bucket: the k-th of `count` samples in bucket [2^i, 2^(i+1)) is
/// placed at `2^i * (1 + (k - 0.5)/count)` — uniform-within-bucket
/// assumption. (Reporting the upper bucket edge, as `Metrics` once
/// did, biases the estimate up to 2x high.)
pub fn percentile_from(buckets: &[u64; BUCKETS], p: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
    let mut acc = 0u64;
    for (i, &count) in buckets.iter().enumerate() {
        if count == 0 {
            continue;
        }
        if acc + count >= target {
            let k = (target - acc) as f64; // k-th sample inside this bucket
            let lower = (1u64 << i) as f64;
            let frac = ((k - 0.5) / count as f64).clamp(0.0, 1.0);
            return (lower + lower * frac).round() as u64;
        }
        acc += count;
    }
    1u64 << BUCKETS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_brackets_percentiles() {
        let h = LatencyHist::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 10);
        let p50 = h.percentile_us(50.0);
        assert!((128..256).contains(&p50), "p50 {p50}");
        let p99 = h.percentile_us(99.0);
        assert!((65536..131072).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn single_sample_interpolates_to_bucket_midpoint() {
        let h = LatencyHist::new();
        h.record(Duration::from_micros(3000)); // bucket [2048, 4096)
        assert_eq!(h.percentile_us(50.0), 3072);
    }

    #[test]
    fn zero_samples_clamp_to_one_microsecond() {
        let h = LatencyHist::new();
        h.record_us(0);
        h.record(Duration::from_nanos(10));
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile_us(99.0), 1);
        assert!((h.mean_us() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_internally_monotone_and_complete() {
        let h = LatencyHist::new();
        for us in [100u64, 200, 400, 800, 1600] {
            h.record_us(us);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_us, 3100);
        assert!(s.p50_us <= s.p90_us && s.p90_us <= s.p99_us, "{s:?}");
        assert!((s.mean_us() - 620.0).abs() < 1e-12);
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(50.0), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.snapshot(), StageStats::default());
    }
}
