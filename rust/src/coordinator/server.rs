//! TCP front end for the coordinator: transport + per-connection codec
//! negotiation, nothing else. The protocol itself — the typed
//! `Request`/`Response` vocabulary, the v0 ASCII line grammar and the
//! v1 length-prefixed frame layout — lives in [`crate::protocol`] and
//! is documented in DESIGN.md §15; dispatch lives in
//! [`Coordinator::handle`], the same entry point the in-process
//! [`crate::client::Client`] uses, so wire and in-process callers
//! share one code path.
//!
//! Per connection (std::net, one thread each — no tokio in the offline
//! vendor set):
//!
//!   1. apply `SystemConfig::read_timeout` so an idle or dead client is
//!      disconnected instead of pinning its thread forever;
//!   2. sniff the first byte: [`frame::FRAME_MAGIC`] selects the v1
//!      [`FrameCodec`], anything else (every ASCII command letter) the
//!      v0 [`LineCodec`] — that is the entire version negotiation;
//!   3. loop: decode a request, dispatch through `Coordinator::handle`,
//!      encode the response. Malformed input answers `ERR ...` (v0) or
//!      an error frame (v1) without dropping the connection; QUIT, EOF,
//!      an I/O error or the read timeout end it.
//!
//! [`frame::FRAME_MAGIC`]: crate::protocol::frame::FRAME_MAGIC

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::protocol::{line, Codec, Decoded, FrameCodec, LineCodec, Response};

use super::Coordinator;

/// Handle one v0 protocol line — the thin shim that keeps the historic
/// line surface (and its unit tests) alive over the typed dispatcher.
/// `None` means QUIT (close the connection).
pub fn handle_line(coord: &Coordinator, line: &str) -> Option<String> {
    match line::parse_line(line) {
        Decoded::Quit | Decoded::Eof => None,
        Decoded::Malformed(msg) => Some(format!("ERR {msg}")),
        Decoded::Request(req) => Some(line::format_response(&coord.handle(req))),
    }
}

fn serve_conn(coord: Arc<Coordinator>, stream: TcpStream) {
    let _ = stream.set_nodelay(true); // request/response pattern: defeat Nagle
    // dead-client hygiene: never let an idle connection pin this thread
    let _ = stream.set_read_timeout(coord.read_timeout);
    // codec negotiation: peek (don't consume) the first byte
    let mut first = [0u8; 1];
    let mut codec: Box<dyn Codec> = match stream.peek(&mut first) {
        Ok(0) | Err(_) => return, // closed or timed out before a byte arrived
        Ok(_) if first[0] == crate::protocol::frame::FRAME_MAGIC => Box::new(FrameCodec),
        Ok(_) => Box::new(LineCodec),
    };
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let resp = match codec.read_request(&mut reader) {
            Err(_) => break, // I/O error, or idle past the read timeout
            Ok(Decoded::Eof) | Ok(Decoded::Quit) => break,
            Ok(Decoded::Malformed(msg)) => Response::Error(msg),
            Ok(Decoded::Request(req)) => coord.handle(req),
        };
        if codec.write_response(&mut writer, &resp).is_err() {
            break;
        }
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7177"). Blocks the caller;
/// spawns one thread per connection.
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("velm serving on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let c = Arc::clone(&coord);
                std::thread::spawn(move || serve_conn(c, s));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Serve a bounded number of connections (for tests / examples), then
/// return. Binds to an ephemeral port and reports it via the return.
pub fn serve_n(coord: Arc<Coordinator>, conns: usize) -> Result<(std::net::SocketAddr, JoinHandleVec)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ephemeral")?;
    let addr = listener.local_addr()?;
    let mut handles = Vec::new();
    let accept_thread = std::thread::spawn(move || {
        let mut taken = Vec::new();
        for stream in listener.incoming().take(conns) {
            if let Ok(s) = stream {
                let c = Arc::clone(&coord);
                taken.push(std::thread::spawn(move || serve_conn(c, s)));
            }
        }
        for t in taken {
            let _ = t.join();
        }
    });
    handles.push(accept_thread);
    Ok((addr, JoinHandleVec(handles)))
}

/// Joinable bundle returned by [`serve_n`].
pub struct JoinHandleVec(pub Vec<std::thread::JoinHandle<()>>);

impl JoinHandleVec {
    pub fn join(self) {
        for h in self.0 {
            let _ = h.join();
        }
    }
}
