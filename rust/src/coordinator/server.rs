//! Line-protocol TCP front end for the coordinator (std::net, one thread
//! per connection — no tokio in the offline vendor set).
//!
//! Protocol (newline-terminated ASCII):
//!   `CLASSIFY x1,x2,...,xd`  ->  `OK <label> <score>` (the default head)
//!   `PREDICT <tenant> x1,..` ->  `OK <label> <score>` through the named
//!                                tenant's model (DESIGN.md §14): ±1
//!                                labels for binary, the argmax class
//!                                for multi-class, label 0 + the raw
//!                                score for regression
//!   `REGISTER <name> <dataset> [seed]` -> train + install a tenant
//!                                fleet-wide from a named dataset
//!                                (`digits`, `digits-binary`,
//!                                `brightness`, or any synth set)
//!   `UNREGISTER <name>`      ->  drop a tenant fleet-wide
//!   `MODELS`                 ->  `OK <tenant directory one-liner>`
//!   `STATS`                  ->  `OK <metrics one-liner>` (incl. per-tenant)
//!   `HEALTH`                 ->  `OK <per-die lifecycle gauges + fleet counters>`
//!   `DRAIN <die>`            ->  `OK draining die <die>` (recalibrated + re-admitted by the fleet manager)
//!   `PING`                   ->  `OK pong`
//!   `QUIT`                   ->  closes the connection
//! Errors come back as `ERR <reason>`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::registry::TenantSpec;

use super::Coordinator;

/// Parse a comma-separated feature list.
fn parse_features(text: &str) -> std::result::Result<Vec<f64>, String> {
    text.split(',')
        .map(|t| t.trim().parse::<f64>().map_err(|e| format!("bad features: {e}")))
        .collect()
}

/// Handle one protocol line. Exposed for unit testing without sockets.
pub fn handle_line(coord: &Coordinator, line: &str) -> Option<String> {
    let line = line.trim();
    if line.is_empty() {
        return Some("ERR empty command".into());
    }
    let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
    match cmd.to_ascii_uppercase().as_str() {
        "PING" => Some("OK pong".into()),
        "STATS" => Some(format!("OK {}", coord.metrics.report())),
        "HEALTH" => Some(format!("OK {}", coord.fleet_status())),
        "MODELS" => Some(format!("OK {}", coord.models())),
        "DRAIN" => match rest.trim().parse::<usize>() {
            Err(_) => Some(format!("ERR DRAIN wants a die index, got '{rest}'")),
            Ok(die) => match coord.drain_die(die) {
                Ok(()) => Some(format!("OK draining die {die}")),
                Err(e) => Some(format!("ERR {e:#}")),
            },
        },
        "QUIT" => None,
        "CLASSIFY" => match parse_features(rest) {
            Err(e) => Some(format!("ERR {e}")),
            Ok(f) => match coord.classify(f) {
                Ok(resp) => Some(format!("OK {} {:.6}", resp.label, resp.score)),
                Err(e) => Some(format!("ERR {e:#}")),
            },
        },
        "PREDICT" => {
            // PREDICT <tenant> x1,x2,...,xd
            let Some((tenant, feats)) = rest.trim().split_once(' ') else {
                return Some("ERR PREDICT wants: PREDICT <tenant> x1,x2,...".into());
            };
            match parse_features(feats.trim()) {
                Err(e) => Some(format!("ERR {e}")),
                Ok(f) => match coord.classify_tenant(Some(tenant.trim()), f) {
                    Ok(resp) => Some(format!("OK {} {:.6}", resp.label, resp.score)),
                    Err(e) => Some(format!("ERR {e:#}")),
                },
            }
        }
        "REGISTER" => {
            // REGISTER <name> <dataset> [seed]
            let mut parts = rest.split_whitespace();
            let (Some(name), Some(dataset)) = (parts.next(), parts.next()) else {
                return Some("ERR REGISTER wants: REGISTER <name> <dataset> [seed]".into());
            };
            let seed = match parts.next().map(|t| t.parse::<u64>()) {
                None => 1,
                Some(Ok(s)) => s,
                Some(Err(e)) => return Some(format!("ERR bad seed: {e}")),
            };
            match TenantSpec::from_dataset(name, dataset, seed, coord.d) {
                Err(e) => Some(format!("ERR {e}")),
                Ok(spec) => {
                    let task = spec.task;
                    match coord.register_tenant(spec) {
                        Ok(score) => Some(format!(
                            "OK registered {name} ({task}, mean train score {score:.4})"
                        )),
                        Err(e) => Some(format!("ERR {e:#}")),
                    }
                }
            }
        }
        "UNREGISTER" => {
            let name = rest.trim();
            if name.is_empty() {
                return Some("ERR UNREGISTER wants a tenant name".into());
            }
            match coord.unregister_tenant(name) {
                Ok(()) => Some(format!("OK unregistered {name}")),
                Err(e) => Some(format!("ERR {e:#}")),
            }
        }
        other => Some(format!("ERR unknown command {other}")),
    }
}

fn serve_conn(coord: Arc<Coordinator>, stream: TcpStream) {
    let _ = stream.set_nodelay(true); // request/response pattern: defeat Nagle
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match handle_line(&coord, &line) {
            Some(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            None => break, // QUIT
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7177"). Blocks the caller;
/// spawns one thread per connection.
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("velm serving on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let c = Arc::clone(&coord);
                std::thread::spawn(move || serve_conn(c, s));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Serve a bounded number of connections (for tests / examples), then
/// return. Binds to an ephemeral port and reports it via the return.
pub fn serve_n(coord: Arc<Coordinator>, conns: usize) -> Result<(std::net::SocketAddr, JoinHandleVec)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ephemeral")?;
    let addr = listener.local_addr()?;
    let mut handles = Vec::new();
    let accept_thread = std::thread::spawn(move || {
        let mut taken = Vec::new();
        for stream in listener.incoming().take(conns) {
            if let Ok(s) = stream {
                let c = Arc::clone(&coord);
                taken.push(std::thread::spawn(move || serve_conn(c, s)));
            }
        }
        for t in taken {
            let _ = t.join();
        }
    });
    handles.push(accept_thread);
    Ok((addr, JoinHandleVec(handles)))
}

/// Joinable bundle returned by [`serve_n`].
pub struct JoinHandleVec(pub Vec<std::thread::JoinHandle<()>>);

impl JoinHandleVec {
    pub fn join(self) {
        for h in self.0 {
            let _ = h.join();
        }
    }
}
