//! Line-protocol TCP front end for the coordinator (std::net, one thread
//! per connection — no tokio in the offline vendor set).
//!
//! Protocol (newline-terminated ASCII):
//!   `CLASSIFY x1,x2,...,xd`  ->  `OK <label> <score>`
//!   `STATS`                  ->  `OK <metrics one-liner>`
//!   `HEALTH`                 ->  `OK <per-die lifecycle gauges + fleet counters>`
//!   `DRAIN <die>`            ->  `OK draining die <die>` (recalibrated + re-admitted by the fleet manager)
//!   `PING`                   ->  `OK pong`
//!   `QUIT`                   ->  closes the connection
//! Errors come back as `ERR <reason>`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::Coordinator;

/// Handle one protocol line. Exposed for unit testing without sockets.
pub fn handle_line(coord: &Coordinator, line: &str) -> Option<String> {
    let line = line.trim();
    if line.is_empty() {
        return Some("ERR empty command".into());
    }
    let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
    match cmd.to_ascii_uppercase().as_str() {
        "PING" => Some("OK pong".into()),
        "STATS" => Some(format!("OK {}", coord.metrics.report())),
        "HEALTH" => Some(format!("OK {}", coord.fleet_status())),
        "DRAIN" => match rest.trim().parse::<usize>() {
            Err(_) => Some(format!("ERR DRAIN wants a die index, got '{rest}'")),
            Ok(die) => match coord.drain_die(die) {
                Ok(()) => Some(format!("OK draining die {die}")),
                Err(e) => Some(format!("ERR {e:#}")),
            },
        },
        "QUIT" => None,
        "CLASSIFY" => {
            let features: std::result::Result<Vec<f64>, _> =
                rest.split(',').map(|t| t.trim().parse::<f64>()).collect();
            match features {
                Err(e) => Some(format!("ERR bad features: {e}")),
                Ok(f) => match coord.classify(f) {
                    Ok(resp) => Some(format!("OK {} {:.6}", resp.label, resp.score)),
                    Err(e) => Some(format!("ERR {e:#}")),
                },
            }
        }
        other => Some(format!("ERR unknown command {other}")),
    }
}

fn serve_conn(coord: Arc<Coordinator>, stream: TcpStream) {
    let _ = stream.set_nodelay(true); // request/response pattern: defeat Nagle
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match handle_line(&coord, &line) {
            Some(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            None => break, // QUIT
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7177"). Blocks the caller;
/// spawns one thread per connection.
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("velm serving on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let c = Arc::clone(&coord);
                std::thread::spawn(move || serve_conn(c, s));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Serve a bounded number of connections (for tests / examples), then
/// return. Binds to an ephemeral port and reports it via the return.
pub fn serve_n(coord: Arc<Coordinator>, conns: usize) -> Result<(std::net::SocketAddr, JoinHandleVec)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ephemeral")?;
    let addr = listener.local_addr()?;
    let mut handles = Vec::new();
    let accept_thread = std::thread::spawn(move || {
        let mut taken = Vec::new();
        for stream in listener.incoming().take(conns) {
            if let Ok(s) = stream {
                let c = Arc::clone(&coord);
                taken.push(std::thread::spawn(move || serve_conn(c, s)));
            }
        }
        for t in taken {
            let _ = t.join();
        }
    });
    handles.push(accept_thread);
    Ok((addr, JoinHandleVec(handles)))
}

/// Joinable bundle returned by [`serve_n`].
pub struct JoinHandleVec(pub Vec<std::thread::JoinHandle<()>>);

impl JoinHandleVec {
    pub fn join(self) {
        for h in self.0 {
            let _ = h.join();
        }
    }
}
