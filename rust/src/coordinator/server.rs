//! TCP front end for the coordinator: transport + per-connection codec
//! negotiation, nothing else. The protocol itself — the typed
//! `Request`/`Response` vocabulary, the v0 ASCII line grammar and the
//! v1 length-prefixed frame layout — lives in [`crate::protocol`] and
//! is documented in DESIGN.md §15; dispatch lives in
//! [`Coordinator::handle`], the same entry point the in-process
//! [`crate::client::Client`] uses, so wire and in-process callers
//! share one code path.
//!
//! Since PR 10 the serve path is the multiplexed connection reactor
//! (DESIGN.md §20, [`super::reactor`]): `reactor_workers + 2` threads
//! serve every v1 connection, each connection carrying multiple
//! in-flight correlated requests. Version negotiation still sniffs the
//! first byte — [`frame::FRAME_MAGIC`] keeps the connection on the
//! reactor, anything else (every ASCII command letter) hands the
//! socket to the blocking v0 path below, which costs one thread per
//! connection and applies `SystemConfig::read_timeout` the historic
//! way.
//!
//! [`frame::FRAME_MAGIC`]: crate::protocol::frame::FRAME_MAGIC

use std::io::{BufReader, Read};
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::Result;

use crate::protocol::{line, Codec, Decoded, LineCodec, Response};

use super::reactor;
use super::Coordinator;

/// Handle one v0 protocol line — the thin shim that keeps the historic
/// line surface (and its unit tests) alive over the typed dispatcher.
/// `None` means QUIT (close the connection).
pub fn handle_line(coord: &Coordinator, line: &str) -> Option<String> {
    match line::parse_line(line) {
        Decoded::Quit | Decoded::Eof => None,
        Decoded::Malformed(msg) => Some(format!("ERR {msg}")),
        Decoded::Request(req) => Some(line::format_response(&coord.handle(req))),
    }
}

/// Legacy blocking v0 connection, entered when the reactor's sniff
/// sees a non-magic first byte. `prefix` carries whatever the reactor
/// already buffered; the socket arrives back in blocking mode (the
/// reactor flipped it before handing over). Costs one thread per
/// connection — the compatibility tax the reactor meters as
/// `legacy_conns`.
pub(crate) fn serve_v0_conn(coord: Arc<Coordinator>, stream: TcpStream, prefix: Vec<u8>) {
    // dead-client hygiene: never let an idle connection pin this thread
    let _ = stream.set_read_timeout(coord.read_timeout);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut codec: Box<dyn Codec> = Box::new(LineCodec);
    let mut reader = BufReader::new(std::io::Cursor::new(prefix).chain(stream));
    loop {
        let resp = match codec.read_request(&mut reader) {
            Err(_) => break, // I/O error, or idle past the read timeout
            Ok(Decoded::Eof) | Ok(Decoded::Quit) => break,
            Ok(Decoded::Malformed(msg)) => Response::Error(msg),
            Ok(Decoded::Request(req)) => coord.handle(req),
        };
        if codec.write_response(&mut writer, &resp).is_err() {
            break;
        }
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7177") through the
/// connection reactor. Blocks the caller; total thread count is
/// `coord.reactor_workers + 2` regardless of connection count (plus
/// one thread per legacy v0 connection).
pub fn serve(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let cfg = reactor::ReactorConfig {
        workers: coord.reactor_workers,
        read_timeout: coord.read_timeout,
        max_conns: None,
    };
    let handle = reactor::spawn(Arc::clone(&coord), addr, cfg)?;
    eprintln!(
        "velm serving on {} ({} reactor threads)",
        handle.addr,
        handle.thread_count()
    );
    handle.join();
    Ok(())
}

/// Serve a bounded number of connections (for tests / examples)
/// through the reactor, then return. Binds to an ephemeral port and
/// reports it via the return; `.join()` on the handle bundle blocks
/// until every accepted connection has drained.
pub fn serve_n(
    coord: Arc<Coordinator>,
    conns: usize,
) -> Result<(std::net::SocketAddr, JoinHandleVec)> {
    let cfg = reactor::ReactorConfig {
        workers: coord.reactor_workers,
        read_timeout: coord.read_timeout,
        max_conns: Some(conns),
    };
    let handle = reactor::spawn(coord, "127.0.0.1:0", cfg)?;
    let addr = handle.addr;
    Ok((addr, JoinHandleVec(handle.into_threads())))
}

/// Joinable bundle returned by [`serve_n`].
pub struct JoinHandleVec(pub Vec<std::thread::JoinHandle<()>>);

impl JoinHandleVec {
    pub fn join(self) {
        for h in self.0 {
            let _ = h.join();
        }
    }
}
