//! Request/response types flowing through the serving pipeline, plus
//! the fleet-health control messages workers interleave with traffic.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::fleet::probe::{ProbeReport, ProbeSet};

/// Which engine produced the hidden layer for a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Scalar behavioural chip simulator (per-sample conversion).
    ChipSim,
    /// Batched AOT JAX/Pallas artifact via PJRT.
    Pjrt,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::ChipSim => write!(f, "chip-sim"),
            Backend::Pjrt => write!(f, "pjrt"),
        }
    }
}

/// One classification request: features in [-1, 1]^d.
#[derive(Debug)]
pub struct ClassifyRequest {
    pub id: u64,
    pub features: Vec<f64>,
    pub submitted: Instant,
    pub reply: mpsc::Sender<ClassifyResponse>,
}

/// Everything a worker can receive: traffic, or a fleet-health control
/// message (DESIGN.md §12). Control rides the same channel, so control
/// messages execute in the order they were sent — a probe sent after a
/// drift injection always observes the drifted die. (Classify requests
/// collected into the same batch window are served *before* that
/// window's control messages, so traffic-vs-control ordering is only
/// batch-granular.)
#[derive(Debug)]
pub enum WorkerMsg {
    Classify(ClassifyRequest),
    Control(ControlMsg),
}

/// Fleet-health commands executed on the worker thread (which owns the
/// die). Replies go back over per-command channels to the
/// `fleet::FleetManager`.
#[derive(Debug)]
pub enum ControlMsg {
    /// Classify the pinned probe set + read the reference columns.
    Probe {
        probe: Arc<ProbeSet>,
        reply: mpsc::Sender<ProbeReport>,
    },
    /// Drift injection (tests/benches replaying Figs. 17/18): change
    /// VDD / temperature, or age the mismatch profile.
    SetEnv {
        vdd: Option<f64>,
        temp_k: Option<f64>,
        age_sigma_vt: Option<f64>,
        seed: u64,
    },
    /// Tier-1 recovery: cancel a measured common-mode gain by
    /// reprogramming the counting window. Replies with the new T_neu.
    Renormalize { gain: f64, reply: mpsc::Sender<f64> },
    /// Tier-2 recovery: chip-in-the-loop head refit on the (drained)
    /// die; replies with a post-refit probe report.
    Refit {
        xs: Arc<Vec<Vec<f64>>>,
        ys: Arc<Vec<f64>>,
        lambda: f64,
        beta_bits: u32,
        probe: Arc<ProbeSet>,
        reply: mpsc::Sender<Result<ProbeReport, String>>,
    },
}

/// The answer.
#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    pub id: u64,
    /// Raw second-stage score (eq. 1 output o).
    pub score: f64,
    /// Thresholded label (+1 / -1).
    pub label: i8,
    /// Which worker/die served it.
    pub worker: usize,
    pub backend: Backend,
    /// Physical conversions this request cost on the die — 1 on a
    /// physical die, `RotationPlan::passes()` on a virtual one
    /// (DESIGN.md §13).
    pub passes: usize,
    /// Wall-clock latency from submit to reply.
    pub latency: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_display() {
        assert_eq!(Backend::ChipSim.to_string(), "chip-sim");
        assert_eq!(Backend::Pjrt.to_string(), "pjrt");
    }

    #[test]
    fn request_response_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = ClassifyRequest {
            id: 7,
            features: vec![0.1, -0.2],
            submitted: Instant::now(),
            reply: tx,
        };
        let resp = ClassifyResponse {
            id: req.id,
            score: 0.5,
            label: 1,
            worker: 0,
            backend: Backend::ChipSim,
            passes: 1,
            latency: req.submitted.elapsed(),
        };
        req.reply.send(resp.clone()).unwrap();
        let got = rx.recv().unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.label, 1);
    }
}
