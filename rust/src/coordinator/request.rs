//! Request/response types flowing through the serving pipeline.

use std::sync::mpsc;
use std::time::Instant;

/// Which engine produced the hidden layer for a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Scalar behavioural chip simulator (per-sample conversion).
    ChipSim,
    /// Batched AOT JAX/Pallas artifact via PJRT.
    Pjrt,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::ChipSim => write!(f, "chip-sim"),
            Backend::Pjrt => write!(f, "pjrt"),
        }
    }
}

/// One classification request: features in [-1, 1]^d.
#[derive(Debug)]
pub struct ClassifyRequest {
    pub id: u64,
    pub features: Vec<f64>,
    pub submitted: Instant,
    pub reply: mpsc::Sender<ClassifyResponse>,
}

/// The answer.
#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    pub id: u64,
    /// Raw second-stage score (eq. 1 output o).
    pub score: f64,
    /// Thresholded label (+1 / -1).
    pub label: i8,
    /// Which worker/die served it.
    pub worker: usize,
    pub backend: Backend,
    /// Wall-clock latency from submit to reply.
    pub latency: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_display() {
        assert_eq!(Backend::ChipSim.to_string(), "chip-sim");
        assert_eq!(Backend::Pjrt.to_string(), "pjrt");
    }

    #[test]
    fn request_response_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = ClassifyRequest {
            id: 7,
            features: vec![0.1, -0.2],
            submitted: Instant::now(),
            reply: tx,
        };
        let resp = ClassifyResponse {
            id: req.id,
            score: 0.5,
            label: 1,
            worker: 0,
            backend: Backend::ChipSim,
            latency: req.submitted.elapsed(),
        };
        req.reply.send(resp.clone()).unwrap();
        let got = rx.recv().unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.label, 1);
    }
}
