//! Request/response types flowing through the serving pipeline, plus
//! the fleet-health and registry control messages workers interleave
//! with traffic.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::fleet::probe::{ProbeReport, ProbeSet};
use crate::registry::TenantSpec;

use super::metrics::TenantMetrics;

/// Which engine produced the hidden layer for a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Scalar behavioural chip simulator (per-sample conversion).
    ChipSim,
    /// Batched AOT JAX/Pallas artifact via PJRT.
    Pjrt,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::ChipSim => write!(f, "chip-sim"),
            Backend::Pjrt => write!(f, "pjrt"),
        }
    }
}

/// The model a request is addressed to (DESIGN.md §14): the tenant's
/// name plus its metrics handle, resolved once at submit so the hot
/// path never touches the registry again. `None` end-to-end means the
/// fleet's boot ("default") head.
#[derive(Clone, Debug)]
pub struct TenantTag {
    pub name: Arc<str>,
    pub metrics: Arc<TenantMetrics>,
}

/// One classification request: features in [-1, 1]^d, addressed to one
/// tenant's head (or the default head when `tenant` is `None`).
#[derive(Debug)]
pub struct ClassifyRequest {
    pub id: u64,
    pub features: Vec<f64>,
    /// Model id carried end-to-end; workers resolve the head from
    /// their own tenant table (lock-free) using this tag.
    pub tenant: Option<TenantTag>,
    pub submitted: Instant,
    /// When the batcher pulled this request off its queue — the
    /// queue-wait / batch-wait stage boundary (DESIGN.md §16). `None`
    /// until the batcher stamps it; stays `None` on paths that bypass
    /// the batcher (direct `serve_batch` tests), where queue-wait
    /// reads as zero.
    pub collected: Option<Instant>,
    pub reply: mpsc::Sender<ClassifyResponse>,
}

/// Everything a worker can receive: traffic, or a fleet-health /
/// registry control message (DESIGN.md §12, §14). Control rides the
/// same channel, so control messages execute in the order they were
/// sent — a probe sent after a drift injection always observes the
/// drifted die, and a request routed after a REGISTER acknowledgement
/// always finds the tenant's head installed. (Classify requests
/// collected into the same batch window are served *before* that
/// window's control messages, so traffic-vs-control ordering is only
/// batch-granular.)
#[derive(Debug)]
pub enum WorkerMsg {
    Classify(ClassifyRequest),
    Control(ControlMsg),
}

/// Fleet-health and registry commands executed on the worker thread
/// (which owns the die and its tenant table). Replies go back over
/// per-command channels to the `fleet::FleetManager` or the
/// coordinator's registry surface.
#[derive(Debug)]
pub enum ControlMsg {
    /// Classify the pinned probe set + read the reference columns.
    Probe {
        probe: Arc<ProbeSet>,
        reply: mpsc::Sender<ProbeReport>,
    },
    /// Drift injection (tests/benches replaying Figs. 17/18): change
    /// VDD / temperature, or age the mismatch profile.
    SetEnv {
        vdd: Option<f64>,
        temp_k: Option<f64>,
        age_sigma_vt: Option<f64>,
        seed: u64,
    },
    /// Tier-1 recovery: cancel a measured common-mode gain by
    /// reprogramming the counting window. Replies with the new T_neu.
    Renormalize { gain: f64, reply: mpsc::Sender<f64> },
    /// Tier-2 recovery: chip-in-the-loop head refit on the (drained)
    /// die — the default head **and every registered tenant's heads**
    /// re-solve against the drifted die (DESIGN.md §14); replies with a
    /// post-refit probe report plus the per-tenant post-refit train
    /// scores, so the fleet manager can refresh the tenant gauges.
    Refit {
        xs: Arc<Vec<Vec<f64>>>,
        ys: Arc<Vec<f64>>,
        lambda: f64,
        beta_bits: u32,
        probe: Arc<ProbeSet>,
        reply: mpsc::Sender<Result<(ProbeReport, Vec<(String, f64)>), String>>,
    },
    /// Registry: train this tenant's heads chip-in-the-loop on the die
    /// (one shared H, all heads) and install them in the worker's
    /// tenant table. Replies with the train-set score on this die.
    Register {
        spec: Arc<TenantSpec>,
        reply: mpsc::Sender<Result<f64, String>>,
    },
    /// Registry: drop a tenant's heads from this die. Replies whether
    /// the tenant was present.
    Unregister {
        tenant: Arc<str>,
        reply: mpsc::Sender<bool>,
    },
    /// Registry: OS-ELM incremental update — drive one labelled sample
    /// through the die and stream it into every head of the tenant
    /// (shared-P RLS, DESIGN.md §14).
    OnlineUpdate {
        tenant: Arc<str>,
        x: Arc<Vec<f64>>,
        /// One target per head of the tenant's task.
        targets: Arc<Vec<f64>>,
        reply: mpsc::Sender<Result<(), String>>,
    },
    /// Governor: move the die to another rung of the operating-point
    /// ladder (DESIGN.md §17) by reprogramming the counter MSB. The
    /// worker rescales its counting window so the eq. 19 relation
    /// `H = 2^b at I_sat^z` is preserved at the new cap, re-prices its
    /// energy ledger at the new point, and replies with the new
    /// fJ/conversion price.
    Retune {
        b: u32,
        reply: mpsc::Sender<u64>,
    },
}

/// The answer.
#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    pub id: u64,
    /// Raw second-stage score (eq. 1 output o) for the default head;
    /// training-unit score for tenant heads (regression outputs land in
    /// target units).
    pub score: f64,
    /// Thresholded label: ±1 for binary heads, the argmax class for
    /// multi-class tenants, 0 for regression.
    pub label: i8,
    /// Which tenant's head produced it (`None` = the default head).
    pub tenant: Option<Arc<str>>,
    /// Which worker/die served it.
    pub worker: usize,
    pub backend: Backend,
    /// Physical conversions this request cost on the die — 1 on a
    /// physical die, `RotationPlan::passes()` on a virtual one
    /// (DESIGN.md §13).
    pub passes: usize,
    /// Wall-clock latency from submit to reply.
    pub latency: std::time::Duration,
}

impl ClassifyResponse {
    /// The protocol-facing view of this answer (DESIGN.md §15): label,
    /// score and tenant — the fields every wire version carries.
    /// Serving internals (worker, backend, passes, latency) stay on
    /// this richer in-process type.
    pub fn to_prediction(&self) -> crate::protocol::Prediction {
        crate::protocol::Prediction {
            label: self.label,
            score: self.score,
            tenant: self.tenant.as_deref().map(str::to_string),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_display() {
        assert_eq!(Backend::ChipSim.to_string(), "chip-sim");
        assert_eq!(Backend::Pjrt.to_string(), "pjrt");
    }

    #[test]
    fn request_response_roundtrip_over_channel() {
        let (tx, rx) = mpsc::channel();
        let req = ClassifyRequest {
            id: 7,
            features: vec![0.1, -0.2],
            tenant: None,
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        };
        let resp = ClassifyResponse {
            id: req.id,
            score: 0.5,
            label: 1,
            tenant: None,
            worker: 0,
            backend: Backend::ChipSim,
            passes: 1,
            latency: req.submitted.elapsed(),
        };
        req.reply.send(resp.clone()).unwrap();
        let got = rx.recv().unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.label, 1);
        assert!(got.tenant.is_none());
    }

    #[test]
    fn tenant_tag_rides_the_request() {
        let (tx, _rx) = mpsc::channel();
        let tag = TenantTag {
            name: Arc::from("digits"),
            metrics: Arc::new(TenantMetrics::default()),
        };
        let req = ClassifyRequest {
            id: 1,
            features: vec![0.0; 4],
            tenant: Some(tag.clone()),
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        };
        assert_eq!(req.tenant.as_ref().unwrap().name.as_ref(), "digits");
        // the tag shares the metrics handle, not a copy
        tag.metrics.record_request();
        assert_eq!(
            req.tenant
                .as_ref()
                .unwrap()
                .metrics
                .requests
                .load(crate::sync::Ordering::Relaxed),
            1
        );
    }
}
