//! Workload generation for serving experiments: open-loop Poisson
//! arrivals and closed-loop clients, driving the coordinator the way the
//! paper's FPGA drives the chip — plus a latency-under-load sweep used
//! by the perf bench and EXPERIMENTS.md §E2E.

use std::time::{Duration, Instant};

use crate::sync::Ordering;

use crate::coordinator::Coordinator;
use crate::util::prng::Prng;

/// Result of one load level.
#[derive(Clone, Debug)]
pub struct LoadPoint {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_batch: f64,
}

/// Exponential inter-arrival sample for a Poisson process at `rate` Hz.
pub fn exp_interarrival(rate: f64, rng: &mut Prng) -> Duration {
    let u = rng.f64().max(f64::MIN_POSITIVE);
    Duration::from_secs_f64((-u.ln() / rate).min(1.0))
}

/// Open-loop Poisson load: submit `n` requests at `rate` req/s drawn
/// from `samples`, wait for all responses, report latency percentiles.
pub fn poisson_load(
    coord: &Coordinator,
    samples: &[Vec<f64>],
    rate: f64,
    n: usize,
    seed: u64,
) -> LoadPoint {
    let mut rng = Prng::new(seed);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for k in 0..n {
        let x = samples[k % samples.len()].clone();
        rxs.push(coord.submit(x).expect("submit"));
        std::thread::sleep(exp_interarrival(rate, &mut rng));
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let wall = t0.elapsed().as_secs_f64();
    LoadPoint {
        offered_rps: rate,
        achieved_rps: n as f64 / wall,
        p50_us: coord.metrics.latency_percentile_us(50.0),
        p99_us: coord.metrics.latency_percentile_us(99.0),
        mean_batch: coord.metrics.mean_batch_size(),
    }
}

/// Closed-loop saturation: `clients` threads submitting back-to-back for
/// `per_client` requests each; measures the system's peak throughput.
pub fn closed_loop(
    coord: &Coordinator,
    samples: &[Vec<f64>],
    clients: usize,
    per_client: usize,
) -> LoadPoint {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let coord = &*coord;
            let samples = &samples;
            s.spawn(move || {
                for k in 0..per_client {
                    let x = samples[(c * per_client + k) % samples.len()].clone();
                    let rx = coord.submit(x).expect("submit");
                    let _ = rx.recv();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let n = clients * per_client;
    LoadPoint {
        offered_rps: f64::INFINITY,
        achieved_rps: n as f64 / wall,
        p50_us: coord.metrics.latency_percentile_us(50.0),
        p99_us: coord.metrics.latency_percentile_us(99.0),
        mean_batch: coord.metrics.mean_batch_size(),
    }
}

/// Sanity counter: requests in == responses out (conservation).
/// Callers invoke this at quiescence (after their drivers joined), so
/// the counters cannot move between the two loads.
pub fn conservation_ok(coord: &Coordinator) -> bool {
    // relaxed-ok: quiescent equality check; both counters are settled
    // by the time callers ask, and a torn mid-traffic read could only
    // yield a spurious `false`, never a false `true` being relied on.
    coord.metrics.requests.load(Ordering::Relaxed)
        == coord.metrics.responses.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, SystemConfig};
    use crate::datasets::synth;

    fn tiny_coord() -> (Coordinator, Vec<Vec<f64>>) {
        let ds = synth::brightdata(1).with_test_subsample(40, 1);
        let mut cfg = ChipConfig::default().with_b(10);
        cfg.d = ds.d();
        let sys = SystemConfig {
            n_chips: 2,
            artifact_dir: "/nonexistent".into(),
            max_wait: Duration::from_millis(1),
            ..Default::default()
        };
        let c = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10).unwrap();
        (c, ds.test_x)
    }

    #[test]
    fn interarrival_mean_matches_rate() {
        let mut rng = Prng::new(1);
        let n = 20_000;
        let total: f64 = (0..n)
            .map(|_| exp_interarrival(1000.0, &mut rng).as_secs_f64())
            .sum();
        let mean = total / n as f64;
        assert!((mean - 1e-3).abs() < 1e-4, "mean {mean}");
    }

    #[test]
    fn closed_loop_completes_and_conserves() {
        let (coord, samples) = tiny_coord();
        let lp = closed_loop(&coord, &samples, 4, 25);
        assert!(lp.achieved_rps > 0.0);
        assert!(lp.p99_us >= lp.p50_us);
        assert!(conservation_ok(&coord));
        coord.shutdown();
    }

    #[test]
    fn poisson_load_reports_sane_numbers() {
        let (coord, samples) = tiny_coord();
        let lp = poisson_load(&coord, &samples, 2000.0, 60, 7);
        assert!(lp.achieved_rps > 0.0);
        assert!(lp.mean_batch >= 1.0);
        assert!(conservation_ok(&coord));
        coord.shutdown();
    }
}
