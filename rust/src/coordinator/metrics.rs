//! Serving metrics: lock-free counters, per-stage log2 latency
//! histograms ([`LatencyHist`] — atomics only on the hot path;
//! percentile math at snapshot), an always-on flight recorder of the
//! last N request traces, and a modelled energy ledger (DESIGN.md
//! §16). Per-tenant request/latency/energy/score gauges ride along
//! (DESIGN.md §14): tenant handles are `Arc<TenantMetrics>` resolved
//! once at submit and carried inside the request, so the hot path
//! never locks the tenant directory.
//!
//! Every export — the classic one-line report, JSON, Prometheus text,
//! the v1 snapshot frame — is built from ONE single-pass
//! [`StatsSnapshot`], never from independent atomic reads, so readers
//! cannot observe torn states like `responses > requests`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::sync::{AtomicU64, Mutex, Ordering};

use super::hist::{LatencyHist, BUCKETS};
use super::timeline::Timeline;
use super::trace::{FlightRecorder, DEFAULT_TRACE_CAPACITY};
use crate::protocol::stats::{StatsSnapshot, TenantStats, SNAPSHOT_VERSION};

/// Per-tenant serving gauges: all atomics, shared between the submit
/// path (requests), the workers (responses/latency/energy) and the
/// registry (train score after register/refit).
#[derive(Debug, Default)]
pub struct TenantMetrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    /// End-to-end latency of this tenant's answered rows.
    latency: LatencyHist,
    /// Modelled energy booked to this tenant's answered rows, fJ.
    pub energy_fj: AtomicU64,
    /// Die-busy microseconds attributed to this tenant's rows by the
    /// timeline profiler (DESIGN.md §19): a batch's compute span split
    /// across its rows, so tenant shares sum to (at most) fleet busy
    /// time and `busy_us / sum(busy_us)` is the utilization share.
    pub busy_us: AtomicU64,
    /// Mean chip-in-the-loop train score across dies (classification:
    /// error rate; regression: RMSE), stored as f64 bits.
    score_bits: AtomicU64,
}

impl TenantMetrics {
    // relaxed-ok: every gauge here is an independent monotone counter
    // (or an idempotent f64-bits store); readers never infer other
    // memory from a value. The one cross-counter invariant,
    // responses <= requests, is enforced by `Metrics::snapshot` load
    // order + clamping rather than by memory ordering.
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_response(&self, latency: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency);
    }

    /// Book modelled conversion energy (femtojoules) to this tenant.
    pub fn record_energy(&self, fj: u64) {
        self.energy_fj.fetch_add(fj, Ordering::Relaxed);
    }

    /// Attribute die-busy microseconds to this tenant (the worker
    /// splits each batch's compute span across its rows).
    pub fn record_busy_us(&self, us: u64) {
        self.busy_us.fetch_add(us, Ordering::Relaxed);
    }

    /// One windowable copy of this tenant's latency buckets (the
    /// governor diffs two copies for its sliding-window p99).
    pub fn latency_buckets(&self) -> [u64; BUCKETS] {
        self.latency.bucket_counts()
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latency.mean_us()
    }

    /// Interpolated latency percentile (shared [`LatencyHist`] math).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency.percentile_us(p)
    }

    /// Record the tenant's train score (set at register and refit).
    pub fn set_score(&self, score: f64) {
        self.score_bits.store(score.to_bits(), Ordering::Relaxed);
    }

    pub fn score(&self) -> f64 {
        f64::from_bits(self.score_bits.load(Ordering::Relaxed))
    }
}

pub struct Metrics {
    /// When the coordinator started serving (the STATS time base).
    started: Instant,
    pub requests: AtomicU64,
    /// Client-facing submit events: a single predict ticks this once,
    /// and a `BatchPredict` of B rows ALSO ticks it once (while
    /// `requests` counts all B rows) — so `requests / submissions` is
    /// the mean rows-per-submission, the protocol-level batching the
    /// v1 wire buys (DESIGN.md §15).
    pub submissions: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_batches: AtomicU64,
    pub sim_batches: AtomicU64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_requests: AtomicU64,
    /// Physical die conversions booked while serving — a virtual
    /// request books `RotationPlan::passes()` of them (DESIGN.md §13),
    /// so `conversions / responses` is the fleet's mean pass cost.
    pub conversions: AtomicU64,
    /// Modelled energy of every booked conversion, femtojoules: each
    /// worker prices its die's conversions at the die's operating
    /// point (`chip::energy::conversion_price_fj`), so the ledger is
    /// exactly `sum(conversions_i * price_i)` over dies.
    pub energy_fj: AtomicU64,
    /// Modelled MACs performed by those conversions (d*L per physical
    /// conversion), the denominator of fleet pJ/MAC.
    pub macs: AtomicU64,
    /// End-to-end latency (submit -> reply).
    latency: LatencyHist,
    /// Stage: submit -> pulled off the batcher queue.
    queue: LatencyHist,
    /// Stage: pulled -> batch dispatched to an engine.
    batch_wait: LatencyHist,
    /// Stage: engine dispatch -> row answered.
    compute: LatencyHist,
    /// Flight recorder: the last N completed request traces,
    /// dumpable via the `TRACE` verb (DESIGN.md §16).
    pub trace: FlightRecorder,
    /// Fleet timeline profiler (DESIGN.md §19): per-die lifecycle
    /// segment stamps, folded into exact occupancy fractions and
    /// exportable as Chrome trace-event JSON via the `TIMELINE` verb.
    pub timeline: Timeline,
    // fleet-health counters (DESIGN.md §12)
    /// Probe passes executed across the fleet.
    pub probes: AtomicU64,
    /// Tier-1 counting-window renormalisations applied.
    pub renorms: AtomicU64,
    /// Tier-2 chip-in-the-loop head refits completed.
    pub refits: AtomicU64,
    /// Dies quarantined after failed recovery.
    pub quarantines: AtomicU64,
    /// Hot standbys promoted into rotation.
    pub promotions: AtomicU64,
    // governor counters (DESIGN.md §17)
    /// Governor control-loop ticks executed.
    pub gov_ticks: AtomicU64,
    /// Dies escalated toward the boot rung (hot traffic).
    pub gov_raises: AtomicU64,
    /// Dies dropped one rung (idle, SLOs holding).
    pub gov_lowers: AtomicU64,
    /// Moves refused: unhealthy die (lifecycle owns it), hysteresis
    /// budget spent, or a retune that could not be applied.
    pub gov_rejected: AtomicU64,
    /// Cumulative energy saved vs the boot operating point, fJ —
    /// booked per conversion at the exact integer price difference.
    pub gov_fj_saved: AtomicU64,
    /// Governor ticks that observed a windowed-p99 latency SLO breach
    /// (fleet-wide or any tenant) — each one pins the fleet hot and
    /// blocks descent for that tick (DESIGN.md §19).
    pub gov_slo_breaches: AtomicU64,
    /// Per-die operating point (counter bits) as last published by the
    /// governor; empty while the governor has never run.
    gov_points: Mutex<Vec<u32>>,
    /// Per-tenant gauges, keyed by tenant name (DESIGN.md §14). The
    /// mutex guards only registration/removal and the report snapshot —
    /// hot-path recording goes through the `Arc<TenantMetrics>` carried
    /// in each request.
    tenants: Mutex<BTreeMap<String, Arc<TenantMetrics>>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    // relaxed-ok: all counters are independent monotone telemetry; no
    // reader dereferences memory published by a counter value. The two
    // cross-counter invariants exported to clients — responses <=
    // requests, and energy_fj + fj_saved <= boot-priced conversions —
    // are enforced by `snapshot`'s documented load order plus clamping
    // (model-checked in tests/model_checker.rs), not by Acquire/Release
    // pairs.
    pub fn new() -> Self {
        Metrics::with_trace_cap(DEFAULT_TRACE_CAPACITY)
    }

    /// Metrics with a custom flight-recorder capacity
    /// (`SystemConfig::trace_cap` / `velm serve --trace-cap`). Both
    /// rings — recorder and timeline — allocate here, once, and never
    /// again (pinned in coordinator::trace tests).
    pub fn with_trace_cap(trace_cap: usize) -> Self {
        Metrics {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            submissions: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            pjrt_batches: AtomicU64::new(0),
            sim_batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            conversions: AtomicU64::new(0),
            energy_fj: AtomicU64::new(0),
            macs: AtomicU64::new(0),
            latency: LatencyHist::new(),
            queue: LatencyHist::new(),
            batch_wait: LatencyHist::new(),
            compute: LatencyHist::new(),
            trace: FlightRecorder::new(trace_cap),
            timeline: Timeline::new(),
            probes: AtomicU64::new(0),
            renorms: AtomicU64::new(0),
            refits: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            gov_ticks: AtomicU64::new(0),
            gov_raises: AtomicU64::new(0),
            gov_lowers: AtomicU64::new(0),
            gov_rejected: AtomicU64::new(0),
            gov_fj_saved: AtomicU64::new(0),
            gov_slo_breaches: AtomicU64::new(0),
            gov_points: Mutex::new(Vec::new()),
            tenants: Mutex::new(BTreeMap::new()),
        }
    }

    /// Book exact saved energy (fJ vs the boot point) for conversions
    /// served on a cheaper governor rung.
    pub fn record_gov_fj_saved(&self, fj: u64) {
        self.gov_fj_saved.fetch_add(fj, Ordering::Relaxed);
    }

    /// Count one governor tick whose windowed p99 breached its latency
    /// SLO (fleet-wide or any tenant's).
    pub fn mark_slo_breach(&self) {
        self.gov_slo_breaches.fetch_add(1, Ordering::Relaxed);
    }

    /// One windowable copy of the fleet end-to-end latency buckets
    /// (the governor diffs two copies for its sliding-window p99).
    pub fn latency_buckets(&self) -> [u64; BUCKETS] {
        self.latency.bucket_counts()
    }

    /// Publish the boot operating points before the first governor
    /// tick, so a freshly started governor-enabled fleet reports where
    /// its dies sit instead of an empty vector.
    pub fn seed_gov_points(&self, points: Vec<u32>) {
        *self.gov_points.lock().unwrap() = points;
    }

    /// Record one governor tick's outcome counts and publish the
    /// per-die operating points it left behind.
    pub fn record_gov_tick(&self, raises: u64, lowers: u64, rejected: u64, points: Vec<u32>) {
        self.gov_ticks.fetch_add(1, Ordering::Relaxed);
        self.gov_raises.fetch_add(raises, Ordering::Relaxed);
        self.gov_lowers.fetch_add(lowers, Ordering::Relaxed);
        self.gov_rejected.fetch_add(rejected, Ordering::Relaxed);
        *self.gov_points.lock().unwrap() = points;
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One client-facing submit event (single or whole batch).
    pub fn record_submission(&self) {
        self.submissions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize, pjrt: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        if pjrt {
            self.pjrt_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.sim_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_conversions(&self, n: u64) {
        self.conversions.fetch_add(n, Ordering::Relaxed);
    }

    /// Book a batch's modelled energy (fJ) and MAC count.
    pub fn record_energy(&self, fj: u64, macs: u64) {
        self.energy_fj.fetch_add(fj, Ordering::Relaxed);
        self.macs.fetch_add(macs, Ordering::Relaxed);
    }

    /// Record one answered request's stage decomposition
    /// (queue-wait, batch-wait, compute) into the per-stage histograms.
    pub fn record_stages(&self, queue: Duration, batch_wait: Duration, compute: Duration) {
        self.queue.record(queue);
        self.batch_wait.record(batch_wait);
        self.compute.record(compute);
    }

    /// Create (or return) the gauge handle for a tenant.
    pub fn register_tenant(&self, name: &str) -> Arc<TenantMetrics> {
        let mut map = self.tenants.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(TenantMetrics::default())),
        )
    }

    /// Drop a tenant's gauges from the report (outstanding request tags
    /// keep their handle alive until answered).
    pub fn drop_tenant(&self, name: &str) {
        self.tenants.lock().unwrap().remove(name);
    }

    /// The gauge handle for a tenant, if registered — never inserts
    /// (the fleet manager uses this so a refit racing an unregister
    /// cannot resurrect a dropped tenant's gauges).
    pub fn tenant_handle(&self, name: &str) -> Option<Arc<TenantMetrics>> {
        self.tenants.lock().unwrap().get(name).map(Arc::clone)
    }

    /// Snapshot of the per-tenant gauge handles.
    pub fn tenant_snapshot(&self) -> Vec<(String, Arc<TenantMetrics>)> {
        self.tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    pub fn record_response(&self, latency: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency);
    }

    /// Approximate end-to-end latency percentile (see
    /// [`LatencyHist::percentile_us`]).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency.percentile_us(p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latency.mean_us()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One consistent picture of the fleet, taken in a single pass.
    ///
    /// Two load-order disciplines keep the exported pairs consistent
    /// mid-traffic (both are model-checked in tests/model_checker.rs):
    ///
    /// - `responses` is loaded BEFORE `requests` and then clamped to
    ///   `<= requests`: a request recorded between the two loads can
    ///   only raise `requests`, so the exported pair always satisfies
    ///   the invariant (same for each tenant).
    /// - the energy ledger is read in the REVERSE of the worker's
    ///   booking order (workers book conversions, then energy, then
    ///   saved energy; we load `gov_fj_saved`, then `energy_fj`, then
    ///   `conversions`), so every booking observed in the two energy
    ///   sums has its conversions already visible in `conversions` and
    ///   `energy_fj + fj_saved <= boot_price * conversions` holds at
    ///   every observable point, with exact equality at quiescence.
    pub fn snapshot(&self) -> StatsSnapshot {
        let uptime_us = self.started.elapsed().as_micros() as u64;
        let responses = self.responses.load(Ordering::Relaxed);
        let requests = self.requests.load(Ordering::Relaxed);
        let gov_fj_saved = self.gov_fj_saved.load(Ordering::Relaxed);
        let energy_fj = self.energy_fj.load(Ordering::Relaxed);
        let macs = self.macs.load(Ordering::Relaxed);
        let conversions = self.conversions.load(Ordering::Relaxed);
        let tenants = self
            .tenant_snapshot()
            .into_iter()
            .map(|(name, m)| {
                let t_resp = m.responses.load(Ordering::Relaxed);
                let t_req = m.requests.load(Ordering::Relaxed);
                TenantStats {
                    name,
                    requests: t_req,
                    responses: t_resp.min(t_req),
                    energy_fj: m.energy_fj.load(Ordering::Relaxed),
                    busy_us: m.busy_us.load(Ordering::Relaxed),
                    train_score: m.score(),
                    latency: m.latency.snapshot(),
                }
            })
            .collect();
        StatsSnapshot {
            version: SNAPSHOT_VERSION,
            uptime_us,
            requests,
            submissions: self.submissions.load(Ordering::Relaxed),
            responses: responses.min(requests),
            batches: self.batches.load(Ordering::Relaxed),
            pjrt_batches: self.pjrt_batches.load(Ordering::Relaxed),
            sim_batches: self.sim_batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            conversions,
            probes: self.probes.load(Ordering::Relaxed),
            renorms: self.renorms.load(Ordering::Relaxed),
            refits: self.refits.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            energy_fj,
            macs,
            latency: self.latency.snapshot(),
            queue: self.queue.snapshot(),
            batch_wait: self.batch_wait.snapshot(),
            compute: self.compute.snapshot(),
            governor: crate::protocol::stats::GovernorStats {
                ticks: self.gov_ticks.load(Ordering::Relaxed),
                raises: self.gov_raises.load(Ordering::Relaxed),
                lowers: self.gov_lowers.load(Ordering::Relaxed),
                rejected: self.gov_rejected.load(Ordering::Relaxed),
                fj_saved: gov_fj_saved,
                points: self.gov_points.lock().unwrap().clone(),
            },
            tenants,
            occupancy: self.timeline.occupancy(),
            slo_breaches: self.gov_slo_breaches.load(Ordering::Relaxed),
        }
    }

    /// One-line human snapshot (plus a ` tenant[..]` clause per
    /// registered tenant), rendered from one [`StatsSnapshot`].
    pub fn report(&self) -> String {
        let s = self.snapshot();
        let tenants: String = s
            .tenants
            .iter()
            .map(|t| {
                format!(
                    " tenant[{}: req={} resp={} mean={:.0}us p50~{}us p99~{}us energy_fj={} train_score={:.4}]",
                    t.name,
                    t.requests,
                    t.responses,
                    t.latency.mean_us(),
                    t.latency.p50_us,
                    t.latency.p99_us,
                    t.energy_fj,
                    t.train_score,
                )
            })
            .collect();
        let mean_batch = if s.batches == 0 {
            0.0
        } else {
            s.batched_requests as f64 / s.batches as f64
        };
        format!(
            "requests={} submissions={} responses={} batches={} (pjrt={}, sim={}, mean size {:.1}) \
             conversions={} latency mean={:.0}us p50~{}us p99~{}us \
             fleet probes={} renorms={} refits={} quarantines={} promotions={} \
             governor ticks={} raises={} lowers={} rejected={} fj_saved={} slo_breaches={} \
             stages queue p50~{}us p99~{}us batch p50~{}us p99~{}us compute p50~{}us p99~{}us \
             energy_fj={} pJ/MAC={:.3} uptime={:.1}s req/s={:.1} conv/s={:.1}{tenants}",
            s.requests,
            s.submissions,
            s.responses,
            s.batches,
            s.pjrt_batches,
            s.sim_batches,
            mean_batch,
            s.conversions,
            s.latency.mean_us(),
            s.latency.p50_us,
            s.latency.p99_us,
            s.probes,
            s.renorms,
            s.refits,
            s.quarantines,
            s.promotions,
            s.governor.ticks,
            s.governor.raises,
            s.governor.lowers,
            s.governor.rejected,
            s.governor.fj_saved,
            s.slo_breaches,
            s.queue.p50_us,
            s.queue.p99_us,
            s.batch_wait.p50_us,
            s.batch_wait.p99_us,
            s.compute.p50_us,
            s.compute.p99_us,
            s.energy_fj,
            s.pj_per_mac(),
            s.uptime_us as f64 * 1e-6,
            s.requests_per_s(),
            s.conversions_per_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_submission();
        m.record_batch(2, true);
        m.record_response(Duration::from_micros(100));
        m.record_response(Duration::from_micros(200));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.submissions.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("submissions=1"), "{}", m.report());
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
        assert_eq!(m.pjrt_batches.load(Ordering::Relaxed), 1);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        assert!((m.mean_latency_us() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            m.record_response(Duration::from_micros(us));
        }
        // 5th of 10 samples is 160 us, in bucket [128, 256): the
        // interpolated estimate must stay inside that bucket (tighter
        // than the old upper-edge report of 256)
        let p50 = m.latency_percentile_us(50.0);
        assert!((128..256).contains(&p50), "p50 {p50}");
        // 100_000 us lives in bucket [65536, 131072): p99 must bracket
        // it within the bucket instead of reporting the 131072 edge
        let p99 = m.latency_percentile_us(99.0);
        assert!((65536..131072).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn interpolated_percentile_bias_is_bounded_by_half_bucket() {
        // upper-edge reporting returned up to 2x the true latency; the
        // interpolated estimate of a single-valued distribution lands at
        // the bucket midpoint — at most ~1.5x the bucket's lower edge
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_response(Duration::from_micros(1000)); // bucket [512, 1024)
        }
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!((512..1024).contains(&p50), "p50 {p50}");
        assert!((512..1024).contains(&p99), "p99 {p99}");
        // and the uniform-within-bucket spread is monotone in p
        assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
    }

    #[test]
    fn single_sample_percentile_sits_mid_bucket() {
        let m = Metrics::new();
        m.record_response(Duration::from_micros(3000)); // bucket [2048, 4096)
        let p50 = m.latency_percentile_us(50.0);
        assert_eq!(p50, 3072, "one sample interpolates to the bucket midpoint");
    }

    #[test]
    fn conversions_accumulate_and_report() {
        let m = Metrics::new();
        m.record_conversions(9);
        m.record_conversions(9);
        assert_eq!(m.conversions.load(Ordering::Relaxed), 18);
        assert!(m.report().contains("conversions=18"), "{}", m.report());
    }

    #[test]
    fn fleet_counters_appear_in_report() {
        let m = Metrics::new();
        m.probes.fetch_add(3, Ordering::Relaxed);
        m.renorms.fetch_add(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("probes=3"), "{r}");
        assert!(r.contains("renorms=1"), "{r}");
        assert!(r.contains("quarantines=0"), "{r}");
    }

    #[test]
    fn tenant_gauges_register_record_and_report() {
        let m = Metrics::new();
        let t = m.register_tenant("digits");
        t.record_request();
        t.record_response(Duration::from_micros(200));
        t.record_response(Duration::from_micros(400));
        t.set_score(0.0625);
        assert_eq!(t.requests.load(Ordering::Relaxed), 1);
        assert_eq!(t.responses.load(Ordering::Relaxed), 2);
        assert!((t.mean_latency_us() - 300.0).abs() < 1e-9);
        assert!((t.score() - 0.0625).abs() < 1e-15);
        let r = m.report();
        assert!(r.contains("tenant[digits:"), "{r}");
        assert!(r.contains("resp=2"), "{r}");
        assert!(r.contains("train_score=0.0625"), "{r}");
        // re-registering returns the same handle
        let t2 = m.register_tenant("digits");
        assert_eq!(t2.requests.load(Ordering::Relaxed), 1);
        m.drop_tenant("digits");
        assert!(!m.report().contains("tenant[digits"), "{}", m.report());
        // the outstanding handle still works after the drop
        t.record_request();
        assert_eq!(t.requests.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn governor_counters_accumulate_and_reach_the_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().governor.points.is_empty(), "never ticked");
        m.record_gov_tick(1, 0, 2, vec![14, 10]);
        m.record_gov_tick(0, 3, 0, vec![14, 6]);
        m.record_gov_fj_saved(500);
        m.record_gov_fj_saved(250);
        let g = m.snapshot().governor;
        assert_eq!((g.ticks, g.raises, g.lowers, g.rejected), (2, 1, 3, 2));
        assert_eq!(g.fj_saved, 750);
        assert_eq!(g.points, vec![14, 6], "last published points win");
        let r = m.report();
        assert!(r.contains("governor ticks=2"), "{r}");
        assert!(r.contains("fj_saved=750"), "{r}");
    }

    #[test]
    fn trace_cap_timeline_and_slo_counters_reach_the_snapshot() {
        use crate::protocol::stats::Segment;
        let m = Metrics::with_trace_cap(4);
        assert_eq!(m.trace.capacity(), 4, "--trace-cap sizes the recorder");
        // the timeline rides the same Metrics instance the workers get
        let die = m.timeline.register(0);
        die.stamp(Segment::Convert, 0, 750, Some(1));
        die.stamp(Segment::Idle, 750, 1000, None);
        m.mark_slo_breach();
        let t = m.register_tenant("digits");
        t.record_busy_us(250);
        let s = m.snapshot();
        assert_eq!(s.slo_breaches, 1);
        assert!(m.report().contains("slo_breaches=1"), "{}", m.report());
        assert_eq!(s.occupancy.len(), 1);
        assert_eq!(s.occupancy[0].total_us(), 1000);
        let sum: f64 = s.occupancy[0].fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
        assert_eq!(s.tenants[0].busy_us, 250);
        // fleet latency buckets window like the tenant ones
        m.record_response(Duration::from_micros(3000)); // bucket 11
        assert_eq!(m.latency_buckets()[11], 1);
        assert_eq!(t.latency_buckets().iter().sum::<u64>(), 0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(m.report().contains("requests=0"));
    }

    #[test]
    fn tenant_percentiles_reach_the_report() {
        let m = Metrics::new();
        let t = m.register_tenant("digits");
        t.record_request();
        t.record_response(Duration::from_micros(3000)); // bucket [2048, 4096)
        assert_eq!(t.latency_percentile_us(50.0), 3072);
        let r = m.report();
        assert!(r.contains("p50~3072us"), "{r}");
    }

    #[test]
    fn energy_ledger_accumulates_and_prices_macs() {
        let m = Metrics::new();
        m.record_energy(1000, 50);
        m.record_energy(500, 25);
        let s = m.snapshot();
        assert_eq!(s.energy_fj, 1500);
        assert_eq!(s.macs, 75);
        assert!((s.pj_per_mac() - 0.02).abs() < 1e-12, "1500 fJ / 75 MAC = 0.02 pJ/MAC");
        assert!(m.report().contains("energy_fj=1500"), "{}", m.report());
        let t = m.register_tenant("digits");
        t.record_energy(300);
        assert_eq!(m.snapshot().tenants[0].energy_fj, 300);
    }

    #[test]
    fn snapshot_is_single_pass_and_self_consistent() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_request();
        }
        m.record_submission();
        m.record_batch(5, false);
        for _ in 0..3 {
            m.record_response(Duration::from_micros(500));
            m.record_stages(
                Duration::from_micros(100),
                Duration::from_micros(50),
                Duration::from_micros(350),
            );
        }
        let s = m.snapshot();
        assert_eq!(s.version, SNAPSHOT_VERSION);
        assert_eq!(s.requests, 5);
        assert_eq!(s.responses, 3);
        assert!(s.responses <= s.requests);
        assert_eq!(s.latency.count, 3);
        assert_eq!(s.queue.count, 3);
        assert_eq!(s.batch_wait.count, 3);
        assert_eq!(s.compute.count, 3);
        // the JSON path roundtrips the same snapshot
        let parsed = StatsSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(parsed.requests, s.requests);
        assert_eq!(parsed.queue, s.queue);
    }

    #[test]
    fn snapshot_clamps_torn_response_counts() {
        let m = Metrics::new();
        // simulate a torn read: responses ticked ahead of requests
        m.responses.fetch_add(7, Ordering::Relaxed);
        m.requests.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2, "clamped to requests");
        let t = m.register_tenant("digits");
        t.responses.fetch_add(4, Ordering::Relaxed);
        t.requests.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.tenants[0].responses, 1);
    }

    #[test]
    fn uptime_and_rates_are_reported() {
        let m = Metrics::new();
        m.record_request();
        std::thread::sleep(Duration::from_millis(5));
        let s = m.snapshot();
        assert!(s.uptime_us >= 5000, "uptime {}us", s.uptime_us);
        assert!(s.requests_per_s() > 0.0);
        let r = m.report();
        assert!(r.contains("uptime="), "{r}");
        assert!(r.contains("req/s="), "{r}");
        assert!(r.contains("conv/s="), "{r}");
    }

    #[test]
    fn threaded_stress_snapshots_stay_consistent() {
        use crate::protocol::stats::{TraceEntry, TraceOutcome};
        // Miri executes this interpreter-slow; shrink the schedule but
        // keep the shape (4 booking writers racing a snapshot reader).
        const OPS: u64 = if cfg!(miri) { 25 } else { 2000 };
        const SNAPS: usize = if cfg!(miri) { 10 } else { 300 };
        // Each booked conversion costs 100 fJ against a 150 fJ boot
        // price, so the ledger bound below is non-trivially exercised.
        const PRICE_FJ: u64 = 100;
        const BOOT_FJ: u64 = 150;
        let m = Arc::new(Metrics::new());
        let tenant = m.register_tenant("stress");
        std::thread::scope(|scope| {
            for worker in 0..4u64 {
                let m = Arc::clone(&m);
                let tenant = Arc::clone(&tenant);
                scope.spawn(move || {
                    for i in 0..OPS {
                        // request strictly before response keeps the
                        // invariant the snapshot clamp relies on
                        m.record_request();
                        tenant.record_request();
                        let us = 1 + (worker * OPS + i) % 5000;
                        m.record_response(Duration::from_micros(us));
                        tenant.record_response(Duration::from_micros(us));
                        m.record_stages(
                            Duration::from_micros(us / 4),
                            Duration::from_micros(us / 8),
                            Duration::from_micros(us / 2),
                        );
                        // ledger booking order: conversions, energy,
                        // saved — snapshot reads it in reverse
                        m.record_conversions(6);
                        m.record_energy(6 * PRICE_FJ, 6 * 48);
                        m.record_gov_fj_saved(6 * (BOOT_FJ - PRICE_FJ));
                        tenant.record_energy(6 * PRICE_FJ);
                        m.trace.push(TraceEntry {
                            id: worker * OPS + i,
                            tenant: Some("stress".into()),
                            die: worker as u32,
                            pjrt: false,
                            passes: 6,
                            queue_us: us / 4,
                            batch_us: us / 8,
                            compute_us: us / 2,
                            total_us: us,
                            outcome: TraceOutcome::Ok,
                        });
                    }
                });
            }
            let m = Arc::clone(&m);
            scope.spawn(move || {
                for _ in 0..SNAPS {
                    let s = m.snapshot();
                    assert!(s.responses <= s.requests, "{} > {}", s.responses, s.requests);
                    assert!(
                        s.energy_fj + s.governor.fj_saved <= BOOT_FJ * s.conversions,
                        "ledger bound torn: {} + {} > {} * {}",
                        s.energy_fj,
                        s.governor.fj_saved,
                        BOOT_FJ,
                        s.conversions
                    );
                    for stage in [&s.latency, &s.queue, &s.batch_wait, &s.compute] {
                        assert!(
                            stage.p50_us <= stage.p90_us && stage.p90_us <= stage.p99_us,
                            "non-monotone percentiles {stage:?}"
                        );
                    }
                    for t in &s.tenants {
                        assert!(t.responses <= t.requests);
                    }
                    let _ = m.trace.dump(64);
                    let _ = m.report();
                }
            });
        });
        let s = m.snapshot();
        assert_eq!(s.requests, 4 * OPS);
        assert_eq!(s.responses, 4 * OPS);
        assert_eq!(s.conversions, 4 * OPS * 6);
        assert_eq!(s.energy_fj, 4 * OPS * 6 * PRICE_FJ);
        assert_eq!(s.macs, 4 * OPS * 6 * 48);
        assert_eq!(
            s.energy_fj + s.governor.fj_saved,
            BOOT_FJ * s.conversions,
            "exact ledger identity at quiescence"
        );
        assert_eq!(s.latency.count, 4 * OPS);
        assert_eq!(m.trace.recorded(), 4 * OPS);
        assert_eq!(s.tenants[0].requests, 4 * OPS);
        assert_eq!(s.tenants[0].energy_fj, 4 * OPS * 6 * PRICE_FJ);
    }
}
