//! Serving metrics: lock-free counters + a log2-bucketed latency
//! histogram (atomics only on the hot path; percentile math at snapshot).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 latency buckets: bucket i covers [2^i, 2^(i+1)) us.
const BUCKETS: usize = 32;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_batches: AtomicU64,
    pub sim_batches: AtomicU64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_requests: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize, pjrt: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        if pjrt {
            self.pjrt_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.sim_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_response(&self, latency: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros().max(1) as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate percentile from the log2 histogram (upper bucket edge).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.latency_us.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human snapshot.
    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} batches={} (pjrt={}, sim={}, mean size {:.1}) \
             latency mean={:.0}us p50<{}us p99<{}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_batches.load(Ordering::Relaxed),
            self.sim_batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2, true);
        m.record_response(Duration::from_micros(100));
        m.record_response(Duration::from_micros(200));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
        assert_eq!(m.pjrt_batches.load(Ordering::Relaxed), 1);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        assert!((m.mean_latency_us() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            m.record_response(Duration::from_micros(us));
        }
        let p50 = m.latency_percentile_us(50.0);
        assert!((64..=256).contains(&p50), "p50 {p50}");
        let p99 = m.latency_percentile_us(99.0);
        assert!(p99 >= 100_000, "p99 {p99}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(m.report().contains("requests=0"));
    }
}
