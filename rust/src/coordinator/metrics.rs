//! Serving metrics: lock-free counters + a log2-bucketed latency
//! histogram (atomics only on the hot path; percentile math at
//! snapshot), plus per-tenant request/latency/score gauges
//! (DESIGN.md §14). Tenant handles are `Arc<TenantMetrics>` resolved
//! once at submit and carried inside the request, so the hot path
//! never locks the tenant directory.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of log2 latency buckets: bucket i covers [2^i, 2^(i+1)) us.
const BUCKETS: usize = 32;

/// Per-tenant serving gauges: all atomics, shared between the submit
/// path (requests), the workers (responses/latency) and the registry
/// (train score after register/refit).
#[derive(Debug, Default)]
pub struct TenantMetrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    latency_sum_us: AtomicU64,
    /// Mean chip-in-the-loop train score across dies (classification:
    /// error rate; regression: RMSE), stored as f64 bits.
    score_bits: AtomicU64,
}

impl TenantMetrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_response(&self, latency: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add(latency.as_micros().max(1) as u64, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Record the tenant's train score (set at register and refit).
    pub fn set_score(&self, score: f64) {
        self.score_bits.store(score.to_bits(), Ordering::Relaxed);
    }

    pub fn score(&self) -> f64 {
        f64::from_bits(self.score_bits.load(Ordering::Relaxed))
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    /// Client-facing submit events: a single predict ticks this once,
    /// and a `BatchPredict` of B rows ALSO ticks it once (while
    /// `requests` counts all B rows) — so `requests / submissions` is
    /// the mean rows-per-submission, the protocol-level batching the
    /// v1 wire buys (DESIGN.md §15).
    pub submissions: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub pjrt_batches: AtomicU64,
    pub sim_batches: AtomicU64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_requests: AtomicU64,
    /// Physical die conversions booked while serving — a virtual
    /// request books `RotationPlan::passes()` of them (DESIGN.md §13),
    /// so `conversions / responses` is the fleet's mean pass cost.
    pub conversions: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    // fleet-health counters (DESIGN.md §12)
    /// Probe passes executed across the fleet.
    pub probes: AtomicU64,
    /// Tier-1 counting-window renormalisations applied.
    pub renorms: AtomicU64,
    /// Tier-2 chip-in-the-loop head refits completed.
    pub refits: AtomicU64,
    /// Dies quarantined after failed recovery.
    pub quarantines: AtomicU64,
    /// Hot standbys promoted into rotation.
    pub promotions: AtomicU64,
    /// Per-tenant gauges, keyed by tenant name (DESIGN.md §14). The
    /// mutex guards only registration/removal and the report snapshot —
    /// hot-path recording goes through the `Arc<TenantMetrics>` carried
    /// in each request.
    tenants: Mutex<BTreeMap<String, Arc<TenantMetrics>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One client-facing submit event (single or whole batch).
    pub fn record_submission(&self) {
        self.submissions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize, pjrt: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        if pjrt {
            self.pjrt_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.sim_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_conversions(&self, n: u64) {
        self.conversions.fetch_add(n, Ordering::Relaxed);
    }

    /// Create (or return) the gauge handle for a tenant.
    pub fn register_tenant(&self, name: &str) -> Arc<TenantMetrics> {
        let mut map = self.tenants.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(TenantMetrics::default())),
        )
    }

    /// Drop a tenant's gauges from the report (outstanding request tags
    /// keep their handle alive until answered).
    pub fn drop_tenant(&self, name: &str) {
        self.tenants.lock().unwrap().remove(name);
    }

    /// The gauge handle for a tenant, if registered — never inserts
    /// (the fleet manager uses this so a refit racing an unregister
    /// cannot resurrect a dropped tenant's gauges).
    pub fn tenant_handle(&self, name: &str) -> Option<Arc<TenantMetrics>> {
        self.tenants.lock().unwrap().get(name).map(Arc::clone)
    }

    /// Snapshot of the per-tenant gauge handles.
    pub fn tenant_snapshot(&self) -> Vec<(String, Arc<TenantMetrics>)> {
        self.tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    pub fn record_response(&self, latency: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros().max(1) as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate percentile from the log2 histogram, interpolated
    /// within the bucket: the k-th of `count` samples in bucket
    /// [2^i, 2^(i+1)) is placed at `2^i * (1 + (k - 0.5)/count)` —
    /// uniform-within-bucket assumption. (Reporting the upper bucket
    /// edge, as this used to, biases the estimate up to 2x high.)
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, b) in self.latency_us.iter().enumerate() {
            let count = b.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            if acc + count >= target {
                let k = (target - acc) as f64; // k-th sample inside this bucket
                let lower = (1u64 << i) as f64;
                let frac = ((k - 0.5) / count as f64).clamp(0.0, 1.0);
                return (lower + lower * frac).round() as u64;
            }
            acc += count;
        }
        1u64 << BUCKETS
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human snapshot (plus a ` tenant[..]` clause per
    /// registered tenant).
    pub fn report(&self) -> String {
        let tenants: String = self
            .tenant_snapshot()
            .iter()
            .map(|(name, m)| {
                format!(
                    " tenant[{name}: req={} resp={} mean={:.0}us train_score={:.4}]",
                    m.requests.load(Ordering::Relaxed),
                    m.responses.load(Ordering::Relaxed),
                    m.mean_latency_us(),
                    m.score(),
                )
            })
            .collect();
        format!(
            "requests={} submissions={} responses={} batches={} (pjrt={}, sim={}, mean size {:.1}) \
             conversions={} latency mean={:.0}us p50~{}us p99~{}us \
             fleet probes={} renorms={} refits={} quarantines={} promotions={}{tenants}",
            self.requests.load(Ordering::Relaxed),
            self.submissions.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_batches.load(Ordering::Relaxed),
            self.sim_batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.conversions.load(Ordering::Relaxed),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
            self.probes.load(Ordering::Relaxed),
            self.renorms.load(Ordering::Relaxed),
            self.refits.load(Ordering::Relaxed),
            self.quarantines.load(Ordering::Relaxed),
            self.promotions.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_submission();
        m.record_batch(2, true);
        m.record_response(Duration::from_micros(100));
        m.record_response(Duration::from_micros(200));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.submissions.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("submissions=1"), "{}", m.report());
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
        assert_eq!(m.pjrt_batches.load(Ordering::Relaxed), 1);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        assert!((m.mean_latency_us() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            m.record_response(Duration::from_micros(us));
        }
        // 5th of 10 samples is 160 us, in bucket [128, 256): the
        // interpolated estimate must stay inside that bucket (tighter
        // than the old upper-edge report of 256)
        let p50 = m.latency_percentile_us(50.0);
        assert!((128..256).contains(&p50), "p50 {p50}");
        // 100_000 us lives in bucket [65536, 131072): p99 must bracket
        // it within the bucket instead of reporting the 131072 edge
        let p99 = m.latency_percentile_us(99.0);
        assert!((65536..131072).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn interpolated_percentile_bias_is_bounded_by_half_bucket() {
        // upper-edge reporting returned up to 2x the true latency; the
        // interpolated estimate of a single-valued distribution lands at
        // the bucket midpoint — at most ~1.5x the bucket's lower edge
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_response(Duration::from_micros(1000)); // bucket [512, 1024)
        }
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!((512..1024).contains(&p50), "p50 {p50}");
        assert!((512..1024).contains(&p99), "p99 {p99}");
        // and the uniform-within-bucket spread is monotone in p
        assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
    }

    #[test]
    fn single_sample_percentile_sits_mid_bucket() {
        let m = Metrics::new();
        m.record_response(Duration::from_micros(3000)); // bucket [2048, 4096)
        let p50 = m.latency_percentile_us(50.0);
        assert_eq!(p50, 3072, "one sample interpolates to the bucket midpoint");
    }

    #[test]
    fn conversions_accumulate_and_report() {
        let m = Metrics::new();
        m.record_conversions(9);
        m.record_conversions(9);
        assert_eq!(m.conversions.load(Ordering::Relaxed), 18);
        assert!(m.report().contains("conversions=18"), "{}", m.report());
    }

    #[test]
    fn fleet_counters_appear_in_report() {
        let m = Metrics::new();
        m.probes.fetch_add(3, Ordering::Relaxed);
        m.renorms.fetch_add(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("probes=3"), "{r}");
        assert!(r.contains("renorms=1"), "{r}");
        assert!(r.contains("quarantines=0"), "{r}");
    }

    #[test]
    fn tenant_gauges_register_record_and_report() {
        let m = Metrics::new();
        let t = m.register_tenant("digits");
        t.record_request();
        t.record_response(Duration::from_micros(200));
        t.record_response(Duration::from_micros(400));
        t.set_score(0.0625);
        assert_eq!(t.requests.load(Ordering::Relaxed), 1);
        assert_eq!(t.responses.load(Ordering::Relaxed), 2);
        assert!((t.mean_latency_us() - 300.0).abs() < 1e-9);
        assert!((t.score() - 0.0625).abs() < 1e-15);
        let r = m.report();
        assert!(r.contains("tenant[digits:"), "{r}");
        assert!(r.contains("resp=2"), "{r}");
        assert!(r.contains("train_score=0.0625"), "{r}");
        // re-registering returns the same handle
        let t2 = m.register_tenant("digits");
        assert_eq!(t2.requests.load(Ordering::Relaxed), 1);
        m.drop_tenant("digits");
        assert!(!m.report().contains("tenant[digits"), "{}", m.report());
        // the outstanding handle still works after the drop
        t.record_request();
        assert_eq!(t.requests.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(m.report().contains("requests=0"));
    }
}
