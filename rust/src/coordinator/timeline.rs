//! Fleet timeline profiler (DESIGN.md §19): where every die's wall
//! clock goes, as exact per-segment microsecond ledgers plus a
//! Chrome-trace-exportable event stream.
//!
//! Each worker owns a [`Stamper`] over its die's [`DieTimeline`]. The
//! stamper closes segments *contiguously* — every `mark` attributes
//! the interval since the previous mark to one [`Segment`] — so the
//! accumulated per-segment times tile the die's profiled wall clock
//! with no gaps or overlaps, and occupancy fractions sum to 1.0 by
//! construction.
//!
//! Hot-path cost mirrors the flight recorder (DESIGN.md §16): one
//! relaxed `fetch_add` per segment counter, one relaxed `fetch_add` to
//! claim a ring slot plus one *uncontended* `try_lock` to write the
//! event. A worker never blocks on the profiler; a contended slot
//! drops the event (the occupancy ledger still counts it).
//!
//! The raw event stream exports as Chrome trace-event JSON
//! ([`chrome_trace_json`]): one process per die, one thread track per
//! segment, flow events linking a request's path batch-wait ->
//! convert -> transfer. [`validate_chrome_trace`] is the schema check
//! `velm client timeline --check` and CI run over the export.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::sync::{AtomicU64, Mutex, Ordering, TryLockError};

use crate::protocol::stats::{DieOccupancy, Segment, TimelineEvent, SEGMENTS};
use crate::util::json::Value;

/// Per-die event ring capacity: enough for several seconds of serving
/// at typical segment rates without measurable memory.
pub const DEFAULT_TIMELINE_CAPACITY: usize = 2048;

/// One die's timeline: the exact per-segment microsecond ledger plus
/// a fixed ring of the most recent stamped intervals.
pub struct DieTimeline {
    die: u32,
    /// Shared profiling epoch — every die measures on one time axis.
    epoch: Instant,
    /// Accumulated microseconds per segment, indexed by
    /// [`Segment::code`].
    seg_us: [AtomicU64; SEGMENTS],
    /// Monotone claim counter; slot = claim % capacity.
    head: AtomicU64,
    slots: Vec<Mutex<Option<TimelineEvent>>>,
}

impl DieTimeline {
    fn new(die: u32, epoch: Instant, capacity: usize) -> Self {
        DieTimeline {
            die,
            epoch,
            seg_us: std::array::from_fn(|_| AtomicU64::new(0)),
            head: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Die (worker index) this timeline belongs to.
    pub fn die(&self) -> u32 {
        self.die
    }

    /// Microseconds since the profiling epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Microseconds from the profiling epoch to `t` (saturating to 0
    /// for instants before it) — converts caller-captured stamps like
    /// the batcher's `collected` onto the timeline's axis.
    pub fn us_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record one closed interval. Zero-width intervals are dropped:
    /// they carry no occupancy and would only churn the ring.
    pub fn stamp(&self, seg: Segment, start_us: u64, end_us: u64, req_id: Option<u64>) {
        if end_us <= start_us {
            return;
        }
        // relaxed-ok: each segment counter is an independent monotone
        // microsecond ledger; the occupancy snapshot reads one copy
        // and tolerates counters that lag each other by a segment.
        self.seg_us[seg.code() as usize].fetch_add(end_us - start_us, Ordering::Relaxed);
        // relaxed-ok: `head` only allocates slot numbers; the event
        // itself is published by the slot mutex (acquire/release on
        // lock/unlock), exactly like the flight recorder's ring.
        let claim = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = (claim % self.slots.len() as u64) as usize;
        let event = TimelineEvent { die: self.die, seg, start_us, end_us, req_id };
        match self.slots[slot].try_lock() {
            Ok(mut guard) => *guard = Some(event),
            // A previous writer panicked mid-store: the slot still
            // holds a structurally sound entry; overwrite clears the
            // poison.
            Err(TryLockError::Poisoned(poisoned)) => *poisoned.into_inner() = Some(event),
            // Contended slot (a dump holds it): drop the event rather
            // than stall the worker. The seg_us ledger already counted
            // the interval, so occupancy stays exact.
            Err(TryLockError::WouldBlock) => {}
        }
    }

    /// This die's occupancy ledger (one relaxed copy per segment).
    pub fn occupancy(&self) -> DieOccupancy {
        DieOccupancy {
            die: self.die,
            // relaxed-ok: monotone counters read as a diagnostic
            // snapshot; a read racing a stamp may miss the newest
            // interval, which the export tolerates.
            seg_us: std::array::from_fn(|i| self.seg_us[i].load(Ordering::Relaxed)),
        }
    }

    /// Every event currently held in the ring, in no particular
    /// order. Entries a writer is lapping mid-dump may surface as
    /// their older occupant or be skipped.
    fn dump(&self) -> Vec<TimelineEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let guard = match slot.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            if let Some(event) = guard.as_ref() {
                out.push(event.clone());
            }
        }
        out
    }
}

impl std::fmt::Debug for DieTimeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DieTimeline")
            .field("die", &self.die)
            .field("capacity", &self.slots.len())
            .finish()
    }
}

/// The fleet's timeline: lazily-registered per-die ledgers sharing one
/// profiling epoch. Lives on `Metrics` so workers, the dispatcher and
/// the stats snapshot all see the same instance.
pub struct Timeline {
    epoch: Instant,
    capacity: usize,
    dies: Mutex<Vec<Arc<DieTimeline>>>,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline::with_capacity(DEFAULT_TIMELINE_CAPACITY)
    }

    /// A timeline whose per-die rings hold `capacity.max(1)` events.
    pub fn with_capacity(capacity: usize) -> Self {
        Timeline {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            dies: Mutex::new(Vec::new()),
        }
    }

    /// Microseconds since the profiling epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// The ledger for `die`, created on first use (idempotent — a
    /// re-registration returns the existing ledger, so a restarted
    /// worker keeps its die's history).
    pub fn register(&self, die: u32) -> Arc<DieTimeline> {
        let mut dies = match self.dies.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(existing) = dies.iter().find(|t| t.die == die) {
            return Arc::clone(existing);
        }
        let t = Arc::new(DieTimeline::new(die, self.epoch, self.capacity));
        dies.push(Arc::clone(&t));
        t
    }

    /// A contiguous-interval stamper for `die` (registers the die).
    pub fn stamper(&self, die: u32) -> Stamper {
        let tl = self.register(die);
        let cursor_us = tl.now_us();
        Stamper { tl, cursor_us }
    }

    /// Per-die occupancy ledgers, sorted by die id.
    pub fn occupancy(&self) -> Vec<DieOccupancy> {
        let dies = match self.dies.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let mut out: Vec<DieOccupancy> = dies.iter().map(|t| t.occupancy()).collect();
        out.sort_by_key(|o| o.die);
        out
    }

    /// The newest `last` events across the fleet, oldest first
    /// (chronological by start, ties broken by end then die) — the
    /// exact shape [`chrome_trace_json`] wants.
    pub fn recent(&self, last: usize) -> Vec<TimelineEvent> {
        let dies: Vec<Arc<DieTimeline>> = {
            let guard = match self.dies.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.clone()
        };
        let mut events: Vec<TimelineEvent> = dies.iter().flat_map(|t| t.dump()).collect();
        events.sort_by_key(|e| (e.start_us, e.end_us, e.die));
        if events.len() > last {
            events.drain(..events.len() - last);
        }
        events
    }
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline::new()
    }
}

impl std::fmt::Debug for Timeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dies = match self.dies.lock() {
            Ok(guard) => guard.len(),
            Err(poisoned) => poisoned.into_inner().len(),
        };
        f.debug_struct("Timeline")
            .field("capacity", &self.capacity)
            .field("dies", &dies)
            .finish()
    }
}

/// A worker's segment clock: every [`Stamper::mark`] closes the
/// interval since the previous mark and attributes it to one segment,
/// so consecutive marks tile the die's wall clock exactly.
#[derive(Debug)]
pub struct Stamper {
    tl: Arc<DieTimeline>,
    cursor_us: u64,
}

impl Stamper {
    /// Attribute the interval since the previous mark to `seg`, with
    /// `req_id` carrying the first request id worked on (for Chrome
    /// flow linkage). Returns the interval's width in microseconds.
    pub fn mark(&mut self, seg: Segment, req_id: Option<u64>) -> u64 {
        let now_us = self.tl.now_us().max(self.cursor_us);
        self.tl.stamp(seg, self.cursor_us, now_us, req_id);
        let width = now_us - self.cursor_us;
        self.cursor_us = now_us;
        width
    }

    /// Attribute the interval from the previous mark up to `at` — an
    /// instant the caller captured, e.g. the batcher's `collected`
    /// stamp — to `seg`. `at` is clamped into [previous mark, now] so
    /// marks stay contiguous and monotone even when the stamp predates
    /// the cursor (a carried row from an earlier window). Returns the
    /// interval's width in microseconds.
    pub fn mark_until(&mut self, seg: Segment, at: Instant, req_id: Option<u64>) -> u64 {
        let now_us = self.tl.now_us().max(self.cursor_us);
        let at_us = self.tl.us_of(at).clamp(self.cursor_us, now_us);
        self.tl.stamp(seg, self.cursor_us, at_us, req_id);
        let width = at_us - self.cursor_us;
        self.cursor_us = at_us;
        width
    }

    /// The underlying die ledger.
    pub fn die_timeline(&self) -> &Arc<DieTimeline> {
        &self.tl
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export + validator
// ---------------------------------------------------------------------------

/// Render events as Chrome trace-event JSON (a bare event array, the
/// format Perfetto / `chrome://tracing` load directly): one process
/// per die (`pid` = die id), one thread track per segment (`tid` =
/// segment code), duration `B`/`E` pairs per interval, and flow events
/// (`s` on batch-wait, `f` with `bp:"e"` on convert / rotation-pass /
/// transfer) linking a request's path across segments via its id.
///
/// Events should be chronological by start (what [`Timeline::recent`]
/// returns); the export sorts defensively so hand-built inputs work
/// too.
pub fn chrome_trace_json(events: &[TimelineEvent]) -> String {
    let mut events: Vec<&TimelineEvent> = events.iter().collect();
    events.sort_by_key(|e| (e.start_us, e.end_us, e.die));

    let num = |n: u64| Value::Num(n as f64);
    let s = |t: &str| Value::Str(t.to_string());
    let mut recs: Vec<(u64, Value)> = Vec::new();

    // Metadata at ts 0: name each die's process and each segment's
    // thread track so Perfetto labels the UI.
    let mut dies: Vec<u32> = events.iter().map(|e| e.die).collect();
    dies.sort_unstable();
    dies.dedup();
    for &die in &dies {
        recs.push((
            0,
            Value::Obj(vec![
                ("ph".into(), s("M")),
                ("name".into(), s("process_name")),
                ("ts".into(), num(0)),
                ("pid".into(), num(die as u64)),
                ("tid".into(), num(0)),
                (
                    "args".into(),
                    Value::Obj(vec![("name".into(), Value::Str(format!("die {die}")))]),
                ),
            ]),
        ));
        for seg in Segment::ALL {
            recs.push((
                0,
                Value::Obj(vec![
                    ("ph".into(), s("M")),
                    ("name".into(), s("thread_name")),
                    ("ts".into(), num(0)),
                    ("pid".into(), num(die as u64)),
                    ("tid".into(), num(seg.code() as u64)),
                    (
                        "args".into(),
                        Value::Obj(vec![("name".into(), s(seg.name()))]),
                    ),
                ]),
            ));
        }
    }

    for e in &events {
        let base = |ph: &str, ts: u64| {
            vec![
                ("ph".into(), s(ph)),
                ("name".into(), s(e.seg.name())),
                ("cat".into(), s("segment")),
                ("ts".into(), num(ts)),
                ("pid".into(), num(e.die as u64)),
                ("tid".into(), num(e.seg.code() as u64)),
            ]
        };
        recs.push((e.start_us, Value::Obj(base("B", e.start_us))));
        // flow linkage: a request enters the timeline at batch-wait
        // ("s") and is bound into each serving segment ("f", bp:"e")
        if let Some(id) = e.req_id {
            let flow_ph = match e.seg {
                Segment::BatchWait => Some("s"),
                Segment::Convert | Segment::RotationPass | Segment::Transfer => Some("f"),
                _ => None,
            };
            if let Some(ph) = flow_ph {
                let mut flow = vec![
                    ("ph".into(), s(ph)),
                    ("name".into(), s("req")),
                    ("cat".into(), s("flow")),
                    ("ts".into(), num(e.start_us)),
                    ("pid".into(), num(e.die as u64)),
                    ("tid".into(), num(e.seg.code() as u64)),
                    ("id".into(), num(id)),
                ];
                if ph == "f" {
                    flow.push(("bp".into(), s("e")));
                }
                recs.push((e.start_us, Value::Obj(flow)));
            }
        }
        recs.push((e.end_us, Value::Obj(base("E", e.end_us))));
    }

    // Stable sort by timestamp: for equal stamps the push order above
    // survives, so a segment's E precedes the next segment's B on the
    // same track and zero-width pairs stay B-before-E.
    recs.sort_by_key(|&(ts, _)| ts);
    let mut out = String::new();
    Value::Arr(recs.into_iter().map(|(_, v)| v).collect()).write(&mut out);
    out
}

/// Schema-validate a Chrome trace-event JSON document (the `--check`
/// path in `velm client timeline` and CI): the document must be a JSON
/// array whose every record carries `ph` (string), `ts`, `pid` and
/// `tid` (numbers), timestamps must be monotone non-decreasing, and
/// every `(pid, tid)` track's `B`/`E` events must nest — never more
/// ends than begins, and no begin left open at the end. Returns the
/// number of records checked.
pub fn validate_chrome_trace(text: &str) -> Result<usize, String> {
    let doc = Value::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let recs = doc
        .as_arr()
        .ok_or("trace document is not a JSON array of events")?;
    let mut last_ts = f64::NEG_INFINITY;
    let mut depth: BTreeMap<(u64, u64), u64> = BTreeMap::new();
    for (i, rec) in recs.iter().enumerate() {
        let ph = rec
            .get("ph")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("record {i}: missing string 'ph'"))?;
        let ts = rec
            .get("ts")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("record {i}: missing numeric 'ts'"))?;
        let pid = rec
            .get("pid")
            .and_then(Value::as_u64)
            .ok_or_else(|| format!("record {i}: missing numeric 'pid'"))?;
        let tid = rec
            .get("tid")
            .and_then(Value::as_u64)
            .ok_or_else(|| format!("record {i}: missing numeric 'tid'"))?;
        if ts < last_ts {
            return Err(format!(
                "record {i}: timestamp {ts} goes backwards (previous {last_ts})"
            ));
        }
        last_ts = ts;
        match ph {
            "B" => *depth.entry((pid, tid)).or_insert(0) += 1,
            "E" => {
                let d = depth.entry((pid, tid)).or_insert(0);
                if *d == 0 {
                    return Err(format!(
                        "record {i}: 'E' without a matching 'B' on track pid={pid} tid={tid}"
                    ));
                }
                *d -= 1;
            }
            _ => {}
        }
    }
    for (&(pid, tid), &d) in &depth {
        if d != 0 {
            return Err(format!(
                "{d} unclosed 'B' event(s) on track pid={pid} tid={tid}"
            ));
        }
    }
    Ok(recs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(die: u32, seg: Segment, start_us: u64, end_us: u64, req: Option<u64>) -> TimelineEvent {
        TimelineEvent { die, seg, start_us, end_us, req_id: req }
    }

    #[test]
    fn stamps_accumulate_and_fractions_sum_to_one() {
        let tl = Timeline::with_capacity(64);
        let die = tl.register(0);
        die.stamp(Segment::Idle, 0, 500, None);
        die.stamp(Segment::BatchWait, 500, 620, Some(1));
        die.stamp(Segment::Convert, 620, 900, Some(1));
        die.stamp(Segment::Transfer, 900, 1000, Some(1));
        let occ = die.occupancy();
        assert_eq!(occ.total_us(), 1000);
        assert_eq!(occ.seg_us[Segment::Idle.code() as usize], 500);
        assert_eq!(occ.seg_us[Segment::Convert.code() as usize], 280);
        let sum: f64 = occ.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
        // zero-width intervals are dropped entirely
        die.stamp(Segment::Control, 1000, 1000, None);
        assert_eq!(die.occupancy().total_us(), 1000);
        assert_eq!(tl.recent(100).len(), 4);
    }

    #[test]
    fn stamper_tiles_the_wall_clock_with_no_gaps() {
        let tl = Timeline::with_capacity(64);
        let mut st = tl.stamper(0);
        std::thread::sleep(std::time::Duration::from_millis(2));
        st.mark(Segment::Idle, None);
        std::thread::sleep(std::time::Duration::from_millis(2));
        st.mark(Segment::Convert, Some(7));
        let events = tl.recent(100);
        assert!(!events.is_empty());
        // contiguity: each event starts where the previous one ended
        for pair in events.windows(2) {
            assert_eq!(pair[0].end_us, pair[1].start_us, "gap between {pair:?}");
        }
        let occ = &tl.occupancy()[0];
        let spanned: u64 = events.iter().map(|e| e.end_us - e.start_us).sum();
        assert_eq!(occ.total_us(), spanned, "ledger and events must agree");
        let sum: f64 = occ.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
    }

    #[test]
    fn mark_until_splits_the_span_at_a_captured_instant() {
        let tl = Timeline::with_capacity(64);
        let mut st = tl.stamper(0);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let boundary = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // the worker's shape: idle until the batcher's stamp, then
        // batch-wait to now — the two must tile with no gap
        let idle = st.mark_until(Segment::Idle, boundary, None);
        let wait = st.mark(Segment::BatchWait, Some(1));
        assert!(idle >= 1000, "idle span {idle} us");
        assert!(wait >= 1000, "batch-wait span {wait} us");
        let events = tl.recent(10);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].end_us, events[1].start_us, "contiguous at the boundary");
        // a stamp that predates the cursor (a carried row) clamps to a
        // zero-width idle span instead of rewinding the clock
        let stale = Instant::now() - std::time::Duration::from_secs(1);
        assert_eq!(st.mark_until(Segment::Idle, stale, None), 0);
        let sum: f64 = tl.occupancy()[0].fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
    }

    #[test]
    fn register_is_idempotent_and_recent_merges_dies_sorted() {
        let tl = Timeline::with_capacity(8);
        let a = tl.register(1);
        let b = tl.register(1);
        assert!(Arc::ptr_eq(&a, &b), "re-registration returns the same ledger");
        tl.register(0).stamp(Segment::Idle, 10, 20, None);
        a.stamp(Segment::Convert, 0, 5, Some(3));
        let events = tl.recent(10);
        assert_eq!(events.len(), 2);
        assert!(events[0].start_us <= events[1].start_us, "oldest first");
        assert_eq!(tl.occupancy().iter().map(|o| o.die).collect::<Vec<_>>(), vec![0, 1]);
        // the ring caps history: 20 stamps through a capacity-8 ring
        for i in 0..20 {
            a.stamp(Segment::Transfer, 100 + i, 101 + i, None);
        }
        assert!(tl.recent(100).len() <= 8 + 1, "ring must cap per-die history");
        assert_eq!(tl.recent(3).len(), 3, "recent truncates to the newest N");
    }

    #[test]
    fn chrome_export_validates_and_links_flows() {
        let events = vec![
            event(0, Segment::Idle, 0, 500, None),
            event(0, Segment::BatchWait, 500, 620, Some(41)),
            event(0, Segment::Convert, 620, 900, Some(41)),
            event(1, Segment::Idle, 0, 620, None),
            event(0, Segment::Transfer, 900, 1000, Some(41)),
            // zero-width pair must stay balanced in the export
            event(1, Segment::RotationPass, 620, 620, Some(42)),
        ];
        let text = chrome_trace_json(&events);
        let n = validate_chrome_trace(&text).unwrap();
        assert!(n > events.len() * 2, "B/E pairs plus metadata: {n} records");
        assert!(text.contains("\"ph\":\"s\""), "flow start on batch-wait");
        assert!(text.contains("\"ph\":\"f\""), "flow bind on convert/transfer");
        assert!(text.contains("\"bp\":\"e\""), "flow binds to the enclosing slice");
        assert!(text.contains("\"process_name\""), "per-die process metadata");
        assert!(text.contains("die 1"), "both dies named");
        let empty = chrome_trace_json(&[]);
        assert_eq!(validate_chrome_trace(&empty).unwrap(), 0);
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":[]}")
            .unwrap_err()
            .contains("array"));
        // missing tid
        let err = validate_chrome_trace(r#"[{"ph":"B","ts":1,"pid":0}]"#).unwrap_err();
        assert!(err.contains("tid"), "{err}");
        // non-monotone timestamps
        let err = validate_chrome_trace(
            r#"[{"ph":"B","ts":5,"pid":0,"tid":0},{"ph":"E","ts":4,"pid":0,"tid":0}]"#,
        )
        .unwrap_err();
        assert!(err.contains("backwards"), "{err}");
        // E without B
        let err =
            validate_chrome_trace(r#"[{"ph":"E","ts":1,"pid":0,"tid":0}]"#).unwrap_err();
        assert!(err.contains("without a matching"), "{err}");
        // unclosed B
        let err =
            validate_chrome_trace(r#"[{"ph":"B","ts":1,"pid":0,"tid":2}]"#).unwrap_err();
        assert!(err.contains("unclosed"), "{err}");
        // balanced pair across tracks is fine
        assert_eq!(
            validate_chrome_trace(
                r#"[{"ph":"B","ts":1,"pid":0,"tid":0},{"ph":"E","ts":2,"pid":0,"tid":0}]"#,
            )
            .unwrap(),
            2
        );
    }

    #[test]
    fn concurrent_stamps_and_reads_never_panic() {
        const STAMPS: u64 = if cfg!(miri) { 25 } else { 500 };
        const READS: usize = if cfg!(miri) { 10 } else { 200 };
        let tl = Arc::new(Timeline::with_capacity(16));
        std::thread::scope(|s| {
            for die in 0..4u32 {
                let tl = Arc::clone(&tl);
                s.spawn(move || {
                    let d = tl.register(die);
                    for i in 0..STAMPS {
                        d.stamp(Segment::Convert, i, i + 1, Some(i));
                    }
                });
            }
            let tl = Arc::clone(&tl);
            s.spawn(move || {
                for _ in 0..READS {
                    for o in tl.occupancy() {
                        let sum: f64 = o.fractions().iter().sum();
                        assert!(sum == 0.0 || (sum - 1.0).abs() < 1e-9);
                    }
                    assert!(tl.recent(64).len() <= 64);
                }
            });
        });
        let occ = tl.occupancy();
        assert_eq!(occ.len(), 4);
        for o in &occ {
            assert_eq!(o.total_us(), STAMPS, "every stamp lands in the ledger");
        }
    }
}
