//! Dynamic batching: collect requests from a channel into batches bounded
//! by size and by holding time — the standard serving trade-off between
//! per-request latency and per-batch amortisation (here: hitting the
//! compiled PJRT batch shapes).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::request::ClassifyRequest;

/// Blockingly collect the next batch from `rx`.
///
/// Waits (forever) for the first request; then drains until `max_batch`
/// requests are held or `max_wait` has elapsed since the first one.
/// Returns `None` once the channel is closed and drained — the worker's
/// shutdown signal.
pub fn collect_batch(
    rx: &Receiver<ClassifyRequest>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<ClassifyRequest>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + max_wait;
    let mut batch = vec![first];
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> ClassifyRequest {
        let (tx, _rx) = mpsc::channel();
        ClassifyRequest { id, features: vec![], submitted: Instant::now(), reply: tx }
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let b = collect_batch(&rx, 4, Duration::from_millis(50)).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].id, 0);
        assert_eq!(b[3].id, 3);
        // the rest are still queued
        let b2 = collect_batch(&rx, 100, Duration::from_millis(5)).unwrap();
        assert_eq!(b2.len(), 6);
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        let t0 = Instant::now();
        let b = collect_batch(&rx, 64, Duration::from_millis(20)).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(18));
        drop(tx);
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = mpsc::channel::<ClassifyRequest>();
        drop(tx);
        assert!(collect_batch(&rx, 8, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn preserves_order_and_no_duplicates() {
        let (tx, rx) = mpsc::channel();
        for i in 0..50 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(b) = collect_batch(&rx, 7, Duration::from_millis(1)) {
            seen.extend(b.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }
}
