//! Dynamic batching: collect messages from a channel into batches bounded
//! by size and by holding time — the standard serving trade-off between
//! per-request latency and per-batch amortisation (here: hitting the
//! compiled PJRT batch shapes). Batching is tenant-blind (DESIGN.md
//! §14): the hidden layer is task-agnostic, so rows addressed to
//! different tenants coalesce into one batch and cost one hidden-layer
//! pass; the worker applies each row's own head afterwards — but batch
//! *admission* is tenant-fair: when more rows are pending than one
//! window's conversion budget holds, the batcher round-robins one row
//! per tenant instead of taking the queue head-first, so a flooding
//! tenant cannot starve a trickle tenant out of the die (DESIGN.md
//! §17). Rows left behind park in the caller-owned carry deque and get
//! first claim on the next window. Under light load (pending fits the
//! budget) admission degenerates to exact FIFO. Fleet-health and
//! registry control messages ride the same channel (so control stays
//! ordered with respect to control: a probe queued after a drift
//! injection observes the drifted die, a request routed after a REGISTER
//! ack finds the head installed) and are split out of the classify batch
//! for the worker to run after the batch — traffic-vs-control ordering
//! is batch-granular.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::request::{ClassifyRequest, ControlMsg, WorkerMsg};

/// One drained unit of worker input: a classify batch (possibly empty)
/// plus any control messages that arrived in the same window.
pub struct Batch {
    pub requests: Vec<ClassifyRequest>,
    pub control: Vec<ControlMsg>,
}

/// Blockingly collect the next batch from `rx`, carried rows first.
///
/// Rows parked in `carry` by the previous window are admitted ahead of
/// the channel. When both carry and channel are empty this waits
/// (forever) for the first message; then drains until the held classify
/// requests cost `max_batch` *physical conversions* or `max_wait` has
/// elapsed. `cost_per_request` is the die's pass cost (DESIGN.md §13):
/// 1 on a physical die, so the bound counts requests;
/// `RotationPlan::passes()` on a virtual die, so a P-pass die holds 1/P
/// as many requests per batch and the per-batch conversion budget stays
/// constant fleet-wide. At least one request is always collected.
///
/// When more rows are pending than the budget admits, admission is
/// tenant-fair: one row per tenant, round-robin in first-appearance
/// order (the default head counts as one tenant), FIFO within each
/// tenant; the leftovers go back to `carry` in arrival order. Otherwise
/// admission is exact FIFO and `carry` comes back empty.
///
/// A control-only window returns an empty-request batch — the
/// "empty-queue tick" that lets probes run on an idle worker. Returns
/// `None` once the channel is closed and both the channel and the carry
/// are drained — the worker's shutdown signal.
pub fn collect_batch(
    rx: &Receiver<WorkerMsg>,
    carry: &mut VecDeque<ClassifyRequest>,
    max_batch: usize,
    max_wait: Duration,
    cost_per_request: usize,
) -> Option<Batch> {
    let cost = cost_per_request.max(1);
    let max_requests = (max_batch / cost).max(1);
    let mut pending: Vec<ClassifyRequest> = carry.drain(..).collect();
    let mut control = Vec::new();
    if pending.is_empty() {
        // nothing carried over: block for the window-opening message
        push(&mut pending, &mut control, rx.recv().ok()?);
    }
    let deadline = Instant::now() + max_wait;
    while pending.len() < max_requests {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(msg) => push(&mut pending, &mut control, msg),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Overload sweep: once the window is full (or closed), take stock of
    // whatever is *already* queued without waiting — those rows are the
    // load-skew evidence the fair admission below needs, and they park
    // in the carry rather than sitting invisible in the channel.
    while let Ok(msg) = rx.try_recv() {
        push(&mut pending, &mut control, msg);
    }
    let requests = admit(pending, max_requests, carry);
    Some(Batch { requests, control })
}

/// Split `pending` into the admitted batch and the carried remainder.
/// Light load (everything fits) is exact FIFO; overload round-robins
/// one row per tenant in first-appearance order, FIFO within a tenant.
fn admit(
    mut pending: Vec<ClassifyRequest>,
    max_requests: usize,
    carry: &mut VecDeque<ClassifyRequest>,
) -> Vec<ClassifyRequest> {
    if pending.len() <= max_requests {
        return pending;
    }
    // per-tenant FIFO queues of row indices, keyed in first-appearance
    // order (tenant counts per die are small; linear scan beats hashing)
    let mut queues: Vec<VecDeque<usize>> = Vec::new();
    {
        let mut names: Vec<&str> = Vec::new();
        for (i, req) in pending.iter().enumerate() {
            let name = req.tenant.as_ref().map_or("", |t| t.name.as_ref());
            let qi = match names.iter().position(|&n| n == name) {
                Some(qi) => qi,
                None => {
                    names.push(name);
                    queues.push(VecDeque::new());
                    names.len() - 1
                }
            };
            queues[qi].push_back(i);
        }
    }
    let mut take = vec![false; pending.len()];
    let mut taken = 0usize;
    'rounds: loop {
        let mut any = false;
        for q in &mut queues {
            if let Some(i) = q.pop_front() {
                take[i] = true;
                taken += 1;
                any = true;
                if taken == max_requests {
                    break 'rounds;
                }
            }
        }
        if !any {
            break;
        }
    }
    // both the batch and the carry keep arrival order (the admitted
    // rows' indices are marked, so one ordered sweep splits the two)
    let mut admitted = Vec::with_capacity(max_requests);
    for (i, req) in pending.drain(..).enumerate() {
        if take[i] {
            admitted.push(req);
        } else {
            carry.push_back(req);
        }
    }
    admitted
}

fn push(pending: &mut Vec<ClassifyRequest>, control: &mut Vec<ControlMsg>, msg: WorkerMsg) {
    match msg {
        WorkerMsg::Classify(mut req) => {
            // Stage stamp (DESIGN.md §16): queue-wait ends the moment
            // the batcher pulls the request into a forming batch. A row
            // parked in the carry keeps its original stamp — the parked
            // time reads as batch-wait, which is what it is.
            req.collected = Some(Instant::now());
            pending.push(req);
        }
        WorkerMsg::Control(ctl) => control.push(ctl),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> WorkerMsg {
        tenant_req(id, None)
    }

    fn tenant_req(id: u64, tenant: Option<&str>) -> WorkerMsg {
        let (tx, _rx) = mpsc::channel();
        WorkerMsg::Classify(ClassifyRequest {
            id,
            features: vec![],
            tenant: tenant.map(|name| crate::coordinator::request::TenantTag {
                name: std::sync::Arc::from(name),
                metrics: std::sync::Arc::new(
                    crate::coordinator::metrics::TenantMetrics::default(),
                ),
            }),
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        })
    }

    fn ctl() -> WorkerMsg {
        WorkerMsg::Control(ControlMsg::SetEnv {
            vdd: None,
            temp_k: Some(310.0),
            age_sigma_vt: None,
            seed: 1,
        })
    }

    #[test]
    fn max_size_flush_collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let mut carry = VecDeque::new();
        let t0 = Instant::now();
        let b = collect_batch(&rx, &mut carry, 4, Duration::from_millis(200), 1).unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.requests[0].id, 0);
        assert_eq!(b.requests[3].id, 3);
        // a full batch flushes immediately, well before the deadline
        assert!(t0.elapsed() < Duration::from_millis(150));
        // the rest ride the carry (swept out of the channel) in order
        assert_eq!(carry.len(), 6);
        let b2 = collect_batch(&rx, &mut carry, 100, Duration::from_millis(5), 1).unwrap();
        assert_eq!(b2.requests.len(), 6);
        assert!(carry.is_empty());
    }

    #[test]
    fn timeout_flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        let t0 = Instant::now();
        let b = collect_batch(&rx, &mut VecDeque::new(), 64, Duration::from_millis(20), 1).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(b.control.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(18));
        drop(tx);
    }

    #[test]
    fn empty_queue_tick_delivers_control_without_requests() {
        // an idle worker woken only by a control message gets an
        // empty-request batch carrying the control — the probe tick
        let (tx, rx) = mpsc::channel();
        tx.send(ctl()).unwrap();
        let b = collect_batch(&rx, &mut VecDeque::new(), 8, Duration::from_millis(5), 1).unwrap();
        assert!(b.requests.is_empty());
        assert_eq!(b.control.len(), 1);
        assert!(matches!(b.control[0], ControlMsg::SetEnv { .. }));
    }

    #[test]
    fn control_rides_along_with_a_classify_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        tx.send(ctl()).unwrap();
        tx.send(req(1)).unwrap();
        let b = collect_batch(&rx, &mut VecDeque::new(), 8, Duration::from_millis(10), 1).unwrap();
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.control.len(), 1);
    }

    #[test]
    fn pass_cost_shrinks_the_request_window() {
        // a 4-pass virtual die with an 8-conversion budget holds at
        // most 2 requests per batch
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let mut carry = VecDeque::new();
        let b = collect_batch(&rx, &mut carry, 8, Duration::from_millis(50), 4).unwrap();
        assert_eq!(b.requests.len(), 2);
        // even a cost above the whole budget still moves one request
        let b = collect_batch(&rx, &mut carry, 8, Duration::from_millis(5), 100).unwrap();
        assert_eq!(b.requests.len(), 1);
    }

    #[test]
    fn cross_tenant_rows_coalesce_into_one_batch() {
        // the hidden layer is tenant-agnostic: rows for the default
        // head and two different tenants share one batch (one
        // hidden-layer pass on the worker), in arrival order
        let (tx, rx) = mpsc::channel();
        tx.send(tenant_req(0, None)).unwrap();
        tx.send(tenant_req(1, Some("digits"))).unwrap();
        tx.send(tenant_req(2, Some("brightness"))).unwrap();
        tx.send(tenant_req(3, Some("digits"))).unwrap();
        let b = collect_batch(&rx, &mut VecDeque::new(), 8, Duration::from_millis(10), 1).unwrap();
        assert_eq!(b.requests.len(), 4, "tenants must not split the batch");
        assert!(b.requests[0].tenant.is_none());
        assert_eq!(
            b.requests[1].tenant.as_ref().unwrap().name.as_ref(),
            "digits"
        );
        assert_eq!(
            b.requests[2].tenant.as_ref().unwrap().name.as_ref(),
            "brightness"
        );
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn one_submissions_burst_coalesces_into_one_window() {
        // the rows of one BatchPredict submission (DESIGN.md §15) are
        // routed back-to-back before the worker's window closes: they
        // must land in ONE batch — one hidden-layer pass for the whole
        // submission — even when rows address different tenants
        let (tx, rx) = mpsc::channel();
        for i in 0..12 {
            let tenant = if i % 2 == 0 { None } else { Some("slope") };
            tx.send(tenant_req(i, tenant)).unwrap();
        }
        let b = collect_batch(&rx, &mut VecDeque::new(), 64, Duration::from_millis(20), 1).unwrap();
        assert_eq!(b.requests.len(), 12, "burst split across windows");
        assert_eq!(
            b.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..12).collect::<Vec<_>>()
        );
    }

    #[test]
    fn batcher_stamps_the_collected_instant() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let b = collect_batch(&rx, &mut VecDeque::new(), 8, Duration::from_millis(5), 1).unwrap();
        let r = &b.requests[0];
        let collected = r.collected.expect("batcher must stamp collected");
        assert!(collected >= r.submitted, "queue stage must be non-negative");
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        drop(tx);
        assert!(collect_batch(&rx, &mut VecDeque::new(), 8, Duration::from_millis(5), 1).is_none());
    }

    #[test]
    fn preserves_order_and_no_duplicates() {
        let (tx, rx) = mpsc::channel();
        for i in 0..50 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        let mut carry = VecDeque::new();
        let mut seen = Vec::new();
        while let Some(b) = collect_batch(&rx, &mut carry, 7, Duration::from_millis(1), 1) {
            seen.extend(b.requests.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
        assert!(carry.is_empty(), "shutdown must drain the carry");
    }

    #[test]
    fn flooding_tenant_cannot_starve_the_trickle_tenant() {
        // 30 "flood" rows are already queued ahead of one "rare" row,
        // and the window only admits 4. FIFO admission would spend 8
        // whole windows on flood rows before rare ever lands; fair
        // admission round-robins tenants, so rare is in the FIRST batch
        let (tx, rx) = mpsc::channel();
        for i in 0..30 {
            tx.send(tenant_req(i, Some("flood"))).unwrap();
        }
        tx.send(tenant_req(99, Some("rare"))).unwrap();
        let mut carry = VecDeque::new();
        let b = collect_batch(&rx, &mut carry, 4, Duration::from_millis(50), 1).unwrap();
        assert_eq!(b.requests.len(), 4);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert!(ids.contains(&99), "rare row starved out of the window: {ids:?}");
        // flood keeps the remaining slots in its own FIFO order, and the
        // leftovers are parked (in arrival order) instead of re-queued
        assert_eq!(ids, vec![0, 1, 2, 99]);
        assert_eq!(carry.len(), 27);
        assert_eq!(carry.front().map(|r| r.id), Some(3));
    }

    #[test]
    fn fair_windows_deliver_every_row_exactly_once() {
        // a 3:1 tenant skew over 4-row windows: fairness must reorder
        // admission, never duplicate or drop a row — and while the
        // minority tenant has rows pending, every window carries some
        let (tx, rx) = mpsc::channel();
        let mut id = 0u64;
        let mut small_ids = Vec::new();
        for _ in 0..8 {
            for _ in 0..3 {
                tx.send(tenant_req(id, Some("big"))).unwrap();
                id += 1;
            }
            tx.send(tenant_req(id, Some("small"))).unwrap();
            small_ids.push(id);
            id += 1;
        }
        drop(tx);
        let mut carry = VecDeque::new();
        let mut seen = Vec::new();
        let mut small_pending = small_ids.len();
        while let Some(b) = collect_batch(&rx, &mut carry, 4, Duration::from_millis(1), 1) {
            let small_here =
                b.requests.iter().filter(|r| small_ids.contains(&r.id)).count();
            if small_pending > 0 {
                assert!(small_here > 0, "a window starved the minority tenant");
            }
            small_pending -= small_here;
            seen.extend(b.requests.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<_>>(), "row lost or duplicated");
    }
}
