//! Dynamic batching: collect messages from a channel into batches bounded
//! by size and by holding time — the standard serving trade-off between
//! per-request latency and per-batch amortisation (here: hitting the
//! compiled PJRT batch shapes). Batching is tenant-blind (DESIGN.md
//! §14): the hidden layer is task-agnostic, so rows addressed to
//! different tenants coalesce into one batch and cost one hidden-layer
//! pass; the worker applies each row's own head afterwards. Fleet-health
//! and registry control messages ride the same channel (so control stays
//! ordered with respect to control: a probe queued after a drift
//! injection observes the drifted die, a request routed after a REGISTER
//! ack finds the head installed) and are split out of the classify batch
//! for the worker to run after the batch — traffic-vs-control ordering
//! is batch-granular.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::request::{ClassifyRequest, ControlMsg, WorkerMsg};

/// One drained unit of worker input: a classify batch (possibly empty)
/// plus any control messages that arrived in the same window.
pub struct Batch {
    pub requests: Vec<ClassifyRequest>,
    pub control: Vec<ControlMsg>,
}

/// Blockingly collect the next batch from `rx`.
///
/// Waits (forever) for the first message; then drains until the held
/// classify requests cost `max_batch` *physical conversions* or
/// `max_wait` has elapsed since the first message. `cost_per_request`
/// is the die's pass cost (DESIGN.md §13): 1 on a physical die, so the
/// bound counts requests; `RotationPlan::passes()` on a virtual die, so
/// a P-pass die holds 1/P as many requests per batch and the per-batch
/// conversion budget stays constant fleet-wide. At least one request is
/// always collected. A control-only window returns an empty-request
/// batch — the "empty-queue tick" that lets probes run on an idle
/// worker. Returns `None` once the channel is closed and drained — the
/// worker's shutdown signal.
pub fn collect_batch(
    rx: &Receiver<WorkerMsg>,
    max_batch: usize,
    max_wait: Duration,
    cost_per_request: usize,
) -> Option<Batch> {
    let cost = cost_per_request.max(1);
    let max_requests = (max_batch / cost).max(1);
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + max_wait;
    let mut batch = Batch { requests: Vec::new(), control: Vec::new() };
    push(&mut batch, first);
    while batch.requests.len() < max_requests {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(msg) => push(&mut batch, msg),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

fn push(batch: &mut Batch, msg: WorkerMsg) {
    match msg {
        WorkerMsg::Classify(mut req) => {
            // Stage stamp (DESIGN.md §16): queue-wait ends the moment
            // the batcher pulls the request into a forming batch.
            req.collected = Some(Instant::now());
            batch.requests.push(req);
        }
        WorkerMsg::Control(ctl) => batch.control.push(ctl),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> WorkerMsg {
        tenant_req(id, None)
    }

    fn tenant_req(id: u64, tenant: Option<&str>) -> WorkerMsg {
        let (tx, _rx) = mpsc::channel();
        WorkerMsg::Classify(ClassifyRequest {
            id,
            features: vec![],
            tenant: tenant.map(|name| crate::coordinator::request::TenantTag {
                name: std::sync::Arc::from(name),
                metrics: std::sync::Arc::new(
                    crate::coordinator::metrics::TenantMetrics::default(),
                ),
            }),
            submitted: Instant::now(),
            collected: None,
            reply: tx,
        })
    }

    fn ctl() -> WorkerMsg {
        WorkerMsg::Control(ControlMsg::SetEnv {
            vdd: None,
            temp_k: Some(310.0),
            age_sigma_vt: None,
            seed: 1,
        })
    }

    #[test]
    fn max_size_flush_collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let b = collect_batch(&rx, 4, Duration::from_millis(200), 1).unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.requests[0].id, 0);
        assert_eq!(b.requests[3].id, 3);
        // a full batch flushes immediately, well before the deadline
        assert!(t0.elapsed() < Duration::from_millis(150));
        // the rest are still queued
        let b2 = collect_batch(&rx, 100, Duration::from_millis(5), 1).unwrap();
        assert_eq!(b2.requests.len(), 6);
    }

    #[test]
    fn timeout_flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        let t0 = Instant::now();
        let b = collect_batch(&rx, 64, Duration::from_millis(20), 1).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(b.control.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(18));
        drop(tx);
    }

    #[test]
    fn empty_queue_tick_delivers_control_without_requests() {
        // an idle worker woken only by a control message gets an
        // empty-request batch carrying the control — the probe tick
        let (tx, rx) = mpsc::channel();
        tx.send(ctl()).unwrap();
        let b = collect_batch(&rx, 8, Duration::from_millis(5), 1).unwrap();
        assert!(b.requests.is_empty());
        assert_eq!(b.control.len(), 1);
        assert!(matches!(b.control[0], ControlMsg::SetEnv { .. }));
    }

    #[test]
    fn control_rides_along_with_a_classify_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        tx.send(ctl()).unwrap();
        tx.send(req(1)).unwrap();
        let b = collect_batch(&rx, 8, Duration::from_millis(10), 1).unwrap();
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.control.len(), 1);
    }

    #[test]
    fn pass_cost_shrinks_the_request_window() {
        // a 4-pass virtual die with an 8-conversion budget holds at
        // most 2 requests per batch
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let b = collect_batch(&rx, 8, Duration::from_millis(50), 4).unwrap();
        assert_eq!(b.requests.len(), 2);
        // even a cost above the whole budget still moves one request
        let b = collect_batch(&rx, 8, Duration::from_millis(5), 100).unwrap();
        assert_eq!(b.requests.len(), 1);
    }

    #[test]
    fn cross_tenant_rows_coalesce_into_one_batch() {
        // the hidden layer is tenant-agnostic: rows for the default
        // head and two different tenants share one batch (one
        // hidden-layer pass on the worker), in arrival order
        let (tx, rx) = mpsc::channel();
        tx.send(tenant_req(0, None)).unwrap();
        tx.send(tenant_req(1, Some("digits"))).unwrap();
        tx.send(tenant_req(2, Some("brightness"))).unwrap();
        tx.send(tenant_req(3, Some("digits"))).unwrap();
        let b = collect_batch(&rx, 8, Duration::from_millis(10), 1).unwrap();
        assert_eq!(b.requests.len(), 4, "tenants must not split the batch");
        assert!(b.requests[0].tenant.is_none());
        assert_eq!(
            b.requests[1].tenant.as_ref().unwrap().name.as_ref(),
            "digits"
        );
        assert_eq!(
            b.requests[2].tenant.as_ref().unwrap().name.as_ref(),
            "brightness"
        );
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn one_submissions_burst_coalesces_into_one_window() {
        // the rows of one BatchPredict submission (DESIGN.md §15) are
        // routed back-to-back before the worker's window closes: they
        // must land in ONE batch — one hidden-layer pass for the whole
        // submission — even when rows address different tenants
        let (tx, rx) = mpsc::channel();
        for i in 0..12 {
            let tenant = if i % 2 == 0 { None } else { Some("slope") };
            tx.send(tenant_req(i, tenant)).unwrap();
        }
        let b = collect_batch(&rx, 64, Duration::from_millis(20), 1).unwrap();
        assert_eq!(b.requests.len(), 12, "burst split across windows");
        assert_eq!(
            b.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..12).collect::<Vec<_>>()
        );
    }

    #[test]
    fn batcher_stamps_the_collected_instant() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let b = collect_batch(&rx, 8, Duration::from_millis(5), 1).unwrap();
        let r = &b.requests[0];
        let collected = r.collected.expect("batcher must stamp collected");
        assert!(collected >= r.submitted, "queue stage must be non-negative");
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        drop(tx);
        assert!(collect_batch(&rx, 8, Duration::from_millis(5), 1).is_none());
    }

    #[test]
    fn preserves_order_and_no_duplicates() {
        let (tx, rx) = mpsc::channel();
        for i in 0..50 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(b) = collect_batch(&rx, 7, Duration::from_millis(1), 1) {
            seen.extend(b.requests.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }
}
