//! Artifact discovery: parses the `manifest.txt` written by
//! `python/compile/aot.py` so the runtime knows each HLO module's name,
//! file, argument shapes and baked chip parameters without parsing HLO.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    /// Argument shapes in order, e.g. [[32,128],[128,128]].
    pub arg_shapes: Vec<Vec<usize>>,
    /// Baked chip parameters (hidden artifacts only), key -> value.
    pub params: BTreeMap<String, String>,
}

impl ArtifactMeta {
    /// Total element count of argument `i`.
    pub fn arg_elems(&self, i: usize) -> usize {
        self.arg_shapes[i].iter().product()
    }

    /// Leading (batch) dimension of argument 0.
    pub fn batch(&self) -> usize {
        self.arg_shapes[0][0]
    }
}

/// Parsed manifest: name -> meta.
#[derive(Clone, Debug, Default)]
pub struct ArtifactStore {
    pub entries: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Load `<dir>/manifest.txt`. Errors if the directory/manifest is
    /// missing — run `make artifacts` first.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}; run `make artifacts`"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 |-fields, got {}", ln + 1, parts.len());
            }
            let name = parts[0].to_string();
            let path = dir.join(parts[1]);
            let arg_shapes: Result<Vec<Vec<usize>>> = parts[2]
                .split(';')
                .map(|s| {
                    s.split('x')
                        .map(|t| {
                            t.parse::<usize>()
                                .with_context(|| format!("manifest line {}: bad dim {t}", ln + 1))
                        })
                        .collect()
                })
                .collect();
            let mut params = BTreeMap::new();
            if !parts[3].is_empty() {
                for kv in parts[3].split(',') {
                    if let Some((k, v)) = kv.split_once('=') {
                        params.insert(k.to_string(), v.to_string());
                    }
                }
            }
            entries.insert(
                name.clone(),
                ArtifactMeta { name, path, arg_shapes: arg_shapes?, params },
            );
        }
        Ok(ArtifactStore { entries, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest ({} known)", self.entries.len()))
    }

    /// Hidden-stage artifact names available, sorted by batch size.
    pub fn hidden_variants(&self, normalized: bool, d: usize, l: usize) -> Vec<&ArtifactMeta> {
        let prefix = if normalized { "hidden_norm_b" } else { "hidden_b" };
        let suffix = format!("_d{d}_l{l}");
        let mut v: Vec<&ArtifactMeta> = self
            .entries
            .values()
            .filter(|m| m.name.starts_with(prefix) && m.name.ends_with(&suffix))
            .filter(|m| {
                // exclude hidden_norm when asking for plain hidden
                normalized || !m.name.starts_with("hidden_norm")
            })
            .collect();
        v.sort_by_key(|m| m.batch());
        v
    }

    /// Smallest hidden variant whose batch dim fits `n` rows (or the
    /// largest available if none fits — the caller then splits).
    pub fn pick_hidden(&self, normalized: bool, d: usize, l: usize, n: usize) -> Option<&ArtifactMeta> {
        let v = self.hidden_variants(normalized, d, l);
        v.iter().find(|m| m.batch() >= n).copied().or(v.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
hidden_b1_d128_l128|hidden_b1_d128_l128.hlo.txt|1x128;128x128|d=128,mode=quadratic,t_neu=6.5e-06
hidden_b32_d128_l128|hidden_b32_d128_l128.hlo.txt|32x128;128x128|d=128,mode=quadratic,t_neu=6.5e-06
hidden_norm_b32_d128_l128|hidden_norm_b32_d128_l128.hlo.txt|32x128;128x128|d=128
train_n1024_l128|train_n1024_l128.hlo.txt|1024x128;1024x1;1|
";

    #[test]
    fn parses_manifest_fields() {
        let s = ArtifactStore::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(s.entries.len(), 4);
        let h = s.get("hidden_b32_d128_l128").unwrap();
        assert_eq!(h.arg_shapes, vec![vec![32, 128], vec![128, 128]]);
        assert_eq!(h.batch(), 32);
        assert_eq!(h.params["mode"], "quadratic");
        let t = s.get("train_n1024_l128").unwrap();
        assert_eq!(t.arg_shapes[2], vec![1]);
        assert!(t.params.is_empty());
    }

    #[test]
    fn hidden_variant_selection() {
        let s = ArtifactStore::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let plain = s.hidden_variants(false, 128, 128);
        assert_eq!(plain.len(), 2);
        assert_eq!(plain[0].batch(), 1);
        // picking: n=8 -> batch 32; n=100 (too big) -> largest (32)
        assert_eq!(s.pick_hidden(false, 128, 128, 8).unwrap().batch(), 32);
        assert_eq!(s.pick_hidden(false, 128, 128, 100).unwrap().batch(), 32);
        assert_eq!(s.pick_hidden(false, 128, 128, 1).unwrap().batch(), 1);
        // normalized picks the norm variant
        assert!(s.pick_hidden(true, 128, 128, 4).unwrap().name.starts_with("hidden_norm"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactStore::parse(Path::new("/x"), "only|three|fields").is_err());
        assert!(ArtifactStore::parse(Path::new("/x"), "n|f|1xZ|").is_err());
    }

    #[test]
    fn unknown_artifact_error_is_helpful() {
        let s = ArtifactStore::parse(Path::new("/x"), SAMPLE).unwrap();
        let err = format!("{:#}", s.get("nope").unwrap_err());
        assert!(err.contains("nope"));
    }
}
