//! Stub PJRT engine (default build): the external `xla` crate is not in
//! the offline vendor set, so without `--features pjrt` the engine
//! cannot execute artifacts. Construction fails with a clear message;
//! every caller (worker::open_engine, tests, benches) already falls back
//! to the behavioural chip simulator when the engine is unavailable.

use std::path::Path;

use anyhow::{bail, Result};

use super::ArtifactStore;

const UNAVAILABLE: &str =
    "velm was built without the `pjrt` feature; rebuild with `--features pjrt` \
     (requires the external `xla` crate) to execute AOT artifacts";

/// Same public surface as the real engine so call sites compile
/// unchanged; `new` always fails, so the methods are unreachable in
/// practice but keep identical signatures.
pub struct PjrtEngine {
    pub store: ArtifactStore,
}

impl PjrtEngine {
    /// Always fails: artifacts cannot execute without the `pjrt` feature.
    pub fn new(_dir: &Path) -> Result<Self> {
        bail!("{UNAVAILABLE}");
    }

    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".to_string()
    }

    pub fn execute_f32(&mut self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn hidden(
        &mut self,
        _codes: &[f32],
        _n: usize,
        _d: usize,
        _l: usize,
        _weights: &[f32],
        _normalized: bool,
    ) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn train_beta(
        &mut self,
        _h: &[f32],
        _n: usize,
        _l: usize,
        _t: &[f32],
        _lambda: f32,
    ) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn predict(&mut self, _h: &[f32], _n: usize, _l: usize, _beta: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_reports_missing_feature() {
        let err = PjrtEngine::new(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }
}
