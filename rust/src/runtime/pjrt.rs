//! Real PJRT engine (feature `pjrt`): loads the HLO-text artifacts
//! produced by `make artifacts` and executes them on the XLA CPU client
//! from the serving hot path. Python never runs here — the artifacts are
//! the only hand-off (see /opt/xla-example/load_hlo for the wiring
//! reference). Requires the external `xla` crate; see DESIGN.md §7.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{ArtifactMeta, ArtifactStore};

/// A compiled-executable cache over one PJRT CPU client.
///
/// One engine per worker thread (the xla crate's handles are not shared
/// across threads here); compilation happens once per artifact name.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    pub store: ArtifactStore,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let store = ArtifactStore::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, store, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self.store.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                meta.path
                    .to_str()
                    .context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32 buffers. `inputs[i]` must match the
    /// manifest shape of argument i. Returns the flattened f32 output
    /// (all artifacts return a 1-tuple of one array).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let meta = self.store.get(name)?.clone();
        if inputs.len() != meta.arg_shapes.len() {
            bail!(
                "artifact '{name}' wants {} args, got {}",
                meta.arg_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, buf) in inputs.iter().enumerate() {
            if buf.len() != meta.arg_elems(i) {
                bail!(
                    "artifact '{name}' arg {i}: want {} elems ({:?}), got {}",
                    meta.arg_elems(i),
                    meta.arg_shapes[i],
                    buf.len()
                );
            }
            let dims: Vec<i64> = meta.arg_shapes[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .with_context(|| format!("reshaping arg {i} to {dims:?}"))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{name}'"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping output tuple")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Hidden stage: codes [n, d] (row-major) + weights [d, l] -> counts
    /// [n, l]. Pads the batch up to the chosen compiled variant and
    /// slices back (zero rows are exact through the transfer).
    pub fn hidden(
        &mut self,
        codes: &[f32],
        n: usize,
        d: usize,
        l: usize,
        weights: &[f32],
        normalized: bool,
    ) -> Result<Vec<f32>> {
        assert_eq!(codes.len(), n * d);
        assert_eq!(weights.len(), d * l);
        let meta = self
            .store
            .pick_hidden(normalized, d, l, n)
            .with_context(|| format!("no hidden artifact for d={d} l={l}"))?
            .clone();
        let bsz = meta.batch();
        let mut out = Vec::with_capacity(n * l);
        for chunk in codes.chunks(bsz * d) {
            let rows = chunk.len() / d;
            let padded;
            let input = if rows == bsz {
                chunk
            } else {
                padded = {
                    let mut p = vec![0f32; bsz * d];
                    p[..chunk.len()].copy_from_slice(chunk);
                    p
                };
                &padded[..]
            };
            let res = self.execute_f32(&meta.name, &[input, weights])?;
            out.extend_from_slice(&res[..rows * l]);
        }
        Ok(out)
    }

    /// Ridge training on-device: H [n, l], T [n], lambda -> beta [l].
    /// Zero-pads rows up to the smallest train artifact that fits.
    pub fn train_beta(&mut self, h: &[f32], n: usize, l: usize, t: &[f32], lambda: f32) -> Result<Vec<f32>> {
        assert_eq!(h.len(), n * l);
        assert_eq!(t.len(), n);
        let (name, rows) = {
            let mut variants: Vec<&ArtifactMeta> = self
                .store
                .entries
                .values()
                .filter(|m| m.name.starts_with("train_n") && m.name.ends_with(&format!("_l{l}")))
                .collect();
            variants.sort_by_key(|m| m.batch());
            let meta = variants
                .iter()
                .find(|m| m.batch() >= n)
                .with_context(|| format!("no train artifact with n >= {n}"))?;
            (meta.name.clone(), meta.batch())
        };
        let mut hp = vec![0f32; rows * l];
        hp[..h.len()].copy_from_slice(h);
        let mut tp = vec![0f32; rows];
        tp[..t.len()].copy_from_slice(t);
        self.execute_f32(&name, &[&hp, &tp, &[lambda]])
    }

    /// Second stage on-device: H [n, l] x beta [l] -> scores [n].
    pub fn predict(&mut self, h: &[f32], n: usize, l: usize, beta: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(h.len(), n * l);
        assert_eq!(beta.len(), l);
        let (name, bsz) = {
            let mut variants: Vec<&ArtifactMeta> = self
                .store
                .entries
                .values()
                .filter(|m| m.name.starts_with("predict_b") && m.name.ends_with(&format!("_l{l}")))
                .collect();
            variants.sort_by_key(|m| m.batch());
            let meta = variants
                .iter()
                .find(|m| m.batch() >= n)
                .or(variants.last())
                .with_context(|| format!("no predict artifact for l={l}"))?;
            (meta.name.clone(), meta.batch())
        };
        let mut out = Vec::with_capacity(n);
        for chunk in h.chunks(bsz * l) {
            let rows = chunk.len() / l;
            let padded;
            let input = if rows == bsz {
                chunk
            } else {
                padded = {
                    let mut p = vec![0f32; bsz * l];
                    p[..chunk.len()].copy_from_slice(chunk);
                    p
                };
                &padded[..]
            };
            let res = self.execute_f32(&name, &[input, beta])?;
            out.extend_from_slice(&res[..rows]);
        }
        Ok(out)
    }
}
