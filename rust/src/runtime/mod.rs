//! PJRT runtime: loads the HLO-text artifacts produced by `make
//! artifacts` and executes them on the XLA CPU client from the serving
//! hot path. Python never runs here — the artifacts are the only
//! hand-off (see /opt/xla-example/load_hlo for the wiring reference).
//!
//! The actual XLA binding (the external `xla` crate) is not part of the
//! offline vendor set, so the real engine is gated behind the `pjrt`
//! feature; the default build ships a same-signature stub whose
//! constructor fails, and every call site falls back to the behavioural
//! chip simulator (DESIGN.md §7).

pub mod artifact;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

use std::path::Path;

pub use artifact::{ArtifactMeta, ArtifactStore};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtEngine;

/// Whether the artifact directory looks built (used by tests/examples to
/// skip gracefully with a pointer to `make artifacts`).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.txt").exists()
}
