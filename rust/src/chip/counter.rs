//! Asynchronous saturating counter (eq. 11): the hidden-layer activation.
//!
//! The counter counts neuron spikes during T_neu and freezes at 2^b —
//! the "hard nonlinearity in the form of saturation" that replaces the
//! sigmoid of software ELM (Fig. 5b).

use crate::config::ChipConfig;

/// Ideal count from a spiking frequency over the configured window:
/// `H = min(floor(f_sp * T_neu), 2^b)`.
#[inline]
pub fn count(freq: f64, cfg: &ChipConfig) -> u32 {
    count_window(freq, cfg.t_neu(), cfg.cap())
}

/// Same with explicit window/cap (used by the extension passes and DSE).
#[inline]
pub fn count_window(freq: f64, t_neu: f64, cap: u32) -> u32 {
    if freq <= 0.0 {
        return 0;
    }
    let n = (freq * t_neu).floor();
    if n >= cap as f64 {
        cap
    } else {
        n as u32
    }
}

/// Stateful counter mirroring the hardware block: clocked by spike
/// events, frozen at the cap, readable/resettable via the scanner.
#[derive(Clone, Debug)]
pub struct Counter {
    cap: u32,
    value: u32,
}

impl Counter {
    pub fn new(cfg: &ChipConfig) -> Self {
        Counter { cap: cfg.cap(), value: 0 }
    }

    pub fn with_cap(cap: u32) -> Self {
        Counter { cap, value: 0 }
    }

    /// One spike edge; saturates silently (the hardware stops clocking).
    #[inline]
    pub fn clock(&mut self) {
        if self.value < self.cap {
            self.value += 1;
        }
    }

    /// Batch of spike edges.
    pub fn clock_n(&mut self, n: u64) {
        let room = (self.cap - self.value) as u64;
        self.value += n.min(room) as u32;
    }

    pub fn read(&self) -> u32 {
        self.value
    }

    pub fn saturated(&self) -> bool {
        self.value == self.cap
    }

    pub fn reset(&mut self) {
        self.value = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn count_floor_and_cap() {
        let c = cfg();
        assert_eq!(count(0.0, &c), 0);
        assert_eq!(count(-5.0, &c), 0);
        // exactly one spike period inside the window
        let f1 = 1.0 / c.t_neu();
        assert_eq!(count(f1 * 1.5, &c), 1);
        assert_eq!(count(1e15, &c), c.cap());
    }

    #[test]
    fn count_saturates_exactly_at_isat() {
        // By construction T_neu = 2^b / (K_neu I_sat^z): a neuron driven
        // at exactly I_sat^z in linear mode hits the cap.
        let c = cfg().with_mode(crate::config::Transfer::Linear);
        let f = crate::chip::neuron::f_sp(c.i_sat_z(), &c);
        assert_eq!(count(f, &c), c.cap());
        let f99 = crate::chip::neuron::f_sp(0.99 * c.i_sat_z(), &c);
        assert!(count(f99, &c) < c.cap());
    }

    #[test]
    fn stateful_counter_matches_ideal() {
        let c = cfg();
        let mut ctr = Counter::new(&c);
        for _ in 0..1000 {
            ctr.clock();
        }
        assert_eq!(ctr.read(), 1000);
        ctr.clock_n(1u64 << 40); // silly overdrive
        assert_eq!(ctr.read(), c.cap());
        assert!(ctr.saturated());
        ctr.reset();
        assert_eq!(ctr.read(), 0);
    }

    #[test]
    fn clock_n_equals_repeated_clock() {
        let mut a = Counter::with_cap(100);
        let mut b = Counter::with_cap(100);
        a.clock_n(73);
        for _ in 0..73 {
            b.clock();
        }
        assert_eq!(a.read(), b.read());
        a.clock_n(1000);
        for _ in 0..1000 {
            b.clock();
        }
        assert_eq!(a.read(), b.read());
    }
}
