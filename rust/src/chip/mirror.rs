//! Current-mirror array: bandwidth/settling (Section IV-B) and thermal
//! noise (Section IV-A, eqs. 13-16) of the sub-threshold copy operation.

use crate::chip::dac;
use crate::config::ChipConfig;
use crate::util::prng::Prng;

/// Electron charge [C].
pub const Q_E: f64 = 1.602_176_634e-19;

/// Mirror small-signal bandwidth for an input current [Hz]:
/// `BW = kappa * I / (C * U_T)` — the single pole at the gate node
/// (Section IV-B uses T_cm = 4/BW).
#[inline]
pub fn bandwidth(i_in: f64, cfg: &ChipConfig) -> f64 {
    if i_in <= 0.0 {
        return 0.0;
    }
    cfg.kappa * i_in / (cfg.c_mirror * cfg.u_t())
}

/// Effective bandwidth including the active-mirror assist (Fig. 9a):
/// when S1 engages (4 MSBs zero) the boost factor (SPICE: 5.84x) applies.
#[inline]
pub fn bandwidth_effective(code: u16, cfg: &ChipConfig) -> f64 {
    let bw = bandwidth(dac::dac_current(code, cfg), cfg);
    if cfg.active_mirror && dac::s1_active_mirror(code, cfg) {
        bw * cfg.active_boost
    } else {
        bw
    }
}

/// Settling time to within 5% for one channel's code (eq. 17 family):
/// `T_cm = 4 / BW`. Zero for a shut-off row (S2).
#[inline]
pub fn settling_time(code: u16, cfg: &ChipConfig) -> f64 {
    if dac::s2_row_off(code) {
        return 0.0;
    }
    4.0 / bandwidth_effective(code, cfg)
}

/// Worst-case settling across a loaded input vector: the conversion
/// cannot start until the slowest channel has settled.
pub fn settling_time_vector(codes: &[u16], cfg: &ChipConfig) -> f64 {
    codes
        .iter()
        .map(|&c| settling_time(c, cfg))
        .fold(0.0, f64::max)
}

/// Average-case settling at I_in = I_max/2 (eq. 17): `8 C U_T / (kappa I_max)`.
pub fn t_cm_avg(cfg: &ChipConfig) -> f64 {
    8.0 * cfg.c_mirror * cfg.u_t() / (cfg.kappa * cfg.i_max)
}

/// Max/min settling bounds of eq. 18 (LSB current through the boosted
/// active mirror vs full-scale through the passive one).
pub fn t_cm_max(cfg: &ChipConfig) -> f64 {
    let i_lsb = cfg.i_max / cfg.code_fs() as f64;
    4.0 * cfg.c_mirror * cfg.u_t() / (cfg.active_boost * cfg.kappa * i_lsb)
}

pub fn t_cm_min(cfg: &ChipConfig) -> f64 {
    4.0 * cfg.c_mirror * cfg.u_t() / (cfg.kappa * cfg.i_max)
}

/// Input-referred thermal-noise power spectral-density integral (eq. 15):
/// total mean-square noise current over the mirror's own bandwidth,
/// `i_n^2 = q kappa I^2 (1 + 1/w0) / (2 C U_T)` [A^2].
#[inline]
pub fn noise_current_sq(i_in: f64, w0: f64, cfg: &ChipConfig) -> f64 {
    Q_E * cfg.kappa * i_in * i_in * (1.0 + 1.0 / w0) / (2.0 * cfg.c_mirror * cfg.u_t())
}

/// Mirror SNR (eq. 16): independent of signal level —
/// `SNR = 2 C U_T w0 / (q kappa (w0 + 1))`.
#[inline]
pub fn snr(w0: f64, cfg: &ChipConfig) -> f64 {
    2.0 * cfg.c_mirror * cfg.u_t() * w0 / (Q_E * cfg.kappa * (w0 + 1.0))
}

/// Effective number of bits from the SNR power ratio.
pub fn snr_bits(w0: f64, cfg: &ChipConfig) -> f64 {
    // SNR_dB = 6.02 ENOB + 1.76
    (10.0 * snr(w0, cfg).log10() - 1.76) / 6.02
}

/// One noisy mirror copy: returns `i_in * w` perturbed by the thermal
/// noise of eq. 14 when noise injection is enabled.
#[inline]
pub fn copy_current(i_in: f64, w: f64, cfg: &ChipConfig, rng: &mut Prng) -> f64 {
    let ideal = i_in * w;
    if !cfg.noise_en || i_in <= 0.0 {
        return ideal;
    }
    let sigma = noise_current_sq(i_in, w.max(1e-6), cfg).sqrt();
    (ideal + rng.normal(0.0, sigma)).max(0.0)
}

/// Minimum gate capacitance for a target resolution in bits at gain w0
/// (the Section IV-A sizing argument that fixes C = 0.4 pF for 8 bits).
pub fn cap_for_bits(bits: f64, w0: f64, cfg: &ChipConfig) -> f64 {
    let snr_target = 10f64.powf((6.02 * bits + 1.76) / 10.0);
    snr_target * Q_E * cfg.kappa * (w0 + 1.0) / (2.0 * cfg.u_t() * w0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn bandwidth_proportional_to_current() {
        let c = cfg();
        let b1 = bandwidth(1e-9, &c);
        let b2 = bandwidth(2e-9, &c);
        assert!((b2 / b1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn active_mirror_boosts_small_codes_only() {
        let c = cfg();
        // code 32: 4 MSBs zero -> boosted
        let boosted = bandwidth_effective(32, &c);
        let plain = bandwidth(dac::dac_current(32, &c), &c);
        assert!((boosted / plain - c.active_boost).abs() < 1e-9);
        // code 512: MSB set -> no boost
        let big = bandwidth_effective(512, &c);
        assert!((big - bandwidth(dac::dac_current(512, &c), &c)).abs() < 1e-9);
    }

    #[test]
    fn settling_bounds_bracket_everything() {
        let c = cfg();
        let tmax = t_cm_max(&c);
        let tmin = t_cm_min(&c);
        assert!(tmax > tmin);
        for code in 1..1024u16 {
            let t = settling_time(code, &c);
            assert!(t >= tmin * (1.0 - 1e-12), "code {code}: {t} < {tmin}");
            assert!(t <= tmax * (1.0 + 1e-12), "code {code}: {t} > {tmax}");
        }
        // shut-off row settles instantly (it never turns on)
        assert_eq!(settling_time(0, &c), 0.0);
    }

    #[test]
    fn vector_settling_is_worst_channel() {
        let c = cfg();
        let t = settling_time_vector(&[1023, 512, 1], &c);
        assert!((t - settling_time(1, &c)).abs() < 1e-18);
    }

    #[test]
    fn t_cm_avg_matches_eq17() {
        let c = cfg();
        let expect = 8.0 * 0.4e-12 * c.u_t() / (0.7 * 1e-9);
        assert!((t_cm_avg(&c) - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn snr_gives_8_bits_at_point4_pf() {
        // The Section IV-A design claim: C = 0.4 pF suffices for 8 bits
        // at w0 = 1.
        let c = cfg();
        let bits = snr_bits(1.0, &c);
        assert!(bits > 7.8, "ENOB {bits}");
        // and the sizing inverse is consistent: ~0.4 pF for 8 bits
        let c_needed = cap_for_bits(8.0, 1.0, &c);
        assert!(
            (c_needed / c.c_mirror - 1.0).abs() < 0.1,
            "need {c_needed} have {}",
            c.c_mirror
        );
    }

    #[test]
    fn snr_independent_of_signal_level() {
        let c = cfg();
        // eq. 16 has no I term; verify via the noise/signal ratio
        for &i in &[1e-10, 1e-9, 5e-9] {
            let ratio = i * i / noise_current_sq(i, 1.0, &c);
            assert!((ratio / snr(1.0, &c) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn noisy_copy_unbiased_and_bounded() {
        let mut c = cfg();
        c.noise_en = true;
        let mut rng = Prng::new(9);
        let n = 20_000;
        let i_in = 1e-9;
        let xs: Vec<f64> = (0..n).map(|_| copy_current(i_in, 1.0, &c, &mut rng)).collect();
        let mean = crate::util::stats::mean(&xs);
        assert!((mean / i_in - 1.0).abs() < 0.01, "bias {}", mean / i_in);
        let snr_meas = i_in * i_in / crate::util::stats::var(&xs);
        let snr_theory = snr(1.0, &c);
        assert!((snr_meas / snr_theory - 1.0).abs() < 0.1);
    }

    #[test]
    fn noise_off_is_exact() {
        let c = cfg();
        let mut rng = Prng::new(1);
        assert_eq!(copy_current(1e-9, 2.0, &c, &mut rng), 2e-9);
    }
}
