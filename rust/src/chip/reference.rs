//! Bias / reference current generation (Fig. 3 "Reference" block).
//!
//! The IGC's master current I_ref comes from a Delbruck-style
//! wide-dynamic-range bias generator (paper ref [23]): a self-biased
//! bootstrap mirror whose output is set by a resistor and the
//! sub-threshold characteristic. We model its two operating regimes and
//! its supply/temperature sensitivity, because I_ref drift is a
//! common-mode gain on the whole array — precisely what the eq. 26
//! normalisation is designed to cancel (the Fig. 17/18 studies pull
//! their common-mode disturbance from here).

use crate::config::{thermal_voltage, ChipConfig};

/// Bias generator model parameters.
#[derive(Clone, Debug)]
pub struct BiasGen {
    /// Setting resistor [Ohm].
    pub r_set: f64,
    /// Mirror ratio M (output/master).
    pub mirror_ratio: f64,
    /// Sub-threshold slope kappa.
    pub kappa: f64,
    /// Startup leakage floor [A] (keeps the bootstrap from the zero state).
    pub i_leak: f64,
}

impl Default for BiasGen {
    fn default() -> Self {
        BiasGen { r_set: 25e6, mirror_ratio: 1.0, kappa: 0.7, i_leak: 1e-13 }
    }
}

impl BiasGen {
    /// Nominal output current: in the bootstrap's sub-threshold regime
    /// the loop settles at `I = kappa * U_T * ln(M') / R` (the classic
    /// beta-multiplier result with U_T replacing 1/(2 beta) forms); we
    /// fold the geometric ratio into `mirror_ratio + 1` so the default
    /// lands near 1 nA at 300 K with R = 25 MOhm.
    pub fn i_ref(&self, temp_k: f64) -> f64 {
        let ut = thermal_voltage(temp_k);
        let i = self.kappa * ut * (1.0 + self.mirror_ratio).ln() / self.r_set
            * (1.0 / self.kappa); // slope factor cancels in the loop
        i.max(self.i_leak)
    }

    /// Supply sensitivity: the cascoded bootstrap rejects VDD to first
    /// order; we model a small residual channel-length-modulation slope.
    pub fn i_ref_at(&self, temp_k: f64, vdd: f64, vdd_nom: f64) -> f64 {
        let lambda_cl = 0.02; // 2%/V residual supply sensitivity
        self.i_ref(temp_k) * (1.0 + lambda_cl * (vdd - vdd_nom))
    }

    /// PTAT check: the reference is proportional to absolute temperature
    /// (U_T), the dominant drift the Fig. 18 sweep sees on top of the
    /// weight drift.
    pub fn tempco(&self, temp_k: f64) -> f64 {
        // dI/dT / I = 1/T for a PTAT source
        1.0 / temp_k
    }
}

/// Attach a bias generator to a chip config: returns the I_max the IGC
/// would actually receive at the configured corner.
pub fn i_max_from_bias(cfg: &ChipConfig, bias: &BiasGen) -> f64 {
    bias.i_ref_at(cfg.temp_k, cfg.vdd, cfg.vdd_nom)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_lands_near_1na() {
        let b = BiasGen::default();
        let i = b.i_ref(300.0);
        assert!((0.3e-9..3e-9).contains(&i), "i_ref {i}");
    }

    #[test]
    fn ptat_behaviour() {
        let b = BiasGen::default();
        let cold = b.i_ref(280.0);
        let hot = b.i_ref(320.0);
        assert!(hot > cold);
        // proportional to absolute temperature
        assert!((hot / cold - 320.0 / 280.0).abs() < 1e-6);
        assert!((b.tempco(300.0) - 1.0 / 300.0).abs() < 1e-12);
    }

    #[test]
    fn supply_rejection_is_strong() {
        let b = BiasGen::default();
        let nom = b.i_ref_at(300.0, 1.0, 1.0);
        let hi = b.i_ref_at(300.0, 1.2, 1.0);
        assert!((hi / nom - 1.0).abs() < 0.005, "residual {}", hi / nom - 1.0);
    }

    #[test]
    fn bigger_resistor_smaller_current() {
        let small = BiasGen { r_set: 10e6, ..Default::default() };
        let big = BiasGen { r_set: 100e6, ..Default::default() };
        assert!(small.i_ref(300.0) > big.i_ref(300.0));
    }

    #[test]
    fn leakage_floor_guards_zero_state() {
        let b = BiasGen { r_set: 1e18, ..Default::default() };
        assert!(b.i_ref(300.0) >= b.i_leak);
    }

    #[test]
    fn config_hookup() {
        let cfg = ChipConfig::default();
        let b = BiasGen::default();
        let i = i_max_from_bias(&cfg, &b);
        assert!(i > 0.0);
    }
}
