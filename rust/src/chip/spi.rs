//! Peripheral digital circuits: the SPI input path of Fig. 2(b) and the
//! rotation register banks of Figs. 12/13 that implement the Section V
//! dimension-extension technique.
//!
//! Modelled at frame level with a bit-accurate encoder: a frame is
//! `A<6:0> | Data_in<9:0>` shifted MSB-first, exactly the 1-to-128
//! demultiplexor addressing described in Section III.

/// Serial frame: 7 address bits + b_in data bits, MSB first.
pub fn encode_frame(addr: u8, data: u16, b_in: u32) -> Vec<bool> {
    assert!(addr < 128, "address must fit 7 bits");
    assert!((data as u32) < (1 << b_in), "data must fit {b_in} bits");
    let mut bits = Vec::with_capacity(7 + b_in as usize);
    for k in (0..7).rev() {
        bits.push(addr >> k & 1 == 1);
    }
    for k in (0..b_in).rev() {
        bits.push(data >> k & 1 == 1);
    }
    bits
}

/// Decode a frame produced by [`encode_frame`].
pub fn decode_frame(bits: &[bool], b_in: u32) -> (u8, u16) {
    assert_eq!(bits.len(), 7 + b_in as usize, "bad frame length");
    let mut addr = 0u8;
    for &b in &bits[..7] {
        addr = addr << 1 | b as u8;
    }
    let mut data = 0u16;
    for &b in &bits[7..] {
        data = data << 1 | b as u16;
    }
    (addr, data)
}

/// Input shift-register file (one 10-bit register per channel) with the
/// Fig. 12 `Rotation_Control` circular-shift mode for hidden-layer
/// extension.
#[derive(Clone, Debug)]
pub struct InputRegisters {
    regs: Vec<u16>,
    b_in: u32,
    /// Rotations applied since the last load (for introspection/tests).
    pub rotation: usize,
}

impl InputRegisters {
    pub fn new(d: usize, b_in: u32) -> Self {
        InputRegisters { regs: vec![0; d], b_in, rotation: 0 }
    }

    /// SPI write of one channel (demultiplexed by the 7-bit address).
    pub fn load_frame_bits(&mut self, bits: &[bool]) {
        let (addr, data) = decode_frame(bits, self.b_in);
        self.load(addr as usize, data);
    }

    pub fn load(&mut self, channel: usize, data: u16) {
        assert!(channel < self.regs.len(), "channel {channel} out of range");
        assert!((data as u32) < (1 << self.b_in));
        self.regs[channel] = data;
        self.rotation = 0;
    }

    /// Load a whole input vector (serial in the hardware; batched here).
    pub fn load_vector(&mut self, codes: &[u16]) {
        assert_eq!(codes.len(), self.regs.len(), "vector length != channels");
        for &c in codes {
            assert!((c as u32) < (1 << self.b_in));
        }
        self.regs.copy_from_slice(codes);
        self.rotation = 0;
    }

    /// One `Rotation_Control` pulse (Fig. 12): circular shift by one —
    /// channel i takes the value previously on channel i+1, realising the
    /// row rotation `W -> W_{1,0}` from the neurons' point of view.
    pub fn rotate(&mut self) {
        self.regs.rotate_left(1);
        self.rotation += 1;
    }

    pub fn read(&self) -> &[u16] {
        &self.regs
    }
}

/// Output-side register banks of Fig. 13: a rotation bank fed by the
/// counters plus an accumulator bank, for input-dimension extension.
#[derive(Clone, Debug)]
pub struct OutputBank {
    rot: Vec<u32>,
    acc: Vec<u32>,
}

impl OutputBank {
    pub fn new(l: usize) -> Self {
        OutputBank { rot: vec![0; l], acc: vec![0; l] }
    }

    /// Latch counter outputs into the rotation bank (end of NEU_EN).
    pub fn latch(&mut self, counts: &[u32]) {
        assert_eq!(counts.len(), self.rot.len());
        self.rot.copy_from_slice(counts);
    }

    /// One `CLK_r` pulse: circular rotation of the bank by one position
    /// (undoes the column rotation `W -> W_{0,c}` before accumulation).
    pub fn clk_r(&mut self) {
        self.rot.rotate_left(1);
    }

    /// One `CLK_a` pulse: add the rotation bank into the accumulator.
    pub fn clk_a(&mut self) {
        for (a, &r) in self.acc.iter_mut().zip(&self.rot) {
            *a += r;
        }
    }

    /// Read out the accumulated hidden outputs and clear (column scanner).
    pub fn read_and_clear(&mut self) -> Vec<u32> {
        let out = self.acc.clone();
        self.acc.iter_mut().for_each(|a| *a = 0);
        out
    }

    pub fn peek_acc(&self) -> &[u32] {
        &self.acc
    }

    pub fn peek_rot(&self) -> &[u32] {
        &self.rot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_all_bits() {
        for addr in [0u8, 1, 63, 127] {
            for data in [0u16, 1, 512, 1023] {
                let bits = encode_frame(addr, data, 10);
                assert_eq!(bits.len(), 17);
                assert_eq!(decode_frame(&bits, 10), (addr, data));
            }
        }
    }

    #[test]
    #[should_panic]
    fn frame_rejects_wide_data() {
        encode_frame(0, 1024, 10);
    }

    #[test]
    fn register_file_loads_by_address() {
        let mut r = InputRegisters::new(8, 10);
        r.load_frame_bits(&encode_frame(3, 777, 10));
        assert_eq!(r.read()[3], 777);
        assert_eq!(r.read()[0], 0);
    }

    #[test]
    fn rotation_is_circular() {
        let mut r = InputRegisters::new(4, 10);
        r.load_vector(&[10, 20, 30, 40]);
        r.rotate();
        assert_eq!(r.read(), &[20, 30, 40, 10]);
        r.rotate();
        r.rotate();
        r.rotate();
        assert_eq!(r.read(), &[10, 20, 30, 40]);
        assert_eq!(r.rotation, 4);
    }

    #[test]
    fn load_resets_rotation_counter() {
        let mut r = InputRegisters::new(2, 10);
        r.load_vector(&[1, 2]);
        r.rotate();
        assert_eq!(r.rotation, 1);
        r.load_vector(&[3, 4]);
        assert_eq!(r.rotation, 0);
    }

    #[test]
    fn output_bank_rotate_accumulate() {
        // Fig. 13 timing: latch, rotate c times, accumulate.
        let mut ob = OutputBank::new(4);
        ob.latch(&[1, 2, 3, 4]);
        ob.clk_a();
        assert_eq!(ob.peek_acc(), &[1, 2, 3, 4]);
        ob.latch(&[10, 20, 30, 40]);
        ob.clk_r(); // one rotation: [20,30,40,10]
        ob.clk_a();
        assert_eq!(ob.peek_acc(), &[21, 32, 43, 14]);
        let out = ob.read_and_clear();
        assert_eq!(out, vec![21, 32, 43, 14]);
        assert_eq!(ob.peek_acc(), &[0, 0, 0, 0]);
    }
}
