//! Energy model of Section IV-C (eqs. 21-25): per-spike energy, supply
//! power split, and the energy-per-conversion integral behind Fig. 10 and
//! the 0.47 pJ/MAC headline of Table III.

use crate::chip::neuron;
use crate::config::ChipConfig;

/// Energy per spike E_sp(I^z) (eq. 22): switching + inverter short-circuit
/// + the V_mem short-circuit term. Diverges as I^z -> I_rst (the reset
/// fight), which is why the optimum operating current sits *below* I_flx.
/// Returns `None` where the oscillator does not spike.
pub fn e_sp(i_z: f64, cfg: &ChipConfig) -> Option<f64> {
    let f = neuron::f_sp(i_z, cfg);
    if f <= 0.0 {
        return None;
    }
    let i_chg = cfg.i_rst() - i_z + cfg.i_lk;
    let term1 = cfg.alpha1 * cfg.vdd * cfg.vdd;
    let term2 = cfg.alpha2_isc * cfg.vdd / f;
    let term3 = cfg.c_b * i_z * cfg.vdd * cfg.vdd / i_chg;
    Some(term1 + term2 + term3)
}

/// Product E_sp(I^z) * f_sp(I^z) — the *power* integrand of eq. 25.
///
/// Written symbolically so the I_rst divergence of E_sp cancels against
/// the f_sp zero: for quadratic mode,
/// `E_sp f_sp = alpha1 VDD^2 f_sp + alpha2 I_sc VDD + I^z^2 VDD / I_rst`.
pub fn power_neuron(i_z: f64, cfg: &ChipConfig) -> f64 {
    let f = neuron::f_sp(i_z, cfg);
    if f <= 0.0 {
        return 0.0;
    }
    let sw = cfg.alpha1 * cfg.vdd * cfg.vdd * f;
    let sc = cfg.alpha2_isc * cfg.vdd;
    let i_chg = cfg.i_rst() - i_z + cfg.i_lk;
    let vmem = cfg.c_b * i_z * cfg.vdd * cfg.vdd / i_chg * f;
    sw + sc + vmem
}

/// Digital-supply power for L active neurons at a common frequency
/// (eq. 23 approximation): `P_vdd ~ L (alpha1 VDD^2 f + alpha2 I_sc VDD)`.
pub fn p_vdd_approx(l_active: usize, f_sp: f64, cfg: &ChipConfig) -> f64 {
    l_active as f64 * (cfg.alpha1 * cfg.vdd * cfg.vdd * f_sp + cfg.alpha2_isc * cfg.vdd)
}

/// Average energy per conversion for one neuron (eqs. 24-25): input
/// current uniform over [0, I_max^z], window T_neu set so the counter
/// reaches 2^b exactly at I_sat^z = sat_ratio * I_max^z.
///
/// Eq. 19 writes T_neu with the *linear* gain K_neu; physically the
/// requirement is H(I_sat) = 2^b, i.e. `T_neu = 2^b / f_sp(I_sat)` with
/// the full quadratic transfer. The distinction is what produces the
/// Fig. 10 minimum: as I_sat^z approaches I_flx the neuron's peak rate
/// saturates, T_neu stretches, and conversion energy blows back up —
/// "the optimum current is less than I_flx" (Section IV-C). Returns
/// +inf where the counting window is unrealisable (I_sat^z >= I_rst).
pub fn e_c(i_max_z: f64, cfg: &ChipConfig) -> f64 {
    let i_sat = cfg.sat_ratio * i_max_z;
    let f_sat = neuron::f_sp(i_sat, cfg);
    if f_sat <= 0.0 {
        return f64::INFINITY;
    }
    let t_neu = cfg.cap() as f64 / f_sat;
    // E_c = T_neu / I_max^z * Int_0^{I_max^z} E_sp f_sp dI
    let upper = i_max_z.min(cfg.i_rst() * 0.999_999);
    let integral = simpson(|i| power_neuron(i, cfg), 0.0, upper, 2001);
    t_neu / i_max_z * integral
}

/// Energy booked for one *actual* conversion of neuron j: H_j spikes at
/// column current z_j during window t_neu (the chip ledger's unit).
pub fn e_conversion_neuron(z_j: f64, h_j: u32, t_neu: f64, cfg: &ChipConfig) -> f64 {
    let sw = cfg.alpha1 * cfg.vdd * cfg.vdd * h_j as f64;
    let sc = cfg.alpha2_isc * cfg.vdd * t_neu;
    let i_chg = cfg.i_rst() - z_j + cfg.i_lk;
    let vmem = if i_chg > 0.0 && z_j > 0.0 {
        cfg.c_b * z_j * cfg.vdd * cfg.vdd / i_chg * h_j as f64
    } else {
        0.0
    };
    sw + sc + vmem
}

/// Energy efficiency in pJ/MAC for a full-array conversion:
/// total power x conversion time over d x L multiply-accumulates.
pub fn pj_per_mac(p_total: f64, t_c: f64, d: usize, l: usize) -> f64 {
    p_total * t_c / (d * l) as f64 * 1e12
}

/// Modelled energy of one full-array conversion at the die's nominal
/// operating point, in joules: L neurons' average conversion energy
/// (eq. 25 evaluated at the die's I_max^z) plus the analog-supply
/// window energy `P_AVDD * T_neu`. This is the serving fleet's price
/// per booked conversion (DESIGN.md §16). A non-finite neuron term
/// (unrealisable counting window) contributes zero, so serving never
/// books infinities.
pub fn e_conversion_nominal(cfg: &ChipConfig) -> f64 {
    let per_neuron = e_c(cfg.i_max_z(), cfg);
    let neurons = if per_neuron.is_finite() {
        cfg.l as f64 * per_neuron
    } else {
        0.0
    };
    let t_neu = cfg.t_neu();
    let window = if t_neu.is_finite() { cfg.p_avdd * t_neu } else { 0.0 };
    neurons + window
}

/// [`e_conversion_nominal`] rounded to whole femtojoules: workers book
/// `conversions * price` in integer arithmetic, so the fleet's energy
/// ledger is exact (tests assert equality, not tolerances) and the
/// hot path never touches floating point.
pub fn conversion_price_fj(cfg: &ChipConfig) -> u64 {
    let e = e_conversion_nominal(cfg);
    if !e.is_finite() {
        return 0;
    }
    (e * 1e15).round().max(0.0) as u64
}

/// Throughput in MMAC/s at a classification rate.
pub fn mmacs(rate_hz: f64, d: usize, l: usize) -> f64 {
    rate_hz * (d * l) as f64 / 1e6
}

/// Composite Simpson's rule (n odd number of samples).
pub fn simpson(f: impl Fn(f64) -> f64, a: f64, b: f64, n: usize) -> f64 {
    assert!(n >= 3 && n % 2 == 1, "simpson needs odd n >= 3");
    let h = (b - a) / (n - 1) as f64;
    let mut acc = f(a) + f(b);
    for k in 1..n - 1 {
        let w = if k % 2 == 1 { 4.0 } else { 2.0 };
        acc += w * f(a + k as f64 * h);
    }
    acc * h / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transfer;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn simpson_exact_on_cubics() {
        let got = simpson(|x| x * x * x - 2.0 * x + 1.0, 0.0, 2.0, 11);
        let expect = 4.0 - 4.0 + 2.0; // x^4/4 - x^2 + x on [0,2]
        assert!((got - expect).abs() < 1e-12);
    }

    #[test]
    fn e_sp_diverges_towards_irst() {
        let c = cfg();
        let e_mid = e_sp(c.i_flx(), &c).unwrap();
        let e_hot = e_sp(0.99 * c.i_rst(), &c).unwrap();
        assert!(e_hot > 5.0 * e_mid, "short-circuit blowup missing");
        assert!(e_sp(0.0, &c).is_none());
        assert!(e_sp(c.i_rst() * 1.5, &c).is_none());
    }

    #[test]
    fn power_integrand_is_finite_and_matches_product() {
        let c = cfg();
        for frac in [0.01, 0.3, 0.6, 0.9, 0.999] {
            let i = frac * c.i_rst();
            let p = power_neuron(i, &c);
            assert!(p.is_finite() && p > 0.0);
            if let Some(e) = e_sp(i, &c) {
                let f = neuron::f_sp(i, &c);
                assert!((p - e * f).abs() / p < 1e-9, "frac {frac}");
            }
        }
        // finite limit at I_rst: alpha2IscVDD + I_rst VDD (c_b terms)
        let p_edge = power_neuron(0.999_999 * c.i_rst(), &c);
        assert!(p_edge.is_finite());
    }

    #[test]
    fn e_c_has_interior_minimum_near_iflx() {
        // Fig. 10(a): lowest conversion energy when I_max^z approaches
        // I_flx (slightly below due to the short-circuit blowup).
        //
        let c = cfg();
        let grid: Vec<f64> = (1..=60)
            .map(|k| 0.02 * c.i_rst() + (k as f64 / 60.0) * 1.25 * c.i_rst())
            .collect();
        let e: Vec<f64> = grid.iter().map(|&i| e_c(i, &c)).collect();
        let (argmin, _) = e
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let i_opt = grid[argmin];
        // "the lowest conversion energy is attained when I_max^z is
        // close to I_flx" with the optimum slightly below (Section IV-C)
        assert!(
            i_opt > 0.4 * c.i_flx() && i_opt < 1.5 * c.i_flx(),
            "optimum {} vs I_flx {}",
            i_opt,
            c.i_flx()
        );
        // and the curve rises on both sides
        assert!(e[0] > e[argmin]);
        assert!(e[e.len() - 1] > e[argmin]);
    }

    #[test]
    fn lower_vdd_gives_lower_minimum_energy() {
        // Fig. 10: "lowest energy per conversion is attainable for lowest
        // VDD ... since the short circuit current reduces drastically".
        let min_ec = |vdd: f64| {
            let c = cfg().with_vdd(vdd);
            (1..=30)
                .map(|k| e_c(k as f64 / 30.0 * 1.2 * c.i_flx(), &c))
                .fold(f64::MAX, f64::min)
        };
        let e08 = min_ec(0.8);
        let e10 = min_ec(1.0);
        let e12 = min_ec(1.2);
        assert!(e08 < e10 && e10 < e12, "{e08} {e10} {e12}");
    }

    #[test]
    fn conversion_ledger_consistent_with_esp() {
        let c = cfg();
        let z = c.i_flx() / 2.0;
        let f = neuron::f_sp(z, &c);
        let t_neu = c.t_neu();
        let h = (f * t_neu).floor() as u32;
        let e = e_conversion_neuron(z, h, t_neu, &c);
        // bounded by H * E_sp + short-circuit window energy
        let e_ub = e_sp(z, &c).unwrap() * h as f64 + c.alpha2_isc * c.vdd * t_neu;
        assert!(e <= e_ub * (1.0 + 1e-9));
        assert!(e > 0.0);
    }

    #[test]
    fn pj_per_mac_headline_arithmetic() {
        // Table III check: 188.8 uW at 31.6 kHz over 128x100 MACs
        // = 0.47 pJ/MAC; throughput 404.5 MMAC/s.
        let pj = pj_per_mac(188.8e-6, 1.0 / 31.6e3, 128, 100);
        assert!((pj - 0.467).abs() < 0.01, "pj {pj}");
        let th = mmacs(31.6e3, 128, 100);
        assert!((th - 404.5).abs() < 1.0, "mmacs {th}");
    }

    #[test]
    fn linear_mode_power_is_defined() {
        let c = cfg().with_mode(Transfer::Linear);
        assert!(power_neuron(c.i_sat_z(), &c) > 0.0);
    }

    #[test]
    fn conversion_price_is_positive_finite_and_rounds_the_nominal_energy() {
        let c = cfg();
        let e = e_conversion_nominal(&c);
        assert!(e.is_finite() && e > 0.0, "nominal conversion energy {e}");
        // the window energy alone bounds it from below
        assert!(e >= c.p_avdd * c.t_neu());
        let price = conversion_price_fj(&c);
        assert!(price > 0, "integer price must not round to zero");
        assert_eq!(price, (e * 1e15).round() as u64);
    }

    #[test]
    fn conversion_price_scales_with_hidden_width() {
        // twice the neurons, (at least) roughly twice the neuron term:
        // a wider die must never price a conversion cheaper
        let narrow = cfg();
        let wide = {
            let mut c = cfg();
            c.l = 2 * narrow.l;
            c
        };
        assert!(conversion_price_fj(&wide) > conversion_price_fj(&narrow));
    }
}
