//! Behavioural model of the mixed-signal ELM chip — the "silicon" of this
//! reproduction (DESIGN.md §4).
//!
//! [`ChipModel`] composes the substrates: DAC ([`dac`]), mismatch array
//! ([`mismatch`]), current mirrors with settling + noise ([`mirror`]),
//! oscillator neurons ([`neuron`]), saturating counters ([`counter`]),
//! the SPI/rotation peripherals ([`spi`]) and the timing/energy ledgers
//! ([`timing`], [`energy`]). A conversion is bit-faithful to eqs. 4-12 +
//! eq. 11 and books simulated time and energy exactly as Section IV
//! models them, so characterisation benches read physics off the ledger.

pub mod counter;
pub mod dac;
pub mod energy;
pub mod mirror;
pub mod mismatch;
pub mod neuron;
pub mod reference;
pub mod scanner;
pub mod spi;
pub mod timing;

use crate::config::ChipConfig;
use crate::util::mat::Mat;
use crate::util::prng::Prng;

/// Simulated-time / energy accounting for one die.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ledger {
    /// Simulated chip time spent converting [s].
    pub sim_time: f64,
    /// Energy drawn from both supplies [J].
    pub energy: f64,
    /// Completed conversions (one input vector -> one H row).
    pub conversions: u64,
    /// Multiply-accumulates performed (d x L per conversion).
    pub macs: u64,
}

impl Ledger {
    /// Energy efficiency over everything booked so far [pJ/MAC].
    pub fn pj_per_mac(&self) -> f64 {
        if self.macs == 0 {
            return 0.0;
        }
        self.energy / self.macs as f64 * 1e12
    }

    /// Average classification rate [Hz].
    pub fn rate(&self) -> f64 {
        if self.sim_time == 0.0 {
            return 0.0;
        }
        self.conversions as f64 / self.sim_time
    }

    /// Throughput [MMAC/s] over simulated time.
    pub fn mmacs(&self) -> f64 {
        if self.sim_time == 0.0 {
            return 0.0;
        }
        self.macs as f64 / self.sim_time / 1e6
    }
}

/// One fabricated die.
pub struct ChipModel {
    pub cfg: ChipConfig,
    pub mismatch: mismatch::MismatchMatrix,
    pub input_regs: spi::InputRegisters,
    pub out_bank: spi::OutputBank,
    pub ledger: Ledger,
    /// The NEU_EN counting window actually programmed into the digital
    /// control [s]. Set from the operating point at fabrication/configure
    /// time and deliberately NOT recomputed when VDD or temperature
    /// drift: the window is an FPGA timing setting, so drift shows up as
    /// a common-mode count shift (the Fig. 17/18 mechanism) rather than
    /// being silently compensated.
    pub t_neu_set: f64,
    noise_rng: Prng,
    /// Weight matrix cached per (temperature) — invalidated by set_temp.
    weight_cache: Option<(f64, Mat)>,
}

impl ChipModel {
    /// "Tape-out": sample the mismatch from `seed` at the given operating
    /// point. Same seed = same silicon, forever.
    pub fn fabricate(cfg: ChipConfig, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mismatch = mismatch::MismatchMatrix::fabricate(&cfg, &mut rng);
        let noise_rng = rng.split(0xA0A0);
        ChipModel {
            input_regs: spi::InputRegisters::new(cfg.d, cfg.b_in),
            out_bank: spi::OutputBank::new(cfg.l),
            mismatch,
            noise_rng,
            weight_cache: None,
            ledger: Ledger::default(),
            t_neu_set: cfg.t_neu(),
            cfg,
        }
    }

    /// Reprogram the counting window (an explicit recalibration — what
    /// the paper does between operating points, not what drift does).
    pub fn program_t_neu(&mut self, t_neu: f64) {
        self.t_neu_set = t_neu;
    }

    /// Change supply voltage (the Fig. 17 robustness sweeps).
    pub fn set_vdd(&mut self, vdd: f64) {
        self.cfg.vdd = vdd;
    }

    /// Change die temperature (the Fig. 18 sweeps). Weights shift through
    /// U_T; the cache is invalidated.
    pub fn set_temp(&mut self, t_k: f64) {
        self.cfg.temp_k = t_k;
        self.weight_cache = None;
    }

    /// Drift-injection hook (fleet subsystem, DESIGN.md §12): age the
    /// mismatch profile by an extra N(0, sigma) threshold shift per
    /// mirror. Unlike VDD/temperature drift this changes the *relative*
    /// weights, so eq. 26 renormalisation cannot cancel it.
    pub fn age_mismatch(&mut self, extra_sigma: f64, seed: u64) {
        self.mismatch.age(extra_sigma, seed);
        self.weight_cache = None;
    }

    /// Mismatch weight matrix at the current temperature (cached).
    pub fn weights(&mut self) -> &Mat {
        let t = self.cfg.temp_k;
        let stale = match &self.weight_cache {
            Some((ct, _)) => (*ct - t).abs() > 1e-12,
            None => true,
        };
        if stale {
            self.weight_cache = Some((t, self.mismatch.weights_at(t)));
        }
        &self.weight_cache.as_ref().unwrap().1
    }

    /// Load an input vector through the SPI register file.
    pub fn load_input(&mut self, codes: &[u16]) {
        self.input_regs.load_vector(codes);
    }

    /// Run one conversion (NEU_EN window) on whatever the input registers
    /// hold, booking time and energy. Returns the counter outputs.
    pub fn convert(&mut self) -> Vec<u32> {
        let codes: Vec<u16> = self.input_regs.read().to_vec();
        let counts = self.convert_codes(&codes);
        self.out_bank.latch(&counts);
        counts
    }

    /// Core conversion path (also used by rotation passes): codes -> H.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): derived operating-point values
    /// (I_rst, K_neu, gains) are hoisted out of the per-neuron loop and
    /// the neuron transfer is applied inline instead of through
    /// `neuron::f_sp` (which rederives I_rst per call).
    fn convert_codes(&mut self, codes: &[u16]) -> Vec<u32> {
        let cfg = self.cfg.clone();
        debug_assert_eq!(codes.len(), cfg.d);
        // hoisted operating-point constants
        let i_rst = cfg.i_rst();
        let quad_gain = 1.0 / (i_rst * cfg.c_b * cfg.vdd);
        let k_neu = cfg.k_neu();
        let i_lk = cfg.i_lk;
        let quadratic = cfg.mode == crate::config::Transfer::Quadratic;
        let cap = cfg.cap();
        // DAC currents per channel (eq. 4). The IGC reference comes from
        // a PTAT bias generator (Fig. 3 "Reference"; chip::reference), so
        // the full-scale current drifts proportionally to absolute
        // temperature and carries a small residual VDD slope — the
        // common-mode disturbances the Fig. 17/18 studies exercise and
        // eq. 26 is designed to cancel.
        let bias_gain = (cfg.temp_k / 300.0)
            * (1.0 + 0.02 * (cfg.vdd - cfg.vdd_nom));
        let i_in: Vec<f64> = codes
            .iter()
            .map(|&c| dac::dac_current(c, &cfg) * bias_gain)
            .collect();
        // column currents by KCL (eq. 12 weights), optionally noisy
        let z = if cfg.noise_en {
            let mut z = vec![0.0f64; cfg.l];
            for (i, &ii) in i_in.iter().enumerate() {
                if ii == 0.0 {
                    continue; // S2: row shut off
                }
                for (j, zj) in z.iter_mut().enumerate() {
                    let w = self.mismatch.weight(i, j, cfg.temp_k);
                    *zj += mirror::copy_current(ii, w, &cfg, &mut self.noise_rng);
                }
            }
            z
        } else {
            // hot path: cached weight matrix, dense accumulate
            let w = self.weights();
            let mut z = vec![0.0f64; cfg.l];
            for (i, &ii) in i_in.iter().enumerate() {
                if ii == 0.0 {
                    continue;
                }
                let wrow = w.row(i);
                for (zj, &wij) in z.iter_mut().zip(wrow) {
                    *zj += ii * wij;
                }
            }
            z
        };
        // neuron + counter (eqs. 8, 11) with lumped neuron mismatch;
        // the window is the *programmed* one (drift-exposed, see field)
        let t_neu = self.t_neu_set;
        let counts: Vec<u32> = z
            .iter()
            .enumerate()
            .map(|(j, &zj)| {
                let i_eff = zj - i_lk;
                let f = if quadratic {
                    if i_eff <= 0.0 || i_eff >= i_rst {
                        0.0
                    } else {
                        i_eff * (i_rst - i_eff) * quad_gain
                    }
                } else {
                    i_eff.max(0.0) * k_neu
                };
                let f = neuron::with_neuron_mismatch(f, self.mismatch.kneu_gain(j));
                counter::count_window(f, t_neu, cap)
            })
            .collect();
        // ledgers: Section IV timing + energy
        let t_c = mirror::settling_time_vector(codes, &cfg) + t_neu;
        let mut e = cfg.p_avdd * t_c; // analog supply
        for (j, &zj) in z.iter().enumerate() {
            e += energy::e_conversion_neuron(zj, counts[j], t_neu, &cfg);
        }
        self.ledger.sim_time += t_c;
        self.ledger.energy += e;
        self.ledger.conversions += 1;
        self.ledger.macs += (cfg.d * cfg.l) as u64;
        counts
    }

    /// Load + convert in one call.
    pub fn forward(&mut self, codes: &[u16]) -> Vec<u32> {
        self.load_input(codes);
        self.convert()
    }

    /// Convenience: normalised features in [-1, 1] -> codes -> H.
    pub fn forward_features(&mut self, xs: &[f64]) -> Vec<u32> {
        let codes = dac::features_to_codes(xs, &self.cfg);
        self.forward(&codes)
    }

    /// Batch forward: one row of H per input row.
    pub fn forward_batch(&mut self, xs: &[Vec<f64>]) -> Vec<Vec<u32>> {
        xs.iter().map(|x| self.forward_features(x)).collect()
    }

    /// Fig. 15(a) characterisation: sweep Data_in on one channel (others
    /// zero) and record all L transfer curves.
    pub fn transfer_curves(&mut self, channel: usize, codes: &[u16]) -> Vec<Vec<u32>> {
        codes
            .iter()
            .map(|&c| {
                let mut v = vec![0u16; self.cfg.d];
                v[channel] = c;
                self.forward(&v)
            })
            .collect()
    }

    /// Fig. 15(b) characterisation: fixed code on each channel one by one;
    /// returns the d x L matrix of counter outputs.
    pub fn weight_surface(&mut self, code: u16) -> Mat {
        let d = self.cfg.d;
        let mut m = Mat::zeros(d, self.cfg.l);
        for i in 0..d {
            let mut v = vec![0u16; d];
            v[i] = code;
            let counts = self.forward(&v);
            for (j, &c) in counts.iter().enumerate() {
                m.set(i, j, c as f64);
            }
        }
        m
    }

    /// Reset the time/energy ledger (start of a measurement).
    pub fn reset_ledger(&mut self) {
        self.ledger = Ledger::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transfer;
    use crate::util::stats;

    fn small_cfg() -> ChipConfig {
        ChipConfig::default().with_dims(16, 16)
    }

    #[test]
    fn fabrication_deterministic_forward() {
        let mut a = ChipModel::fabricate(small_cfg(), 42);
        let mut b = ChipModel::fabricate(small_cfg(), 42);
        let codes: Vec<u16> = (0..16).map(|i| (i * 60) as u16).collect();
        assert_eq!(a.forward(&codes), b.forward(&codes));
    }

    #[test]
    fn different_dies_differ() {
        let mut a = ChipModel::fabricate(small_cfg(), 1);
        let mut b = ChipModel::fabricate(small_cfg(), 2);
        let codes = vec![500u16; 16];
        assert_ne!(a.forward(&codes), b.forward(&codes));
    }

    #[test]
    fn zero_input_zero_output_zero_fast() {
        let mut c = ChipModel::fabricate(small_cfg(), 3);
        let counts = c.forward(&[0u16; 16]);
        assert!(counts.iter().all(|&h| h == 0));
        // S2 shutdown means no settling wait: only T_neu books
        assert!((c.ledger.sim_time - c.cfg.t_neu()).abs() < 1e-12);
    }

    #[test]
    fn counts_monotone_in_common_code_until_saturation() {
        // linear mode: more input current -> more counts (no rolloff)
        let cfg = small_cfg().with_mode(Transfer::Linear).with_b(10);
        let mut chip = ChipModel::fabricate(cfg, 4);
        let mut prev_sum = 0u64;
        for code in [64u16, 128, 256, 512, 1023] {
            let counts = chip.forward(&[code; 16]);
            let s: u64 = counts.iter().map(|&c| c as u64).sum();
            assert!(s >= prev_sum, "code {code}");
            prev_sum = s;
        }
    }

    #[test]
    fn ledger_books_time_energy_macs() {
        let mut chip = ChipModel::fabricate(small_cfg(), 5);
        let codes = vec![512u16; 16];
        chip.forward(&codes);
        chip.forward(&codes);
        assert_eq!(chip.ledger.conversions, 2);
        assert_eq!(chip.ledger.macs, 2 * 16 * 16);
        assert!(chip.ledger.sim_time > 2.0 * chip.cfg.t_neu() * 0.99);
        assert!(chip.ledger.energy > 0.0);
        assert!(chip.ledger.pj_per_mac() > 0.0);
        assert!(chip.ledger.rate() > 0.0);
        chip.reset_ledger();
        assert_eq!(chip.ledger.conversions, 0);
    }

    #[test]
    fn transfer_curves_show_mismatch_spread() {
        // Fig. 15(a): "significant variation between the transfer curves".
        let mut chip = ChipModel::fabricate(small_cfg(), 6);
        let curves = chip.transfer_curves(0, &[1023]);
        let row: Vec<f64> = curves[0].iter().map(|&c| c as f64).collect();
        assert!(stats::std(&row) > 0.05 * stats::mean(&row));
    }

    #[test]
    fn weight_surface_recovers_lognormal_sigma() {
        // Fig. 15(b,c): normalise counts by the median and fit ln() —
        // sigma_VT comes back near the fabricated value.
        let cfg = ChipConfig::default().with_dims(48, 48).with_b(14);
        let sigma_fab = cfg.sigma_vt;
        let mut chip = ChipModel::fabricate(cfg, 7);
        let surf = chip.weight_surface(100);
        let mut vals: Vec<f64> = surf.data.iter().cloned().filter(|&v| v > 0.0).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = vals[vals.len() / 2];
        let logs: Vec<f64> = vals.iter().map(|v| (v / median).ln()).collect();
        let (_, s) = stats::fit_gaussian(&logs);
        let sigma_meas = s * crate::config::thermal_voltage(300.0);
        assert!(
            (sigma_meas - sigma_fab).abs() < 0.25 * sigma_fab,
            "measured {} fabricated {}",
            sigma_meas * 1e3,
            sigma_fab * 1e3
        );
    }

    #[test]
    fn noise_injection_perturbs_but_tracks() {
        let cfg = small_cfg().with_noise(true);
        let mut noisy = ChipModel::fabricate(cfg, 8);
        let mut clean = ChipModel::fabricate(small_cfg(), 8);
        let codes = vec![512u16; 16];
        let hn = noisy.forward(&codes);
        let hc = clean.forward(&codes);
        let rel: Vec<f64> = hn
            .iter()
            .zip(&hc)
            .filter(|(_, &c)| c > 20)
            .map(|(&n, &c)| (n as f64 - c as f64).abs() / c as f64)
            .collect();
        assert!(!rel.is_empty());
        // 8-bit SNR design: deviations stay well under a percent-ish
        assert!(stats::mean(&rel) < 0.02, "mean rel dev {}", stats::mean(&rel));
    }

    #[test]
    fn temperature_changes_hidden_outputs() {
        let mut chip = ChipModel::fabricate(small_cfg(), 9);
        let codes = vec![700u16; 16];
        let h0 = chip.forward(&codes);
        chip.set_temp(320.0);
        let h1 = chip.forward(&codes);
        assert_ne!(h0, h1);
    }

    #[test]
    fn aging_changes_hidden_outputs_deterministically() {
        let mut a = ChipModel::fabricate(small_cfg(), 11);
        let mut b = ChipModel::fabricate(small_cfg(), 11);
        let codes = vec![700u16; 16];
        let h0 = a.forward(&codes);
        a.age_mismatch(0.004, 77);
        b.age_mismatch(0.004, 77);
        let ha = a.forward(&codes);
        let hb = b.forward(&codes);
        assert_ne!(h0, ha, "aging must perturb the outputs");
        assert_eq!(ha, hb, "same aging seed must give the same drifted die");
    }

    #[test]
    fn vdd_changes_hidden_outputs() {
        let mut chip = ChipModel::fabricate(small_cfg(), 10);
        let codes = vec![700u16; 16];
        let h0 = chip.forward(&codes);
        chip.set_vdd(0.8);
        let h1 = chip.forward(&codes);
        assert_ne!(h0, h1);
    }
}
