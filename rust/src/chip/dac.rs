//! Input Generation Circuit (IGC): the 10-bit current-splitting DAC of
//! Fig. 3, with the S1 (active-mirror enable) and S2 (row shutdown)
//! switch logic of eq. 5.

use crate::config::ChipConfig;

/// DAC output current for a digital code (eq. 4):
/// `I_DAC = (2^-1 D9 + ... + 2^-10 D0) * I_ref`, with `I_ref = I_max`
/// so a full-scale code maps to the configured per-channel maximum.
#[inline]
pub fn dac_current(code: u16, cfg: &ChipConfig) -> f64 {
    debug_assert!((code as u32) < cfg.code_fs(), "code {code} out of range");
    code as f64 / cfg.code_fs() as f64 * cfg.i_max
}

/// S1 (eq. 5): active current mirror engages when all 4 MSBs are zero —
/// small currents settle too slowly through the passive mirror alone.
#[inline]
pub fn s1_active_mirror(code: u16, cfg: &ChipConfig) -> bool {
    let msb_mask = ((1u32 << 4) - 1) << (cfg.b_in - 4);
    (code as u32 & msb_mask) == 0 && code != 0
}

/// S2 (eq. 5): all-zero code grounds V_bias and shuts the row off.
#[inline]
pub fn s2_row_off(code: u16) -> bool {
    code == 0
}

/// Quantise a normalised feature x in [-1, 1] to a DAC code.
///
/// The chip's mirrors are unidirectional (Section III-D "Input Mapping"):
/// the compact set [-1, 1] maps onto [0, I_max] = codes [0, 2^b_in).
#[inline]
pub fn feature_to_code(x: f64, cfg: &ChipConfig) -> u16 {
    let fs = (cfg.code_fs() - 1) as f64;
    let clamped = x.clamp(-1.0, 1.0);
    ((clamped + 1.0) / 2.0 * fs).round() as u16
}

/// Vector helper for a whole input sample.
pub fn features_to_codes(xs: &[f64], cfg: &ChipConfig) -> Vec<u16> {
    xs.iter().map(|&x| feature_to_code(x, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn dac_is_exactly_linear_in_code() {
        let c = cfg();
        for code in [0u16, 1, 2, 63, 64, 512, 1023] {
            let i = dac_current(code, &c);
            let expect = code as f64 / 1024.0 * c.i_max;
            assert!((i - expect).abs() < 1e-24, "code {code}");
        }
    }

    #[test]
    fn dac_binary_weighting_matches_eq4() {
        // eq. 4 term by term: bit k contributes 2^(k-10) * I_ref.
        let c = cfg();
        for bit in 0..10u16 {
            let i = dac_current(1 << bit, &c);
            let expect = 2f64.powi(bit as i32 - 10) * c.i_max;
            assert!((i - expect).abs() / expect < 1e-12, "bit {bit}");
        }
    }

    #[test]
    fn dac_monotone() {
        let c = cfg();
        let mut prev = -1.0;
        for code in 0..1024u16 {
            let i = dac_current(code, &c);
            assert!(i > prev);
            prev = i;
        }
    }

    #[test]
    fn s1_engages_exactly_when_4_msbs_zero() {
        let c = cfg();
        // codes 1..63 have D9..D6 = 0 -> active mirror on
        assert!(s1_active_mirror(1, &c));
        assert!(s1_active_mirror(63, &c));
        // code 64 sets D6 -> off
        assert!(!s1_active_mirror(64, &c));
        assert!(!s1_active_mirror(1023, &c));
        // all-zero row is shut down by S2 instead
        assert!(!s1_active_mirror(0, &c));
    }

    #[test]
    fn s2_only_for_zero() {
        assert!(s2_row_off(0));
        assert!(!s2_row_off(1));
        assert!(!s2_row_off(1023));
    }

    #[test]
    fn feature_mapping_covers_code_space() {
        let c = cfg();
        assert_eq!(feature_to_code(-1.0, &c), 0);
        assert_eq!(feature_to_code(1.0, &c), 1023);
        assert_eq!(feature_to_code(0.0, &c), 512); // rounds 511.5 up
        // clamping
        assert_eq!(feature_to_code(-5.0, &c), 0);
        assert_eq!(feature_to_code(5.0, &c), 1023);
    }

    #[test]
    fn feature_mapping_monotone() {
        let c = cfg();
        let mut prev = 0u16;
        for k in 0..=200 {
            let x = -1.0 + 2.0 * k as f64 / 200.0;
            let code = feature_to_code(x, &c);
            assert!(code >= prev);
            prev = code;
        }
    }
}
