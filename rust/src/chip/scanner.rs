//! Column scanner (Fig. 2): serial readout of the L counter values to
//! the FPGA over CLK_cnt, with readout-time accounting. On the real chip
//! the scanner runs while the next conversion's inputs load, so readout
//! only bounds throughput when it exceeds T_c — which the timing test
//! below checks for the paper's operating points.

/// Scanner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Scanner {
    /// Read clock frequency [Hz] (FPGA-side CLK_cnt).
    pub clk_hz: f64,
    /// Bits shifted per counter value (the 14-bit output format).
    pub bits: u32,
}

impl Default for Scanner {
    fn default() -> Self {
        Scanner { clk_hz: 50e6, bits: 14 }
    }
}

impl Scanner {
    /// Serial time to scan out L counters [s].
    pub fn readout_time(&self, l: usize) -> f64 {
        l as f64 * self.bits as f64 / self.clk_hz
    }

    /// Does readout hide under a conversion time T_c (pipelined case)?
    pub fn hides_under(&self, l: usize, t_c: f64) -> bool {
        self.readout_time(l) <= t_c
    }

    /// Serialize a counter bank to the bitstream the FPGA would see
    /// (MSB-first per counter, scan order j = 0..L).
    pub fn serialize(&self, counts: &[u32]) -> Vec<bool> {
        let mut bits = Vec::with_capacity(counts.len() * self.bits as usize);
        for &c in counts {
            assert!(c < (1u32 << self.bits), "count {c} overflows {} bits", self.bits);
            for k in (0..self.bits).rev() {
                bits.push(c >> k & 1 == 1);
            }
        }
        bits
    }

    /// FPGA-side deserialization.
    pub fn deserialize(&self, bits: &[bool]) -> Vec<u32> {
        assert_eq!(bits.len() % self.bits as usize, 0, "ragged bitstream");
        bits.chunks(self.bits as usize)
            .map(|chunk| chunk.iter().fold(0u32, |acc, &b| acc << 1 | b as u32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn roundtrip_bitstream() {
        let s = Scanner::default();
        let counts = vec![0u32, 1, 8191, 16383, 1000];
        let bits = s.serialize(&counts);
        assert_eq!(bits.len(), 5 * 14);
        assert_eq!(s.deserialize(&bits), counts);
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn rejects_overflow() {
        Scanner::default().serialize(&[1 << 14]);
    }

    #[test]
    fn readout_hides_under_conversion_at_paper_point() {
        // 128 counters x 14 bits at 50 MHz = 35.84 us; the 31.6 kHz
        // operating point has T_c = 31.6 us -> readout must overlap the
        // *next* load phase; at 100 MHz it fully hides.
        let s = Scanner::default();
        let t_ro = s.readout_time(128);
        assert!((t_ro - 128.0 * 14.0 / 50e6).abs() < 1e-12);
        let fast = Scanner { clk_hz: 100e6, ..s };
        assert!(fast.hides_under(128, 1.0 / 31.6e3));
    }

    #[test]
    fn readout_never_bounds_default_config() {
        let cfg = ChipConfig::default();
        let s = Scanner::default();
        assert!(s.hides_under(cfg.l, cfg.t_neu()));
    }
}
