//! Conversion-time model of Section IV-B: T_c = T_cm + T_neu, the
//! T_cm/T_neu crossover contours of eq. 20 (Fig. 9c), and the
//! classification-rate / throughput bookkeeping used by Table III.

use crate::chip::mirror;
use crate::config::ChipConfig;

/// Neuron counting window for a given I_max^z (eq. 19):
/// `T_neu = 2^b / (sat_ratio * K_neu * I_max^z)`.
pub fn t_neu_for(i_max_z: f64, cfg: &ChipConfig) -> f64 {
    cfg.cap() as f64 / (cfg.sat_ratio * cfg.k_neu() * i_max_z)
}

/// Mean settling estimate used in the Fig. 9(b) study:
/// midpoint of the eq. 18 bounds.
pub fn t_cm_mid(cfg: &ChipConfig) -> f64 {
    0.5 * (mirror::t_cm_max(cfg) + mirror::t_cm_min(cfg))
}

/// Full conversion time for a concrete loaded input vector:
/// worst-channel settling plus the counting window.
pub fn t_c(codes: &[u16], cfg: &ChipConfig) -> f64 {
    mirror::settling_time_vector(codes, cfg) + cfg.t_neu()
}

/// Design-space conversion time: `max` approximation of Section IV-B
/// when one term dominates, else the sum.
pub fn t_c_design(cfg: &ChipConfig) -> f64 {
    t_cm_mid(cfg) + cfg.t_neu()
}

/// The eq. 20 contour: counter dynamic range 2^b at which T_cm = T_neu
/// for input dimension d: `2^b = 6 d C U_t K_neu / kappa`.
pub fn contour_cap(d: usize, cfg: &ChipConfig) -> f64 {
    6.0 * d as f64 * cfg.c_mirror * cfg.u_t() * cfg.k_neu() / cfg.kappa
}

/// Contour expressed in bits (log2 of the cap).
pub fn contour_bits(d: usize, cfg: &ChipConfig) -> f64 {
    contour_cap(d, cfg).log2()
}

/// Which side of the contour an operating point sits on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// T_neu > T_cm (above the contour line).
    NeuronLimited,
    /// T_cm > T_neu (below the contour line).
    MirrorLimited,
}

pub fn regime(cfg: &ChipConfig) -> Regime {
    if cfg.cap() as f64 >= contour_cap(cfg.d, cfg) {
        Regime::NeuronLimited
    } else {
        Regime::MirrorLimited
    }
}

/// Classifications per second at a conversion time.
pub fn classification_rate(t_c: f64) -> f64 {
    1.0 / t_c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn t_neu_inverse_in_imax() {
        let c = cfg();
        let t1 = t_neu_for(100e-9, &c);
        let t2 = t_neu_for(200e-9, &c);
        assert!((t1 / t2 - 2.0).abs() < 1e-12);
        // consistency with the ChipConfig derived value
        assert!((t_neu_for(c.i_max_z(), &c) - c.t_neu()).abs() < 1e-12);
    }

    #[test]
    fn t_neu_doubles_per_counter_bit() {
        // Fig. 9(b): "T_neu increases exponentially with increase in b".
        let c8 = cfg().with_b(8);
        let c12 = cfg().with_b(12);
        let r = t_neu_for(128e-9, &c12) / t_neu_for(128e-9, &c8);
        assert!((r - 16.0).abs() < 1e-9);
    }

    #[test]
    fn contour_matches_eq20_algebra() {
        let c = cfg();
        // at the contour, T_cm,avg (eq. 17) equals T_neu (eq. 19)
        let d = 10;
        let cap = contour_cap(d, &c);
        let i_max_z = d as f64 * c.i_max;
        let t_cm = 8.0 * c.c_mirror * c.u_t() / (c.kappa * c.i_max);
        let t_neu = cap / (0.75 * c.k_neu() * i_max_z);
        assert!((t_cm / t_neu - 1.0).abs() < 1e-9);
    }

    #[test]
    fn contour_linear_in_d() {
        let c = cfg();
        assert!((contour_cap(20, &c) / contour_cap(10, &c) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn contour_shifts_with_vdd() {
        // Fig. 9(c) plots three contours for VDD 0.8/1/1.2: K_neu falls
        // with VDD so the contour cap falls too.
        let lo = cfg().with_vdd(0.8);
        let hi = cfg().with_vdd(1.2);
        assert!(contour_cap(64, &lo) > contour_cap(64, &hi));
    }

    #[test]
    fn paper_regime_at_default_point() {
        // Section IV-B: "for b = 8-10 bits and VDD = 1 V, T_neu dominates
        // T_cm for the maximum dimension of 128".
        let c = cfg().with_b(10);
        assert_eq!(regime(&c), Regime::NeuronLimited);
    }

    #[test]
    fn conversion_time_composition() {
        let c = cfg();
        let codes = vec![512u16; c.d];
        let t = t_c(&codes, &c);
        assert!(t > c.t_neu());
        assert!(t < c.t_neu() + mirror::t_cm_max(&c) + 1e-9);
        assert!(classification_rate(t) > 0.0);
    }
}
