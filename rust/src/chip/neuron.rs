//! Hidden-layer neuron: the current-controlled oscillator of Fig. 4.
//!
//! Two implementations, deliberately independent:
//!  * closed-form frequency `f_sp(I^z)` from the charge-balance analysis
//!    (eqs. 7-10) — the "theory" curve of Fig. 6(a);
//!  * an event/timestep transient simulation of the V_mem waveform —
//!    the stand-in for the paper's SPICE "simulation" curve of Fig. 6(a)
//!    (DESIGN.md §4 substitution table).
//! The fig5_6_neuron bench overlays both.

use crate::config::{ChipConfig, Transfer};

/// Closed-form spiking frequency (eq. 8), clamped outside [0, I_rst]:
/// `f_sp = I^z (I_rst - I^z) / (I_rst C_b VDD)`.
/// In `Transfer::Linear` mode the eq. 9 small-signal form `K_neu I^z`
/// is used (the Section III-D design-space simulations).
#[inline]
pub fn f_sp(i_z: f64, cfg: &ChipConfig) -> f64 {
    match cfg.mode {
        Transfer::Linear => i_z.max(0.0) * cfg.k_neu(),
        Transfer::Quadratic => {
            let i_rst = cfg.i_rst();
            let i_eff = i_z - cfg.i_lk;
            if i_eff <= 0.0 || i_eff >= i_rst {
                return 0.0;
            }
            i_eff * (i_rst - i_eff) / (i_rst * cfg.c_b * cfg.vdd)
        }
    }
}

/// Oscillation period from the two-phase charge balance (eq. 7).
/// Returns `None` where the oscillator stalls.
pub fn t_sp(i_z: f64, cfg: &ChipConfig) -> Option<f64> {
    let i_dis = i_z - cfg.i_lk; // discharge current
    let i_chg = cfg.i_rst() - i_z + cfg.i_lk; // reset (recharge) current
    if i_dis <= 0.0 || i_chg <= 0.0 {
        return None;
    }
    let cv = cfg.c_b * cfg.vdd;
    Some(cv / i_dis + cv / i_chg)
}

/// Peak frequency `f_max = I_rst / (4 C_b VDD)` reached at I_flx (Fig. 5a).
pub fn f_max(cfg: &ChipConfig) -> f64 {
    cfg.i_rst() / (4.0 * cfg.c_b * cfg.vdd)
}

/// Result of a transient run.
#[derive(Clone, Copy, Debug)]
pub struct TransientResult {
    /// Spikes emitted during the window.
    pub spikes: u64,
    /// Estimated frequency from inter-spike timing [Hz].
    pub freq: f64,
}

/// Timestep transient simulation of the V_mem relaxation oscillator.
///
/// Integrates the membrane node (C_a + C_b) under the input current
/// (discharge phase) and I_rst - I^z (reset phase), with the inverter
/// trip at VDD/2 and the C_b/(C_a+C_b) * VDD feedback kick of eq. 6.
/// `steps_per_phase` controls integration resolution; the discretisation
/// error against eq. 8 is what makes this an independent check.
pub fn transient(i_z: f64, window: f64, cfg: &ChipConfig, steps_per_phase: usize) -> TransientResult {
    let i_rst = cfg.i_rst();
    let i_dis = i_z - cfg.i_lk;
    let i_chg = i_rst - i_z + cfg.i_lk;
    if i_dis <= 0.0 || i_chg <= 0.0 {
        return TransientResult { spikes: 0, freq: 0.0 };
    }
    let c_tot = cfg.c_a + cfg.c_b;
    let v_th = cfg.vdd / 2.0;
    let dv_kick = cfg.c_b / c_tot * cfg.vdd; // eq. 6
    // timestep: resolve the faster phase
    let t1 = c_tot * dv_kick / i_dis;
    let t2 = c_tot * dv_kick / i_chg;
    let dt = t1.min(t2) / steps_per_phase as f64;

    let mut v = v_th + dv_kick; // start at top of discharge ramp
    let mut discharging = true;
    let mut t = 0.0;
    let mut spikes = 0u64;
    let mut first_spike_t = None;
    let mut last_spike_t = 0.0;
    while t < window {
        if discharging {
            v -= i_dis / c_tot * dt;
            if v <= v_th {
                // inverters trip: output falls, feedback kicks V_mem down,
                // reset transistor turns on. One spike per cycle.
                spikes += 1;
                if first_spike_t.is_none() {
                    first_spike_t = Some(t);
                }
                last_spike_t = t;
                v -= dv_kick;
                discharging = false;
            }
        } else {
            v += i_chg / c_tot * dt;
            if v >= v_th {
                v += dv_kick;
                discharging = true;
            }
        }
        t += dt;
    }
    let freq = match (first_spike_t, spikes) {
        (Some(t0), s) if s >= 2 => (s - 1) as f64 / (last_spike_t - t0),
        _ => spikes as f64 / window,
    };
    TransientResult { spikes, freq }
}

/// Apply the per-neuron lumped gain mismatch to a frequency.
#[inline]
pub fn with_neuron_mismatch(freq: f64, kneu_gain: f64) -> f64 {
    (freq * kneu_gain).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn f_sp_zero_at_edges_and_peaks_at_iflx() {
        let c = cfg();
        assert_eq!(f_sp(0.0, &c), 0.0);
        assert_eq!(f_sp(c.i_rst(), &c), 0.0);
        assert_eq!(f_sp(-1e-9, &c), 0.0);
        assert_eq!(f_sp(2.0 * c.i_rst(), &c), 0.0);
        let peak = f_sp(c.i_flx(), &c);
        assert!((peak / f_max(&c) - 1.0).abs() < 1e-12);
        // peak is a maximum
        assert!(f_sp(c.i_flx() * 0.9, &c) < peak);
        assert!(f_sp(c.i_flx() * 1.1, &c) < peak);
    }

    #[test]
    fn f_sp_linear_region_matches_kneu() {
        let c = cfg();
        let i = c.i_rst() / 100.0;
        let f = f_sp(i, &c);
        let lin = c.k_neu() * i;
        assert!((f / lin - 1.0).abs() < 0.02, "quadratic vs K_neu {f} {lin}");
    }

    #[test]
    fn t_sp_is_inverse_frequency() {
        let c = cfg();
        for frac in [0.05, 0.2, 0.5, 0.8] {
            let i = frac * c.i_rst();
            let t = t_sp(i, &c).unwrap();
            let f = f_sp(i, &c);
            assert!((t * f - 1.0).abs() < 1e-9, "frac {frac}");
        }
        assert!(t_sp(0.0, &c).is_none());
        assert!(t_sp(c.i_rst(), &c).is_none());
    }

    #[test]
    fn transient_matches_theory_within_discretisation() {
        // Fig. 6(a): "comparison ... between theory and simulation show
        // close match". 2% agreement at 200 steps/phase.
        let c = cfg();
        for frac in [0.05, 0.1, 0.3, 0.5, 0.7, 0.9] {
            let i = frac * c.i_rst();
            let theory = f_sp(i, &c);
            let window = 60.0 / theory; // ~60 cycles
            let sim = transient(i, window, &c, 200);
            let err = (sim.freq - theory).abs() / theory;
            assert!(err < 0.02, "frac {frac}: sim {} vs theory {theory}", sim.freq);
        }
    }

    #[test]
    fn transient_stalls_outside_operating_range() {
        let c = cfg();
        assert_eq!(transient(0.0, 1e-3, &c, 50).spikes, 0);
        assert_eq!(transient(c.i_rst() * 1.01, 1e-3, &c, 50).spikes, 0);
    }

    #[test]
    fn vdd_scaling_matches_fig6b() {
        // Lower VDD: higher f_sp at small I^z (K_neu up) but smaller
        // I_flx and f_max; higher VDD: the opposite.
        let nom = cfg();
        let lo = cfg().with_vdd(0.8);
        let hi = cfg().with_vdd(1.2);
        let i_small = 1e-9;
        assert!(f_sp(i_small, &lo) > f_sp(i_small, &nom));
        assert!(f_sp(i_small, &hi) < f_sp(i_small, &nom));
        assert!(lo.i_flx() < nom.i_flx());
        assert!(hi.i_flx() > nom.i_flx());
        assert!(f_max(&lo) < f_max(&nom));
        assert!(f_max(&hi) > f_max(&nom));
    }

    #[test]
    fn linear_mode_has_no_rolloff() {
        let c = cfg().with_mode(Transfer::Linear);
        let f1 = f_sp(c.i_rst(), &c);
        let f2 = f_sp(2.0 * c.i_rst(), &c);
        assert!(f2 > f1);
        assert!((f2 / f1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn neuron_mismatch_gain() {
        assert_eq!(with_neuron_mismatch(100.0, 1.05), 105.0);
        assert_eq!(with_neuron_mismatch(100.0, -0.5), 0.0);
    }
}
