//! Threshold-voltage mismatch: the computational resource of the paper.
//!
//! Each of the d x L current-mirror transistors carries a frozen offset
//! dV_T ~ N(0, sigma_VT) sampled at "fabrication". The mirror gain seen by
//! neuron j from channel i is `w_ij = exp(dV_T_ij / U_T)` (eq. 12) — a
//! log-normal random weight, temperature-dependent through U_T = kT/q.
//! Pelgrom's area law links sigma_VT to transistor size for the scaling
//! discussion of Section III-D.

use crate::config::{thermal_voltage, ChipConfig};
use crate::util::mat::Mat;
use crate::util::prng::Prng;

/// Pelgrom mismatch model: sigma_VT = A_VT / sqrt(W L) (paper ref [1]).
///
/// `a_vt` in V*m (typical 0.35 um CMOS: ~9.5 mV*um = 9.5e-9 V*m), `w`/`l`
/// transistor dimensions in meters. Used by the design-space discussion:
/// deeply scaled processes need upsized transistors to stay in the
/// optimal 15-25 mV band.
pub fn pelgrom_sigma_vt(a_vt: f64, w: f64, l: f64) -> f64 {
    a_vt / (w * l).sqrt()
}

/// Inverse Pelgrom: transistor area needed to hit a target sigma_VT.
pub fn pelgrom_area_for_sigma(a_vt: f64, sigma_vt: f64) -> f64 {
    (a_vt / sigma_vt) * (a_vt / sigma_vt)
}

/// The fabricated mismatch state of one die.
#[derive(Clone, Debug)]
pub struct MismatchMatrix {
    pub d: usize,
    pub l: usize,
    /// Per-mirror threshold offsets dV_T [V], row-major d x L.
    pub dvt: Vec<f64>,
    /// Per-neuron relative K_neu error (lumped neuron-side mismatch,
    /// Section VI-A: "mismatch obtained here also takes into account
    /// mismatch in the neuronal tuning curves").
    pub kneu_rel: Vec<f64>,
}

impl MismatchMatrix {
    /// Sample a die. Every experiment seeds this explicitly, so a "chip"
    /// is reproducible: same seed = same silicon.
    pub fn fabricate(cfg: &ChipConfig, rng: &mut Prng) -> Self {
        let dvt = (0..cfg.d * cfg.l)
            .map(|_| rng.normal(0.0, cfg.sigma_vt))
            .collect();
        let kneu_rel = (0..cfg.l)
            .map(|_| rng.normal(0.0, cfg.sigma_kneu_rel))
            .collect();
        MismatchMatrix { d: cfg.d, l: cfg.l, dvt, kneu_rel }
    }

    /// Mirror gain w_ij at temperature `t_k` (eq. 12).
    #[inline]
    pub fn weight(&self, i: usize, j: usize, t_k: f64) -> f64 {
        (self.dvt[i * self.l + j] / thermal_voltage(t_k)).exp()
    }

    /// Full weight matrix at temperature `t_k` — what the PJRT hidden
    /// artifact consumes, and the Fig. 15(b) surface.
    pub fn weights_at(&self, t_k: f64) -> Mat {
        let ut = thermal_voltage(t_k);
        let data: Vec<f64> = self.dvt.iter().map(|v| (v / ut).exp()).collect();
        Mat { rows: self.d, cols: self.l, data }
    }

    /// Per-neuron K_neu multiplier (1 + relative error).
    #[inline]
    pub fn kneu_gain(&self, j: usize) -> f64 {
        1.0 + self.kneu_rel[j]
    }

    /// Drift-injection hook for the fleet subsystem (DESIGN.md §12):
    /// superimpose an *additional* N(0, `extra_sigma`) threshold shift on
    /// every mirror, modelling aging / stress-induced mismatch-profile
    /// change — the drift mode eq. 26 renormalisation cannot cancel
    /// (it is not common-mode), so it forces a head retrain.
    /// Deterministic in `seed` so drifted dies stay reproducible.
    pub fn age(&mut self, extra_sigma: f64, seed: u64) {
        let mut rng = Prng::new(seed ^ 0xA6E_D1E);
        for v in self.dvt.iter_mut() {
            *v += rng.normal(0.0, extra_sigma);
        }
    }

    /// Virtually rotated weight lookup used by the Section V extension:
    /// row rotation r (hidden extension, Fig. 12) and column rotation c
    /// (input extension, Fig. 13). `W_{r,c}[i][j] = W[(i+r)%d][(j+c)%l]`.
    #[inline]
    pub fn weight_rotated(&self, i: usize, j: usize, r: usize, c: usize, t_k: f64) -> f64 {
        self.weight((i + r) % self.d, (j + c) % self.l, t_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn die(seed: u64) -> (ChipConfig, MismatchMatrix) {
        let cfg = ChipConfig::default();
        let mut rng = Prng::new(seed);
        let m = MismatchMatrix::fabricate(&cfg, &mut rng);
        (cfg, m)
    }

    #[test]
    fn fabrication_is_deterministic() {
        let (_, a) = die(1);
        let (_, b) = die(1);
        assert_eq!(a.dvt, b.dvt);
    }

    #[test]
    fn weights_are_lognormal_with_fabricated_sigma() {
        // The Fig. 15(c) extraction: fit a Gaussian to ln(w) and recover
        // sigma_VT =~ 16 mV.
        let (cfg, m) = die(2);
        let w = m.weights_at(300.0);
        let logs: Vec<f64> = w.data.iter().map(|x| x.ln()).collect();
        let (mu, sigma) = stats::fit_gaussian(&logs);
        let sigma_vt = sigma * thermal_voltage(300.0);
        assert!(mu.abs() < 0.01, "log-mean {mu}");
        assert!(
            (sigma_vt - cfg.sigma_vt).abs() < 0.0005,
            "recovered sigma_VT {}",
            sigma_vt * 1e3
        );
    }

    #[test]
    fn temperature_shrinks_spread() {
        // U_T grows with T, so ln w = dVT/U_T compresses: hotter die,
        // tighter weights (the Fig. 18 mechanism).
        let (_, m) = die(3);
        let cold = m.weights_at(280.0);
        let hot = m.weights_at(320.0);
        let s_cold = stats::std(&cold.data.iter().map(|x| x.ln()).collect::<Vec<_>>());
        let s_hot = stats::std(&hot.data.iter().map(|x| x.ln()).collect::<Vec<_>>());
        assert!(s_hot < s_cold);
        assert!((s_cold / s_hot - 320.0 / 280.0).abs() < 1e-6);
    }

    #[test]
    fn rotation_wraps_exactly() {
        let (_, m) = die(4);
        let t = 300.0;
        assert_eq!(m.weight_rotated(0, 0, 0, 0, t).to_bits(), m.weight(0, 0, t).to_bits());
        assert_eq!(
            m.weight_rotated(m.d - 1, 0, 1, 0, t).to_bits(),
            m.weight(0, 0, t).to_bits()
        );
        assert_eq!(
            m.weight_rotated(0, m.l - 1, 0, 1, t).to_bits(),
            m.weight(0, 0, t).to_bits()
        );
    }

    #[test]
    fn pelgrom_scaling() {
        let a_vt = 9.5e-9; // V*m
        let s = pelgrom_sigma_vt(a_vt, 0.35e-6, 0.35e-6);
        assert!((s - a_vt / 0.35e-6).abs() < 1e-9);
        let area = pelgrom_area_for_sigma(a_vt, s);
        assert!((area - 0.35e-6 * 0.35e-6).abs() / area < 1e-9);
    }
}
