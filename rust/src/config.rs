//! Configuration system: chip operating point + serving system settings.
//!
//! `ChipConfig` mirrors `python/compile/params.py` (the values baked into
//! the AOT artifacts) and adds everything the behavioural simulator needs
//! beyond the transfer function: mismatch sigma, noise, settling, energy
//! coefficients, temperature. Values default to Table I + Section III-D
//! of the paper. A minimal `key = value` file format (TOML subset) is
//! supported because the offline vendor set has no serde/toml.

use std::collections::BTreeMap;
use std::fmt;

/// Boltzmann-over-charge thermal voltage at temperature `t_k` [V].
pub fn thermal_voltage(t_k: f64) -> f64 {
    // U_T = kT/q; 25.85 mV at 300 K.
    0.02585 * t_k / 300.0
}

/// Iterate the `key = value` lines of a TOML-subset config text:
/// strips `#` comments, skips blanks and `[section]` headers, yields
/// (1-based line number, key, value) or a per-line error. Shared by
/// `ChipConfig::from_kv` and `dse::OperatingPoint::from_kv` so the two
/// parsers cannot drift.
pub fn kv_lines(text: &str) -> impl Iterator<Item = Result<(usize, &str, &str), String>> + '_ {
    text.lines().enumerate().filter_map(|(lineno, raw)| {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            return None;
        }
        Some(
            line.split_once('=')
                .map(|(k, v)| (lineno + 1, k.trim(), v.trim()))
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1)),
        )
    })
}

/// Neuron transfer shape: eq. 8 (quadratic) or its eq. 9 linearisation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transfer {
    Quadratic,
    Linear,
}

impl fmt::Display for Transfer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Transfer::Quadratic => write!(f, "quadratic"),
            Transfer::Linear => write!(f, "linear"),
        }
    }
}

/// One operating point of the mixed-signal ELM chip (paper Table I).
///
/// All units SI. Derived quantities (`k_neu`, `t_neu`, `i_rst`, ...) are
/// methods so that VDD / temperature sweeps stay consistent.
#[derive(Clone, Debug)]
pub struct ChipConfig {
    /// Physical input channels k (Table I: 128).
    pub d: usize,
    /// Physical hidden neurons N (Table I: 128).
    pub l: usize,
    /// Input DAC bits b_in (Table I: 10).
    pub b_in: u32,
    /// Valid counter MSB b, configurable 6..=14 (Section III-B).
    pub b: u32,
    /// Full-scale input current per channel I_max [A].
    pub i_max: f64,
    /// Neuron reset current at nominal VDD [A].
    pub i_rst_nom: f64,
    /// Leakage current I_lk [A] (eq. 7; ~0).
    pub i_lk: f64,
    /// Neuron feedback capacitor C_b [F] (50..300 fF configurable).
    pub c_b: f64,
    /// Neuron input capacitor C_a [F].
    pub c_a: f64,
    /// Current-mirror gate capacitor C = 0.4 pF (eq. 16 SNR sizing).
    pub c_mirror: f64,
    /// Sub-threshold slope factor kappa (Section IV-B: 0.7).
    pub kappa: f64,
    /// Supply voltage VDD [V].
    pub vdd: f64,
    /// Nominal VDD the chip was characterised at [V].
    pub vdd_nom: f64,
    /// Square-law knee for the I_rst(VDD) model [V] (DESIGN.md §4).
    pub v_theta: f64,
    /// Die temperature [K].
    pub temp_k: f64,
    /// Threshold-voltage mismatch sigma [V] (paper-measured: 16 mV).
    pub sigma_vt: f64,
    /// I_sat^z / I_max^z design ratio (Fig. 7a optimum 0.75).
    pub sat_ratio: f64,
    /// Neuron transfer shape.
    pub mode: Transfer,
    /// Thermal-noise injection in the mirror copies (eq. 14).
    pub noise_en: bool,
    /// Active current mirror for small codes (Fig. 3; 5.84x bandwidth).
    pub active_mirror: bool,
    /// Switching-energy coefficient alpha_1 [F] (measured fit 0.3 pF).
    pub alpha1: f64,
    /// Short-circuit coefficient alpha_2 * I_sc [A] (measured 0.076 uA).
    pub alpha2_isc: f64,
    /// Analog supply power P_avdd [W] (measured 3.4 uW).
    pub p_avdd: f64,
    /// Active-mirror bandwidth boost factor (SPICE-measured 5.84).
    pub active_boost: f64,
    /// Per-neuron relative spread of K_neu from C_b/VDD local variation.
    /// Lumped with mirror mismatch in measurements (Section VI-A).
    pub sigma_kneu_rel: f64,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            d: 128,
            l: 128,
            b_in: 10,
            b: 14,
            i_max: 1e-9,
            i_rst_nom: 512e-9,
            i_lk: 0.0,
            c_b: 1.0 / (26e3 / 1e-9), // K_neu = 26 kHz/nA at VDD = 1 V
            c_a: 300e-15,
            c_mirror: 0.4e-12,
            kappa: 0.7,
            vdd: 1.0,
            vdd_nom: 1.0,
            v_theta: 0.5,
            temp_k: 300.0,
            sigma_vt: 0.016,
            sat_ratio: 0.75,
            mode: Transfer::Quadratic,
            noise_en: false,
            active_mirror: true,
            alpha1: 0.3e-12,
            alpha2_isc: 0.076e-6,
            p_avdd: 3.4e-6,
            active_boost: 5.84,
            sigma_kneu_rel: 0.0,
        }
    }
}

impl ChipConfig {
    /// Thermal voltage at the configured die temperature [V].
    pub fn u_t(&self) -> f64 {
        thermal_voltage(self.temp_k)
    }

    /// Reset current at the configured VDD [A].
    ///
    /// Modelled as a saturated transistor square law around the nominal
    /// point, reproducing Fig. 6(b): lower VDD -> smaller I_rst -> smaller
    /// I_flx and f_max (DESIGN.md §4 substitution table).
    pub fn i_rst(&self) -> f64 {
        let num = (self.vdd - self.v_theta).max(0.0);
        let den = self.vdd_nom - self.v_theta;
        self.i_rst_nom * (num / den) * (num / den)
    }

    /// Current-to-frequency gain K_neu = 1/(C_b VDD) [Hz/A] (eq. 10).
    pub fn k_neu(&self) -> f64 {
        1.0 / (self.c_b * self.vdd)
    }

    /// Peak-frequency current I_flx = I_rst/2 (Fig. 5a).
    pub fn i_flx(&self) -> f64 {
        self.i_rst() / 2.0
    }

    /// Maximum column current I_max^z = d * I_max [A].
    pub fn i_max_z(&self) -> f64 {
        self.d as f64 * self.i_max
    }

    /// Counter-saturation column current I_sat^z (Section III-D).
    pub fn i_sat_z(&self) -> f64 {
        self.sat_ratio * self.i_max_z()
    }

    /// Counting window T_neu chosen so H = 2^b at I_sat^z (eq. 19).
    pub fn t_neu(&self) -> f64 {
        self.cap() as f64 / (self.k_neu() * self.i_sat_z())
    }

    /// Counter saturation value 2^b (eq. 11).
    pub fn cap(&self) -> u32 {
        1u32 << self.b
    }

    /// DAC code full scale 2^b_in.
    pub fn code_fs(&self) -> u32 {
        1u32 << self.b_in
    }

    /// Builder-style setters for sweeps.
    pub fn with_vdd(mut self, vdd: f64) -> Self {
        self.vdd = vdd;
        self
    }
    pub fn with_temp(mut self, t_k: f64) -> Self {
        self.temp_k = t_k;
        self
    }
    pub fn with_dims(mut self, d: usize, l: usize) -> Self {
        self.d = d;
        self.l = l;
        self
    }
    pub fn with_b(mut self, b: u32) -> Self {
        self.b = b;
        self
    }
    pub fn with_sigma_vt(mut self, s: f64) -> Self {
        self.sigma_vt = s;
        self
    }
    pub fn with_mode(mut self, m: Transfer) -> Self {
        self.mode = m;
        self
    }
    pub fn with_noise(mut self, en: bool) -> Self {
        self.noise_en = en;
        self
    }
    pub fn with_sat_ratio(mut self, r: f64) -> Self {
        self.sat_ratio = r;
        self
    }
    pub fn with_i_max(mut self, i: f64) -> Self {
        self.i_max = i;
        self
    }

    /// Instantiate the chip side of an autotuned operating point (the
    /// dse explorer's selection): mismatch sigma, saturation ratio,
    /// counter bits and hidden width from the point; input dimension
    /// from the workload. Everything else stays at Table I nominals.
    /// The serving-side half of the point (batch size) is applied by
    /// `Coordinator::start_tuned`.
    pub fn from_operating_point(op: &crate::dse::OperatingPoint, d: usize) -> Self {
        ChipConfig::default()
            .with_dims(d, op.l.max(1))
            .with_b(op.b)
            .with_sigma_vt(op.sigma_vt)
            .with_sat_ratio(op.ratio)
    }

    /// Parse a `key = value` file (lines; `#` comments; TOML subset).
    pub fn from_kv(text: &str) -> Result<Self, String> {
        let mut cfg = ChipConfig::default();
        for item in kv_lines(text) {
            let (lineno, k, v) = item?;
            let fv = || -> Result<f64, String> {
                v.parse::<f64>()
                    .map_err(|e| format!("line {lineno}: bad float {v}: {e}"))
            };
            match k {
                "d" => cfg.d = fv()? as usize,
                "l" => cfg.l = fv()? as usize,
                "b_in" => cfg.b_in = fv()? as u32,
                "b" => cfg.b = fv()? as u32,
                "i_max" => cfg.i_max = fv()?,
                "i_rst_nom" => cfg.i_rst_nom = fv()?,
                "i_lk" => cfg.i_lk = fv()?,
                "c_b" => cfg.c_b = fv()?,
                "c_a" => cfg.c_a = fv()?,
                "c_mirror" => cfg.c_mirror = fv()?,
                "kappa" => cfg.kappa = fv()?,
                "vdd" => cfg.vdd = fv()?,
                "vdd_nom" => cfg.vdd_nom = fv()?,
                "v_theta" => cfg.v_theta = fv()?,
                "temp_k" => cfg.temp_k = fv()?,
                "sigma_vt" => cfg.sigma_vt = fv()?,
                "sat_ratio" => cfg.sat_ratio = fv()?,
                "alpha1" => cfg.alpha1 = fv()?,
                "alpha2_isc" => cfg.alpha2_isc = fv()?,
                "p_avdd" => cfg.p_avdd = fv()?,
                "active_boost" => cfg.active_boost = fv()?,
                "sigma_kneu_rel" => cfg.sigma_kneu_rel = fv()?,
                "noise_en" => cfg.noise_en = v == "true",
                "active_mirror" => cfg.active_mirror = v == "true",
                "mode" => {
                    cfg.mode = match v.trim_matches('"') {
                        "quadratic" => Transfer::Quadratic,
                        "linear" => Transfer::Linear,
                        other => return Err(format!("line {lineno}: bad mode {other}")),
                    }
                }
                other => return Err(format!("line {lineno}: unknown key {other}")),
            }
        }
        Ok(cfg)
    }

    /// Table I style summary.
    pub fn summary(&self) -> String {
        format!(
            "Chip: {}x{} channels, {}-bit in / {}-bit out, VDD={} V, T={} K\n\
             K_neu={:.3} kHz/nA, I_rst={:.1} nA, I_max^z={:.1} nA, \
             I_sat^z/I_max^z={:.2}, T_neu={:.2} us, sigma_VT={:.1} mV, mode={}",
            self.d,
            self.l,
            self.b_in,
            self.b,
            self.vdd,
            self.temp_k,
            self.k_neu() * 1e-12, // Hz/A -> kHz/nA
            self.i_rst() * 1e9,
            self.i_max_z() * 1e9,
            self.sat_ratio,
            self.t_neu() * 1e6,
            self.sigma_vt * 1e3,
            self.mode,
        )
    }
}

/// Serving-system settings for the L3 coordinator.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Number of simulated dies behind the router.
    pub n_chips: usize,
    /// Dynamic batcher: max requests per batch.
    pub max_batch: usize,
    /// Dynamic batcher: max time to hold a partial batch.
    pub max_wait: std::time::Duration,
    /// Artifact directory produced by `make artifacts`.
    pub artifact_dir: String,
    /// Use the PJRT engine for batches at least this large (else the
    /// scalar Rust simulator runs the conversion).
    pub pjrt_min_batch: usize,
    /// Consecutive PJRT engine failures after which a worker drops its
    /// engine entirely (stops paying the flatten+attempt cost per
    /// batch) and serves from the chip simulator for good.
    pub pjrt_max_failures: u32,
    /// Base fabrication seed; chip i uses `seed + i`.
    pub seed: u64,
    /// Apply eq. 26 normalisation on the serving path.
    pub normalize: bool,
    /// Hot standby dies: fabricated and trained like actives but held
    /// out of rotation until a quarantine promotes them (DESIGN.md §12).
    pub standby_chips: usize,
    /// Virtual input dimension served by each die via the Section V
    /// rotation extension (DESIGN.md §13); `None` = the physical d.
    pub virtual_d: Option<usize>,
    /// Virtual hidden width served per die; `None` = the physical L.
    /// When either dim exceeds the die, every request costs
    /// `RotationPlan::passes()` physical conversions — priced into the
    /// router and batcher.
    pub virtual_l: Option<usize>,
    /// Heterogeneous fleet (DESIGN.md §13): per-die fabricated
    /// geometry `(k, N)`, one entry per die (actives then standbys).
    /// Empty = every die is fabricated at the `ChipConfig` dims. All
    /// dies serve the same virtual projection, so a smaller die runs
    /// more rotation passes per request — the router and batcher price
    /// each die at its own pass cost.
    pub die_geoms: Vec<(usize, usize)>,
    /// Per-connection TCP read timeout on the serving front end
    /// (DESIGN.md §15): a client that goes idle or dies mid-connection
    /// is disconnected after this long without a complete request, so
    /// dead connections drain instead of pinning one thread each.
    /// `None` disables the timeout (connections may pin threads
    /// forever — tests and trusted local tooling only).
    pub read_timeout: Option<std::time::Duration>,
    /// Flight-recorder ring capacity (DESIGN.md §16): how many
    /// completed request traces the always-on recorder retains. Sized
    /// once at startup — the ring never reallocates after boot —
    /// `velm serve --trace-cap N` overrides the 512 default.
    pub trace_cap: usize,
    /// Connection-reactor worker pool size (DESIGN.md §20): how many
    /// dispatch threads drain decoded v1 requests into the
    /// coordinator. The server's thread count is `reactor_workers + 2`
    /// (accept + poll loop) regardless of how many connections are
    /// open — connections are table entries, not threads.
    pub reactor_workers: usize,
    /// Connection auth tokens (DESIGN.md §20), each
    /// `"token=name,name"` (that token's Hello scopes the connection
    /// to those tenants) or `"token=*"` (unrestricted). Empty = no
    /// tokens configured; connections that skip Hello stay
    /// unrestricted either way, preserving pre-handshake clients.
    pub auth_tokens: Vec<String>,
    /// Fleet-health settings: probe cadence, drift thresholds,
    /// recovery/quarantine policy.
    pub fleet: crate::fleet::FleetConfig,
    /// Traffic-adaptive governor (DESIGN.md §17): tick period,
    /// hysteresis budget, SLO thresholds, the bits ladder. Disabled by
    /// default — `velm serve --governor` turns it on.
    pub governor: crate::governor::GovernorConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            n_chips: 2,
            max_batch: 128,
            max_wait: std::time::Duration::from_millis(2),
            artifact_dir: "artifacts".to_string(),
            pjrt_min_batch: 8,
            pjrt_max_failures: 3,
            seed: 0xE1_37,
            normalize: false,
            standby_chips: 0,
            virtual_d: None,
            virtual_l: None,
            die_geoms: Vec::new(),
            read_timeout: Some(std::time::Duration::from_secs(120)),
            trace_cap: crate::coordinator::trace::DEFAULT_TRACE_CAPACITY,
            reactor_workers: 4,
            auth_tokens: Vec::new(),
            fleet: crate::fleet::FleetConfig::default(),
            governor: crate::governor::GovernorConfig::default(),
        }
    }
}

/// Generic key-value map parse used by the CLI `--set k=v` overrides.
pub fn parse_overrides(pairs: &[String]) -> Result<BTreeMap<String, String>, String> {
    let mut map = BTreeMap::new();
    for p in pairs {
        let (k, v) = p
            .split_once('=')
            .ok_or_else(|| format!("override '{p}' is not key=value"))?;
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_nominals() {
        let c = ChipConfig::default();
        // K_neu = 26 kHz/nA (Section III-D)
        assert!((c.k_neu() - 26e3 / 1e-9).abs() / (26e3 / 1e-9) < 1e-12);
        assert_eq!(c.cap(), 16384); // 14-bit output format (Table I)
        assert_eq!(c.code_fs(), 1024); // 10-bit input format
        assert!((c.i_sat_z() - 0.75 * 128e-9).abs() < 1e-15);
        // T_neu = 2^b / (K_neu I_sat^z)
        let t = 16384.0 / (26e3 / 1e-9 * 96e-9);
        assert!((c.t_neu() - t).abs() / t < 1e-12);
    }

    #[test]
    fn i_rst_square_law() {
        let c = ChipConfig::default();
        assert!((c.i_rst() - c.i_rst_nom).abs() < 1e-18);
        let lo = c.clone().with_vdd(0.8);
        let hi = c.clone().with_vdd(1.2);
        assert!(lo.i_rst() < c.i_rst());
        assert!(hi.i_rst() > c.i_rst());
        // 0.8 V: ((0.3)/(0.5))^2 = 0.36 of nominal
        assert!((lo.i_rst() / c.i_rst_nom - 0.36).abs() < 1e-12);
    }

    #[test]
    fn thermal_voltage_scales_linearly() {
        assert!((thermal_voltage(300.0) - 0.02585).abs() < 1e-12);
        assert!((thermal_voltage(320.0) / thermal_voltage(300.0) - 320.0 / 300.0).abs() < 1e-12);
    }

    #[test]
    fn kv_roundtrip() {
        let text = "
            # operating point
            d = 64
            l = 32
            b = 8
            vdd = 0.8
            mode = \"linear\"
            noise_en = true
        ";
        let c = ChipConfig::from_kv(text).unwrap();
        assert_eq!(c.d, 64);
        assert_eq!(c.l, 32);
        assert_eq!(c.b, 8);
        assert_eq!(c.mode, Transfer::Linear);
        assert!(c.noise_en);
        assert!((c.vdd - 0.8).abs() < 1e-12);
    }

    #[test]
    fn from_operating_point_applies_all_axes() {
        let op = crate::dse::OperatingPoint {
            sigma_vt: 0.022,
            ratio: 0.6,
            b: 8,
            l: 96,
            batch: 32,
        };
        let c = ChipConfig::from_operating_point(&op, 14);
        assert_eq!((c.d, c.l, c.b), (14, 96, 8));
        assert!((c.sigma_vt - 0.022).abs() < 1e-15);
        assert!((c.sat_ratio - 0.6).abs() < 1e-15);
        // derived quantities stay consistent: T_neu set so H = 2^b at
        // I_sat^z = ratio * d * I_max
        let t = c.cap() as f64 / (c.k_neu() * 0.6 * 14.0 * c.i_max);
        assert!((c.t_neu() - t).abs() / t < 1e-12);
    }

    #[test]
    fn kv_rejects_unknown_key_naming_it() {
        // a typoed key must fail loudly, with the key and its line in
        // the message — never be silently ignored
        let err = ChipConfig::from_kv("nonsense = 3").unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
        assert!(err.contains("nonsense"), "{err}");
        assert!(err.contains("line 1"), "{err}");
        let err = ChipConfig::from_kv("d = 4\nsigma_vtt = 0.01").unwrap_err();
        assert!(err.contains("sigma_vtt") && err.contains("line 2"), "{err}");
    }

    #[test]
    fn overrides_parse() {
        let m = parse_overrides(&["a=1".into(), "b = x".into()]).unwrap();
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "x");
        assert!(parse_overrides(&["broken".into()]).is_err());
    }
}
