//! `velm` CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   characterize  Table I summary + Fig. 15-style die characterisation
//!   train         chip-in-the-loop training on a named dataset
//!   classify      train then evaluate train/test error (Table II row)
//!   serve         start the TCP serving front end
//!   client        talk to a running fleet through the client SDK (DESIGN.md §15)
//!   sweep         quick design-space sweeps (ratio | beta-bits | counter-bits)
//!   tune          closed-loop autotuner: Pareto front + knee operating point
//!   fleet         fleet-health demo: inject drift, watch detect/recover
//!   info          artifact + configuration report
//!   lint          concurrency-convention lints over src/ (DESIGN.md §18)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use velm::bench::Table;
use velm::chip::ChipModel;
use velm::cli::Args;
use velm::config::{ChipConfig, SystemConfig, Transfer};
use velm::coordinator::{server, Coordinator};
use velm::datasets::synth;
use velm::dse::{self, FastSim};
use velm::elm::{self, train::HiddenLayer, ChipHidden};
use velm::extension::VirtualChip;
use velm::util::stats;

fn usage() -> &'static str {
    "velm — VLSI ELM reproduction (Yao & Basu 2016)\n\
     USAGE: velm <command> [--options]\n\
     COMMANDS:\n\
       characterize [--seed N] [--d N] [--l N]       die characterisation (Fig. 15)\n\
       train --dataset NAME [--l N] [--seed N]       chip-in-the-loop training\n\
       classify --dataset NAME [--l N] [--normalize] train + test error (Table II)\n\
       serve [--addr HOST:PORT] [--dataset NAME] [--chips N]\n\
             [--point FILE] [--phys-d K] [--phys-l N] [--virtual-l L]\n\
             [--geoms K1xL1,K2xL2,...] [--tenant NAME=DATASET ...]\n\
             [--governor] [--governor-bits B1,B2,...] [--governor-tick-ms MS]\n\
             [--reactor-workers N] [--auth-token TOK=T1,T2|TOK=* ...]\n\
             [--read-timeout-ms MS] [--trace-cap N]  TCP front end (tuned point via FILE;\n\
                                                     virtual dies via --phys-d/--phys-l/\n\
                                                     --virtual-l; heterogeneous per-die\n\
                                                     geometries via --geoms; extra models\n\
                                                     on the same fleet via repeatable\n\
                                                     --tenant, or REGISTER at runtime;\n\
                                                     --governor closes the telemetry ->\n\
                                                     operating-point loop, rung ladder\n\
                                                     from --governor-bits or the --point\n\
                                                     file's Pareto front; idle clients\n\
                                                     dropped after --read-timeout-ms,\n\
                                                     0 = never; --trace-cap sizes the\n\
                                                     flight-recorder ring, default 512;\n\
                                                     every v1 connection is served by the\n\
                                                     multiplexed reactor: --reactor-workers\n\
                                                     sizes its dispatch pool, default 4;\n\
                                                     repeatable --auth-token entries give\n\
                                                     HELLO tokens a tenant scope, * = all)\n\
       client VERB [--addr HOST:PORT] [--v0]         typed client SDK against a running\n\
                                                     fleet; VERB is one of ping |\n\
                                                     stats [--format human|json|prom] |\n\
                                                     health | models | governor |\n\
                                                     drain --die N |\n\
                                                     predict --features 1,2 [--tenant T] |\n\
                                                     batch --row [tenant:]1,2 ... [--stream] |\n\
                                                     hello --token TOK |\n\
                                                     update NAME --features 1,2\n\
                                                       --targets t1[,t2...] |\n\
                                                     trace [--last N] |\n\
                                                     timeline [--last N] [--out FILE]\n\
                                                       [--check] |\n\
                                                     register NAME DATASET [--seed N] |\n\
                                                     unregister NAME   (--v0 forces the\n\
                                                     ASCII line protocol; default is the\n\
                                                     v1 framed protocol with one-round-\n\
                                                     trip batches; trace, timeline, the\n\
                                                     json/prom stats formats, hello,\n\
                                                     update and batch --stream need v1 —\n\
                                                     --stream prints rows in completion\n\
                                                     order as dies finish; update streams\n\
                                                     one labelled OS-ELM row into a\n\
                                                     registered tenant; --token runs the\n\
                                                     HELLO handshake before the verb.\n\
                                                     timeline exports the fleet profile as\n\
                                                     Chrome trace-event JSON: open the\n\
                                                     --out file at https://ui.perfetto.dev\n\
                                                     or chrome://tracing; --check schema-\n\
                                                     validates the export instead)\n\
       bench serve [--smoke] [--out FILE]            serving benchmark against an in-\n\
             [--requests N] [--concurrency N]        process fleet; reduces the\n\
             [--chips N] [--dataset NAME]            observability snapshot into a\n\
             [--governor] [--connections N]          versioned JSON report (BENCH_6.json;\n\
             [--arrival poisson:RATE]                --governor adds the governor-enabled\n\
                                                     idle-heavy comparison leg and writes\n\
                                                     schema v2 to BENCH_7.json; --arrival\n\
                                                     switches the closed loop to open-loop\n\
                                                     Poisson arrivals at RATE req/s;\n\
                                                     --connections adds the reactor\n\
                                                     multiplexing leg — N pipelined TCP\n\
                                                     connections over a bounded thread\n\
                                                     pool — and writes schema v3 to\n\
                                                     BENCH_8.json)\n\
       bench gate --current FILE --previous FILE     fail if throughput drops or p99 rises\n\
             [--max-regress 0.10]                    beyond the budget between two reports\n\
       sweep --what ratio|beta-bits|counter-bits     quick design-space sweep (Fig. 7)\n\
       tune [--dataset NAME] [--rounds N] [--trials N] [--l LIST] [--b LIST]\n\
            [--batch LIST] [--weights E,J,T,X] [--out FILE]\n\
            [--phys-d K --phys-l N]                  Pareto autotune (pass-aware with a\n\
                                                     pinned k x N die geometry)\n\
       fleet [--dataset NAME] [--chips N] [--standby N] [--ticks N]\n\
             [--temp K] [--age-sigma MV]             drift-recovery demo (Fig. 18 ramp)\n\
       info [--artifacts DIR]                        configuration + artifact report\n\
       lint [--root DIR]                             concurrency-convention lints over\n\
                                                     src/ (facade imports, relaxed-ok\n\
                                                     justifications, frame-tag unique-\n\
                                                     ness, single booking site); exits\n\
                                                     non-zero on any finding\n\
     Common options: --b BITS (counter), --sigma-vt MV, --vdd V, --lambda F\n"
}

#[allow(clippy::field_reassign_with_default)] // getters are fallible; a struct literal can't `?` per field
fn chip_cfg_from(args: &Args) -> Result<ChipConfig> {
    let mut cfg = ChipConfig::default();
    cfg.d = args.get_usize("d", cfg.d).map_err(anyhow::Error::msg)?;
    cfg.l = args.get_usize("l", cfg.l).map_err(anyhow::Error::msg)?;
    cfg.b = args.get_usize("b", cfg.b as usize).map_err(anyhow::Error::msg)? as u32;
    cfg.vdd = args.get_f64("vdd", cfg.vdd).map_err(anyhow::Error::msg)?;
    cfg.sigma_vt = args
        .get_f64("sigma-vt", cfg.sigma_vt * 1e3)
        .map_err(anyhow::Error::msg)?
        / 1e3;
    if args.flag("linear") {
        cfg.mode = Transfer::Linear;
    }
    if args.flag("noise") {
        cfg.noise_en = true;
    }
    Ok(cfg)
}

fn cmd_characterize(args: &Args) -> Result<()> {
    let cfg = chip_cfg_from(args)?;
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    println!("{}", cfg.summary());
    let mut chip = ChipModel::fabricate(cfg.clone(), seed);
    // Fig. 15(c): weight surface -> log-normal fit
    let surf = chip.weight_surface(100);
    let mut vals: Vec<f64> = surf.data.iter().cloned().filter(|&v| v > 0.0).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = vals[vals.len() / 2];
    let logs: Vec<f64> = vals.iter().map(|v| (v / median).ln()).collect();
    let (_, s) = stats::fit_gaussian(&logs);
    println!(
        "die {seed}: weight spread fits log-normal, sigma_dVT ~ {:.2} mV (fabricated {:.2} mV; paper: ~16 mV)",
        s * velm::config::thermal_voltage(cfg.temp_k) * 1e3,
        cfg.sigma_vt * 1e3
    );
    println!(
        "ledger: {} conversions, {:.3} ms simulated, {:.3} pJ/MAC, {:.1} MMAC/s",
        chip.ledger.conversions,
        chip.ledger.sim_time * 1e3,
        chip.ledger.pj_per_mac(),
        chip.ledger.mmacs()
    );
    Ok(())
}

fn cmd_classify(args: &Args, train_only: bool) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?.to_string();
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let lambda = args.get_f64("lambda", 0.1).map_err(anyhow::Error::msg)?;
    let beta_bits = args.get_usize("beta-bits", 10).map_err(anyhow::Error::msg)? as u32;
    let ds = synth::by_name(&name, seed).with_context(|| format!("unknown dataset {name}"))?;
    let mut cfg = chip_cfg_from(args)?;
    cfg.b = args.get_usize("b", 10).map_err(anyhow::Error::msg)? as u32;
    let normalize = args.flag("normalize");
    println!(
        "dataset {name}: d={}, {} train / {} test",
        ds.d(),
        ds.n_train(),
        ds.n_test()
    );
    // choose physical vs virtual chip by dimension
    let use_virtual = ds.d() > cfg.d || args.get("virtual-l").is_some();
    if use_virtual {
        let l_virt = args.get_usize("virtual-l", cfg.l).map_err(anyhow::Error::msg)?;
        let chip = ChipModel::fabricate(cfg.clone(), seed);
        let mut vchip =
            VirtualChip::new(chip, ds.d(), l_virt).map_err(anyhow::Error::msg)?;
        println!(
            "virtual chip: {}x{} physical -> {}x{} via {} rotation passes/sample",
            cfg.d,
            cfg.l,
            ds.d(),
            l_virt,
            vchip.plan.passes()
        );
        let (model, h) = elm::train_model(&mut vchip, &ds.train_x, &ds.train_y, lambda, beta_bits, false)
            .map_err(anyhow::Error::msg)?;
        let train_err =
            elm::train::misclassification(&elm::train::predict(&h, &model.head), &ds.train_y);
        println!("train error: {:.2}%", train_err * 100.0);
        if !train_only {
            let err = elm::eval_classification(&mut vchip, &model, &ds.test_x, &ds.test_y);
            println!("test error: {:.2}%", err * 100.0);
        }
    } else {
        cfg.d = ds.d();
        let chip = ChipModel::fabricate(cfg.clone(), seed);
        let mut hidden = if normalize {
            ChipHidden::normalized(chip)
        } else {
            ChipHidden::new(chip)
        };
        let (model, h) =
            elm::train_model(&mut hidden, &ds.train_x, &ds.train_y, lambda, beta_bits, normalize)
                .map_err(anyhow::Error::msg)?;
        let train_err =
            elm::train::misclassification(&elm::train::predict(&h, &model.head), &ds.train_y);
        println!("train error: {:.2}% (L={})", train_err * 100.0, hidden.hidden_dim());
        if !train_only {
            let err = elm::eval_classification_fixed(&mut hidden, &model, &ds.test_x, &ds.test_y);
            println!("test error (fixed-point 2nd stage): {:.2}%", err * 100.0);
            println!(
                "chip ledger: {:.3} pJ/MAC at {:.1} conversions/s simulated",
                hidden.chip.ledger.pj_per_mac(),
                hidden.chip.ledger.rate()
            );
        }
    }
    Ok(())
}

#[allow(clippy::field_reassign_with_default)] // getters are fallible; a struct literal can't `?` per field
fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7177");
    let name = args.get_or("dataset", "brightdata");
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let ds = synth::by_name(&name, seed).with_context(|| format!("unknown dataset {name}"))?;
    let mut sys = SystemConfig::default();
    sys.n_chips = args.get_usize("chips", sys.n_chips).map_err(anyhow::Error::msg)?;
    sys.artifact_dir = args.get_or("artifacts", &sys.artifact_dir);
    // idle-client hygiene (DESIGN.md §15): 0 disables the read timeout
    sys.read_timeout = args
        .get_ms_opt("read-timeout-ms", sys.read_timeout)
        .map_err(anyhow::Error::msg)?;
    // flight-recorder sizing (DESIGN.md §16): the ring allocates once
    // at boot and never grows, so capacity is a serve-time choice
    sys.trace_cap = args.get_usize("trace-cap", sys.trace_cap).map_err(anyhow::Error::msg)?;
    // connection reactor sizing (DESIGN.md §20): every v1 connection is
    // multiplexed over this worker pool, so threads stay
    // `--reactor-workers + 2` no matter how many clients dial in
    sys.reactor_workers = args
        .get_usize("reactor-workers", sys.reactor_workers)
        .map_err(anyhow::Error::msg)?;
    anyhow::ensure!(sys.reactor_workers > 0, "--reactor-workers must be positive");
    // per-connection auth scoping (DESIGN.md §20): repeatable
    // `--auth-token TOKEN=tenant1,tenant2` (or `TOKEN=*` for an
    // unrestricted scope); clients present tokens via the HELLO frame
    sys.auth_tokens.extend(args.get_all("auth-token"));
    // heterogeneous fleets (DESIGN.md §13): per-die fabricated geometry
    if let Some(geoms) = args.get("geoms") {
        sys.die_geoms = geoms
            .split(',')
            .map(|tok| {
                let (k, l) = tok
                    .trim()
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("--geoms wants KxL pairs, got '{tok}'"))?;
                Ok((
                    k.trim().parse::<usize>().context("bad K in --geoms")?,
                    l.trim().parse::<usize>().context("bad L in --geoms")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    // `--point FILE` closes the tune -> serve loop: apply a serialized
    // `velm tune --out` operating point (chip config + batch size)
    let mut front_bits: Option<Vec<u32>> = None;
    let mut cfg = match args.get("point") {
        Some(path) => {
            // the point file owns the whole chip config: explicit chip
            // flags would be silently shadowed, so call that out
            for opt in ["b", "sigma-vt", "vdd", "d", "l"] {
                if args.get(opt).is_some() {
                    eprintln!("note: --{opt} ignored; chip config comes from --point");
                }
            }
            for flag in ["linear", "noise"] {
                if args.flag(flag) {
                    eprintln!("note: --{flag} ignored; chip config comes from --point");
                }
            }
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading operating point {path}"))?;
            let op = velm::dse::OperatingPoint::from_kv(&text)
                .map_err(anyhow::Error::msg)?;
            // the file's Pareto-front sections double as the governor's
            // rung ladder when --governor is on (DESIGN.md §17): the
            // tuned trade-off becomes a runtime artifact
            if let Ok(front) = velm::dse::OperatingPoint::parse_front(&text) {
                let mut bits: Vec<u32> = front.iter().map(|p| p.b).collect();
                bits.sort_unstable();
                bits.dedup();
                front_bits = (bits.len() >= 2).then_some(bits);
            }
            sys.max_batch = op.batch.max(1);
            println!("operating point from {path}: {op}");
            ChipConfig::from_operating_point(&op, ds.d())
        }
        None => {
            let mut cfg = chip_cfg_from(args)?;
            cfg.d = ds.d();
            cfg.b = args.get_usize("b", 10).map_err(anyhow::Error::msg)? as u32;
            cfg
        }
    };
    // virtual-die serving (DESIGN.md §13): --phys-d fabricates K-channel
    // dies and serves the workload's d by input rotation; --virtual-l
    // serves an L-wide hidden layer beyond the physical array
    let phys_d = args.get_usize("phys-d", 0).map_err(anyhow::Error::msg)?;
    if phys_d > 0 {
        anyhow::ensure!(
            phys_d <= ds.d(),
            "--phys-d {phys_d} exceeds the workload dimension {}",
            ds.d()
        );
        cfg.d = phys_d;
        sys.virtual_d = Some(ds.d());
    }
    let virtual_l = args.get_usize("virtual-l", 0).map_err(anyhow::Error::msg)?;
    if virtual_l > 0 {
        sys.virtual_l = Some(virtual_l);
    }
    // --phys-l N: fabricate N-wide dies; whatever L the point/config
    // asked for beyond that is served by hidden-block rotation. This is
    // the serve half of `velm tune --phys-d K --phys-l N` — the die
    // geometry the pass-aware objective priced, not the point's virtual L
    let phys_l = args.get_usize("phys-l", 0).map_err(anyhow::Error::msg)?;
    if phys_l > 0 {
        let served_l = sys.virtual_l.unwrap_or(cfg.l);
        anyhow::ensure!(
            phys_l <= served_l,
            "--phys-l {phys_l} exceeds the served hidden width {served_l}"
        );
        sys.virtual_l = Some(served_l);
        cfg.l = phys_l;
    }
    if sys.virtual_d.is_some() || sys.virtual_l.is_some() {
        let plan = velm::extension::RotationPlan::new(
            cfg.d,
            cfg.l,
            sys.virtual_d.unwrap_or(cfg.d),
            sys.virtual_l.unwrap_or(cfg.l),
        )
        .map_err(anyhow::Error::msg)?;
        println!(
            "virtual dies: {}x{} physical -> {}x{} served, {} rotation passes/request",
            plan.k,
            plan.n,
            plan.d,
            plan.l,
            plan.passes()
        );
    }
    // traffic-adaptive governor (DESIGN.md §17): --governor closes the
    // telemetry -> operating-point loop. Rung bits come from an
    // explicit --governor-bits list, else the tuned front, else the
    // config default ladder.
    if args.flag("governor")
        || args.get("governor-bits").is_some()
        || args.get("governor-tick-ms").is_some()
    {
        sys.governor.enabled = true;
    }
    match args.get_list::<u32>("governor-bits").map_err(anyhow::Error::msg)? {
        Some(bits) => sys.governor.bits = bits,
        None => {
            if let Some(bits) = front_bits.filter(|_| sys.governor.enabled) {
                sys.governor.bits = bits;
            }
        }
    }
    if let Some(ms) = args.get("governor-tick-ms") {
        let ms: u64 = ms.parse().map_err(|e| anyhow::anyhow!("--governor-tick-ms: {e}"))?;
        sys.governor.tick = std::time::Duration::from_millis(ms.max(1));
    }
    if sys.governor.enabled {
        println!(
            "governor on: tick {}ms, rung bits {:?} (+ the boot point)",
            sys.governor.tick.as_millis(),
            sys.governor.bits
        );
    }
    println!("training {} dies on {name} ...", sys.n_chips);
    let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)?;
    // multi-tenant boot (DESIGN.md §14): `--tenant name=dataset`,
    // repeatable — each installs another model on the same die fleet
    for pair in args.get_all("tenant") {
        let (tenant, dataset) = pair
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--tenant wants name=dataset, got '{pair}'"))?;
        let spec = velm::registry::TenantSpec::from_dataset(tenant, dataset, seed, coord.d)
            .map_err(anyhow::Error::msg)?;
        let task = spec.task;
        let score = coord.register_tenant(spec)?;
        println!(
            "tenant {tenant} registered from {dataset} ({task}, mean train score {score:.4})"
        );
    }
    server::serve(Arc::new(coord), &addr)
}

/// Talk to a running fleet through the client SDK (DESIGN.md §15) —
/// the typed replacement for hand-rolled `nc` command lines. Defaults
/// to the v1 framed protocol; `--v0` forces the ASCII line grammar.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7177");
    let verb = args.positional.first().map(String::as_str).unwrap_or("ping");
    let mut client = if args.flag("v0") {
        velm::client::Client::connect_v0(addr.as_str())?
    } else {
        velm::client::Client::connect(addr.as_str())?
    };
    let show = |prefix: &str, p: &velm::protocol::Prediction| {
        let tenant = p
            .tenant
            .as_deref()
            .map(|t| format!(" tenant {t}"))
            .unwrap_or_default();
        println!("{prefix}label {} score {:.6}{tenant}", p.label, p.score);
    };
    // `--token TOK` on any verb runs the HELLO handshake first, binding
    // this connection to the token's tenant scope (DESIGN.md §20)
    if let Some(token) = args.get("token") {
        let tenants = client.hello(token)?;
        println!("hello ok: scope {}", tenants.join(","));
    }
    match verb {
        "ping" => {
            client.ping()?;
            println!("pong");
        }
        "stats" => match args.get_or("format", "human").as_str() {
            "human" => println!("{}", client.stats()?),
            "json" => println!("{}", client.snapshot()?.to_json()),
            "prom" => print!("{}", client.snapshot()?.to_prometheus()),
            other => bail!("unknown stats format '{other}' (human|json|prom)"),
        },
        "trace" => {
            let last = args.get_usize("last", 32).map_err(anyhow::Error::msg)?;
            let entries = client.trace(last)?;
            if entries.is_empty() {
                println!("trace ring empty (serve some traffic first)");
            }
            for t in entries {
                println!("{t}");
            }
        }
        "timeline" => {
            // fleet timeline profile (DESIGN.md §19) as Chrome
            // trace-event JSON. Workflow: `velm client timeline --out
            // trace.json`, then open trace.json at
            // https://ui.perfetto.dev (or chrome://tracing) to see one
            // process per die with a thread track per segment.
            let last = args.get_usize("last", 4096).map_err(anyhow::Error::msg)?;
            let events = client.timeline(last)?;
            let json = velm::coordinator::timeline::chrome_trace_json(&events);
            if args.flag("check") {
                let n = velm::coordinator::timeline::validate_chrome_trace(&json)
                    .map_err(anyhow::Error::msg)?;
                println!(
                    "timeline ok: {} events export as {n} valid trace records",
                    events.len()
                );
            }
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, json + "\n")
                        .with_context(|| format!("writing {path}"))?;
                    println!(
                        "Chrome trace written to {path} — open it at \
                         https://ui.perfetto.dev or chrome://tracing"
                    );
                }
                // bare `timeline` prints the JSON for piping; with
                // --check and no --out the verdict above is the output
                None if !args.flag("check") => println!("{json}"),
                None => {}
            }
        }
        "health" => println!("{}", client.health()?),
        "models" => println!("{}", client.models()?),
        "governor" => println!("{}", client.governor()?),
        "drain" => {
            // draining is destructive: never let a missing flag default
            // to pulling die 0 out of rotation
            let die: usize = args
                .get("die")
                .context("drain wants --die N")?
                .parse()
                .map_err(|e| anyhow::anyhow!("--die: {e}"))?;
            client.drain(die)?;
            println!("draining die {die}");
        }
        "predict" => {
            let feats = args
                .get_f64_list("features")
                .map_err(anyhow::Error::msg)?
                .context("predict wants --features x1,x2,...")?;
            let p = client.predict(args.get("tenant"), &feats)?;
            show("", &p);
        }
        "batch" => {
            // repeatable --row [tenant:]x1,x2,... — over v1 the whole
            // batch is ONE wire round-trip and ONE batcher submission
            let mut rows = Vec::new();
            for raw in args.get_all("row") {
                let (tenant, feats) = match raw.split_once(':') {
                    Some((t, f)) => (Some(t.trim().to_string()), f),
                    None => (None, raw.as_str()),
                };
                let features =
                    velm::protocol::parse_features(feats).map_err(anyhow::Error::msg)?;
                rows.push(velm::protocol::PredictRow { tenant, features });
            }
            anyhow::ensure!(
                !rows.is_empty(),
                "batch wants at least one --row [tenant:]x1,x2,..."
            );
            if args.flag("stream") {
                // streamed replies (v1 only, DESIGN.md §20): rows print
                // in completion order as their dies finish, not in
                // submission order
                let (preds, passes) = client.predict_stream(&rows, |i, p| {
                    show(&format!("row {i} (streamed): "), p);
                })?;
                println!("stream end: {} rows, {passes} conversion passes", preds.len());
            } else {
                let preds = client.predict_batch(&rows)?;
                for (i, p) in preds.iter().enumerate() {
                    show(&format!("row {i}: "), p);
                }
            }
        }
        "hello" => {
            // bare handshake check: `--token` above already ran it;
            // without the flag this explains what the verb needs
            anyhow::ensure!(
                args.get("token").is_some(),
                "hello wants --token TOKEN (scope comes from `velm serve --auth-token`)"
            );
        }
        "update" => {
            // one labelled OS-ELM row into a registered tenant's heads
            // via the shared-P update path (DESIGN.md §14, §20)
            let name = args
                .positional
                .get(1)
                .context("update wants: update NAME --features x1,x2 --targets t1[,t2...]")?;
            let features = args
                .get_f64_list("features")
                .map_err(anyhow::Error::msg)?
                .context("update wants --features x1,x2,...")?;
            let targets = args
                .get_f64_list("targets")
                .map_err(anyhow::Error::msg)?
                .context("update wants --targets t1[,t2...] (one value per head)")?;
            client.tenant_update(name, &features, &targets)?;
            println!("updated {name} with one labelled row");
        }
        "register" => {
            let name = args.positional.get(1).context("register wants: register NAME DATASET")?;
            let dataset =
                args.positional.get(2).context("register wants: register NAME DATASET")?;
            let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
            let (task, score) = client.register(name, dataset, seed)?;
            println!("registered {name} ({task}, mean train score {score:.4})");
        }
        "unregister" => {
            let name = args.positional.get(1).context("unregister wants a tenant name")?;
            client.unregister(name)?;
            println!("unregistered {name}");
        }
        other => bail!(
            "unknown client verb '{other}' \
             (ping|predict|batch|hello|update|register|unregister|models|stats|health|\
             governor|drain|trace|timeline)"
        ),
    }
    Ok(())
}

/// Closed-loop serving benchmark (DESIGN.md §16): boot an in-process
/// fleet, hammer it, write the versioned JSON report CI validates.
/// `bench gate` compares two such reports and fails on regression.
fn cmd_bench(args: &Args) -> Result<()> {
    let what = args.positional.first().map(String::as_str).unwrap_or("serve");
    if what == "gate" {
        return cmd_bench_gate(args);
    }
    anyhow::ensure!(what == "serve", "unknown bench target '{what}' (expected: serve | gate)");
    let mut cfg = if args.flag("smoke") {
        velm::loadgen::BenchConfig::smoke()
    } else {
        velm::loadgen::BenchConfig::full()
    };
    cfg.dataset = args.get_or("dataset", &cfg.dataset);
    cfg.seed = args.get_u64("seed", cfg.seed).map_err(anyhow::Error::msg)?;
    cfg.requests = args.get_usize("requests", cfg.requests).map_err(anyhow::Error::msg)?;
    cfg.concurrency =
        args.get_usize("concurrency", cfg.concurrency).map_err(anyhow::Error::msg)?;
    cfg.chips = args.get_usize("chips", cfg.chips).map_err(anyhow::Error::msg)?;
    cfg.governor = args.flag("governor");
    // open-loop arrivals (DESIGN.md §19): `--arrival poisson:RATE`
    // replaces the closed loop with seeded Poisson arrivals at RATE
    // requests/second, so queueing is driven by the offered load
    // instead of the clients' round-trip times
    if let Some(spec) = args.get("arrival") {
        let rate = spec
            .strip_prefix("poisson:")
            .ok_or_else(|| {
                anyhow::anyhow!("--arrival wants poisson:RATE (req/s), got '{spec}'")
            })?
            .parse::<f64>()
            .map_err(|e| anyhow::anyhow!("--arrival rate: {e}"))?;
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "--arrival rate must be a positive req/s figure"
        );
        cfg.arrival = Some(rate);
    }
    // reactor multiplexing leg (DESIGN.md §20): `--connections N`
    // drives N real TCP connections through the connection reactor,
    // each pipelining correlated requests — schema v3, BENCH_8.json
    let conns = args.get_usize("connections", 0).map_err(anyhow::Error::msg)?;
    if conns > 0 {
        cfg.connections = Some(conns);
    }
    println!(
        "bench serve: {} requests x {} {} clients on {} ({} dies){} ...",
        cfg.requests,
        cfg.concurrency,
        match cfg.arrival {
            Some(rate) => format!("open-loop (poisson {rate} req/s)"),
            None => "closed-loop".to_string(),
        },
        cfg.dataset,
        cfg.chips,
        if cfg.governor {
            " + governor comparison leg"
        } else if cfg.connections.is_some() {
            " + reactor multiplexing leg"
        } else {
            ""
        }
    );
    let report = velm::loadgen::run(&cfg)?;
    let s = &report.snapshot;
    println!(
        "served {} rows in {:.2}s: {:.1} req/s, total p50 {}us p99 {}us \
         (queue p50 {}us, batch p50 {}us, compute p50 {}us), {:.3} pJ/MAC",
        s.responses,
        report.elapsed_us as f64 * 1e-6,
        report.throughput_rps(),
        s.latency.p50_us,
        s.latency.p99_us,
        s.queue.p50_us,
        s.batch_wait.p50_us,
        s.compute.p50_us,
        s.pj_per_mac()
    );
    if let Some(g) = &report.governor {
        println!(
            "governor leg: {} rows, {:.1} req/s, p99 {}us, {} fJ \
             (saved {} fJ vs boot pricing; {} lowers / {} raises)",
            g.responses, g.throughput_rps, g.p99_us, g.energy_fj, g.fj_saved, g.lowers, g.raises
        );
    }
    if let Some(r) = &report.reactor {
        println!(
            "reactor leg: {} connections x {} in flight over {} server threads \
             (pool {} + acceptor + poll loop): {} rows, {:.1} req/s, \
             peak {} in flight / {} conns",
            r.connections,
            r.in_flight_depth,
            r.thread_count,
            r.pool_workers,
            r.responses,
            r.throughput_rps,
            r.peak_in_flight,
            r.peak_conns
        );
    }
    let json = report.to_json();
    velm::loadgen::validate_bench_json(&json).map_err(anyhow::Error::msg)?;
    let default_out = if cfg.connections.is_some() {
        "BENCH_8.json"
    } else if cfg.governor {
        "BENCH_7.json"
    } else {
        "BENCH_6.json"
    };
    let out = args.get_or("out", default_out);
    std::fs::write(&out, json + "\n").with_context(|| format!("writing {out}"))?;
    println!("report written to {out}");
    Ok(())
}

/// `velm bench gate --current F --previous F [--max-regress 0.10]`:
/// the CI regression gate over two bench reports.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let current = args
        .get("current")
        .ok_or_else(|| anyhow::anyhow!("bench gate needs --current FILE"))?;
    let previous = args
        .get("previous")
        .ok_or_else(|| anyhow::anyhow!("bench gate needs --previous FILE"))?;
    let max_regress = args.get_f64("max-regress", 0.10).map_err(anyhow::Error::msg)?;
    let cur = std::fs::read_to_string(current).with_context(|| format!("reading {current}"))?;
    let prev =
        std::fs::read_to_string(previous).with_context(|| format!("reading {previous}"))?;
    let verdict = velm::loadgen::gate_bench_json(&cur, &prev, max_regress)
        .map_err(anyhow::Error::msg)?;
    println!("bench gate OK: {verdict}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let what = args.get_or("what", "ratio");
    match what.as_str() {
        "ratio" => {
            // mini Fig. 7(a): error at fixed L across the ratio axis
            let l = args.get_usize("l", 64).map_err(anyhow::Error::msg)?;
            println!("I_sat^z/I_max^z sweep at L={l} (sinc regression, lower is better)");
            let ratios = [0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.5];
            let errs = dse::par_map(ratios.to_vec(), dse::default_threads(), |r| {
                let sim = FastSim { ratio: r, ..Default::default() };
                velm::dse::lmin::mean_error(&sim, l, 600, 3, 11)
            });
            for (r, e) in ratios.iter().zip(errs) {
                println!("  ratio {r:5.2}: err {e:.4}");
            }
        }
        "beta-bits" | "counter-bits" => {
            println!("see `cargo bench --bench fig7_design_space` for the full study");
        }
        other => bail!("unknown sweep '{other}'"),
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let name = args.get_or("dataset", "sinc");
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let ds = synth::by_name(&name, seed).with_context(|| format!("unknown dataset {name}"))?;
    let rounds = args.get_usize("rounds", 2).map_err(anyhow::Error::msg)?;
    let trials = args.get_usize("trials", 3).map_err(anyhow::Error::msg)?;
    let threads = args
        .get_usize("threads", dse::default_threads())
        .map_err(anyhow::Error::msg)?;

    let mut space = dse::SearchSpace::default();
    if let Some(ls) = args.get_usize_list("l").map_err(anyhow::Error::msg)? {
        space.l = ls;
    }
    if let Some(bs) = args.get_list::<u32>("b").map_err(anyhow::Error::msg)? {
        space.b = bs;
    }
    if let Some(batches) = args.get_usize_list("batch").map_err(anyhow::Error::msg)? {
        space.batch = batches;
    }
    let mut objective = dse::Objective::new(&ds, trials, seed);
    objective.lambda = args.get_f64("lambda", objective.lambda).map_err(anyhow::Error::msg)?;
    // pass-aware tuning (DESIGN.md §13): pin the fabricated die geometry
    // so candidate L beyond the physical width is priced at its
    // rotation-pass cost instead of assuming a die fabricated that wide
    let phys_d = args.get_usize("phys-d", 0).map_err(anyhow::Error::msg)?;
    let phys_l = args.get_usize("phys-l", 0).map_err(anyhow::Error::msg)?;
    if phys_d > 0 || phys_l > 0 {
        anyhow::ensure!(
            phys_d > 0 && phys_l > 0,
            "--phys-d and --phys-l must be given together"
        );
        objective.phys = Some((phys_d, phys_l));
        println!("pass-aware objective: physical die {phys_d}x{phys_l}");
    }

    println!(
        "tuning on {name} (d={}, {} train / {} test): {} rounds x {} candidates, {} threads",
        ds.d(),
        ds.n_train(),
        ds.n_test(),
        rounds,
        space.grid_size(),
        threads
    );
    let explorer = dse::Explorer { space, objective, rounds, threads };
    let result = explorer.run();
    let knee = result.knee.context("empty design space")?;

    let mut table = Table::new(&[
        "sigma_VT (mV)",
        "ratio",
        "b",
        "L",
        "batch",
        "error",
        "pJ/MAC",
        "latency (us)",
        "kcls/s",
        "",
    ]);
    let mut front = result.front.clone();
    front.sort_by(|a, b| a.error.partial_cmp(&b.error).unwrap());
    for e in &front {
        let is_knee = e.point == knee.point;
        table.row(&[
            format!("{:.1}", e.point.sigma_vt * 1e3),
            format!("{:.3}", e.point.ratio),
            format!("{}", e.point.b),
            format!("{}", e.point.l),
            format!("{}", e.point.batch),
            format!("{:.4}", e.error),
            format!("{:.3}", e.energy_pj_per_mac),
            format!("{:.1}", e.latency_s * 1e6),
            format!("{:.1}", e.throughput_cps / 1e3),
            if is_knee { "<- knee".to_string() } else { String::new() },
        ]);
    }
    println!("Pareto front ({} of {} evaluated points):", front.len(), result.evals.len());
    table.print();

    let first = result.regions.first().context("no rounds ran")?;
    let last = result.regions.last().context("no rounds ran")?;
    println!(
        "refinement: sigma_VT region {:.1}-{:.1} mV -> {:.1}-{:.1} mV; \
         cache {} hits / {} misses",
        first.sigma_lo * 1e3,
        first.sigma_hi * 1e3,
        last.sigma_lo * 1e3,
        last.sigma_hi * 1e3,
        result.cache_hits,
        result.cache_misses
    );

    // "pick for me": explicit weights over [error, energy, latency,
    // -throughput], else the knee
    let selected = match args.get_f64_list("weights").map_err(anyhow::Error::msg)? {
        Some(w) => {
            anyhow::ensure!(
                w.len() == 4,
                "--weights wants 4 values (error,energy,latency,throughput)"
            );
            result
                .select(&[w[0], w[1], w[2], w[3]])
                .context("empty front")?
        }
        None => knee,
    };
    println!("selected operating point: {}", selected.point);
    println!("{}", ChipConfig::from_operating_point(&selected.point, ds.d()).summary());
    println!(
        "deploy with Coordinator::start_tuned, or `velm tune --out p.kv` \
         then `velm serve --point p.kv`"
    );

    if let Some(path) = args.get("out") {
        // front sections first, [selected] last: OperatingPoint::from_kv
        // applied to the whole file then yields the selected point
        let mut text = String::new();
        text.push_str("# velm tune result: Pareto front, then the selected point.\n");
        text.push_str("# Parse with OperatingPoint::from_kv (last section wins).\n");
        for (k, e) in front.iter().enumerate() {
            text.push_str(&format!(
                "\n[front.{k}]  # error {:.6}, pJ/MAC {:.4}, latency {:.2} us\n",
                e.error,
                e.energy_pj_per_mac,
                e.latency_s * 1e6
            ));
            text.push_str(&e.point.to_kv());
        }
        text.push_str("\n[selected]\n");
        text.push_str(&selected.point.to_kv());
        std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
        println!("front serialized to {path}");
    }
    Ok(())
}

/// Fleet-health demo: boot a fleet with hot standbys, replay a Fig. 18
/// style temperature ramp (plus optional mismatch aging) into die 0,
/// tick the fleet manager and report detection, recovery and the
/// accuracy before/under/after drift — all without stopping the fleet.
fn cmd_fleet(args: &Args) -> Result<()> {
    let name = args.get_or("dataset", "brightdata");
    let seed = args.get_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let ds = synth::by_name(&name, seed)
        .with_context(|| format!("unknown dataset {name}"))?
        .with_test_subsample(150, seed);
    let chips = args.get_usize("chips", 2).map_err(anyhow::Error::msg)?;
    let standby = args.get_usize("standby", 1).map_err(anyhow::Error::msg)?;
    let ticks = args.get_usize("ticks", 8).map_err(anyhow::Error::msg)? as u64;
    let t_end = args.get_f64("temp", 350.0).map_err(anyhow::Error::msg)?;
    let age_mv = args.get_f64("age-sigma", 0.0).map_err(anyhow::Error::msg)?;

    let mut cfg = chip_cfg_from(args)?;
    cfg.d = ds.d();
    cfg.b = args.get_usize("b", 10).map_err(anyhow::Error::msg)? as u32;
    let mut sys = SystemConfig { n_chips: chips, ..Default::default() };
    sys.standby_chips = standby;
    sys.max_wait = std::time::Duration::from_millis(1);

    println!(
        "fleet demo on {name}: {} active + {} standby dies, drifting die 0 to {t_end} K",
        chips, standby
    );
    let coord = Coordinator::start(&sys, &cfg, &ds.train_x, &ds.train_y, 0.1, 10)?;

    let accuracy = |label: &str| -> Result<f64> {
        let mut correct = 0usize;
        for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
            let resp = coord.classify(x.clone())?;
            if (resp.label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n_test() as f64;
        println!("{label}: {:.1}% over {} requests", acc * 100.0, ds.n_test());
        Ok(acc)
    };

    let pre = accuracy("pre-drift accuracy")?;
    let mut schedule =
        velm::fleet::DriftSchedule::temperature_ramp(Some(0), 1, 3, 310.0, t_end);
    if age_mv > 0.0 {
        schedule = schedule.with(velm::fleet::DriftEvent {
            at_tick: 1,
            die: Some(0),
            vdd: None,
            temp_k: None,
            age_sigma_vt: Some(age_mv / 1e3),
        });
    }
    coord.set_drift_schedule(schedule);
    for t in 0..ticks {
        coord.fleet_tick();
        println!("tick {t}: {}", coord.fleet_status());
    }
    let post = accuracy("post-recovery accuracy")?;

    println!("\nfleet event log:");
    for line in coord.fleet_log() {
        println!("  {line}");
    }
    println!("\n{}", coord.metrics.report());
    println!(
        "accuracy: {:.1}% -> {:.1}% ({}); fleet served throughout",
        pre * 100.0,
        post * 100.0,
        if post + 0.02 >= pre { "recovered" } else { "NOT recovered" }
    );
    coord.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = ChipConfig::default();
    println!("{}", cfg.summary());
    let dir = args.get_or("artifacts", "artifacts");
    let path = std::path::Path::new(&dir);
    if velm::runtime::artifacts_available(path) {
        let store = velm::runtime::ArtifactStore::load(path)?;
        println!("artifacts in {dir}: {}", store.entries.len());
        for meta in store.entries.values() {
            println!("  {} {:?}", meta.name, meta.arg_shapes);
        }
    } else {
        println!("artifacts not built in {dir} (run `make artifacts`)");
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = args.get_or("root", env!("CARGO_MANIFEST_DIR"));
    let report = velm::analysis::lint_tree(std::path::Path::new(&root))?;
    println!(
        "velm lint: {} files, {} relaxed sites ({} justified)",
        report.files_scanned, report.relaxed_sites, report.justified_sites
    );
    if report.is_clean() {
        println!("clean");
        return Ok(());
    }
    for finding in &report.findings {
        eprintln!("{finding}");
    }
    bail!("{} lint finding(s)", report.findings.len());
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    match args.command.as_deref() {
        Some("characterize") => cmd_characterize(&args),
        Some("train") => cmd_classify(&args, true),
        Some("classify") => cmd_classify(&args, false),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("bench") => cmd_bench(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("tune") => cmd_tune(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("info") => cmd_info(&args),
        Some("lint") => cmd_lint(&args),
        Some("help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => {
            eprint!("{}", usage());
            bail!("unknown command '{other}'");
        }
    }
}
