//! Minimal property-testing harness (the offline vendor set has no
//! proptest). A property is a closure over a seeded [`Prng`]; the runner
//! executes many cases and reports the failing seed so a failure is
//! reproducible with `check_one`.

pub mod model;

use crate::util::prng::Prng;

/// Run `cases` random cases of `prop`; panics with the failing seed on
/// the first counterexample. `prop` returns `Err(reason)` to fail.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Prng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with testing::check_one(\"{name}\", {seed:#x}, prop)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Prng) -> Result<(), String>,
{
    let mut rng = Prng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// Helper: assert closeness with context.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Helper: assert a predicate with context.
pub fn ensure(cond: bool, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use crate::sync::{AtomicU64, Ordering};
        let count = AtomicU64::new(0);
        check("count", 25, |_rng| {
            count.fetch_add(1, Ordering::Relaxed);
            Ok(())
        });
        assert_eq!(count.load(Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let x = rng.f64();
            ensure(x < 0.5, "x too big") // will fail quickly
        });
    }

    #[test]
    fn helpers() {
        assert!(close(1.0, 1.0001, 1e-3, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-3, "x").is_err());
        assert!(ensure(true, "ok").is_ok());
        assert!(ensure(false, "bad").is_err());
    }
}
