//! Loom-style bounded-preemption model checker for the lock-free
//! serving core (DESIGN.md §18). No external dependencies: the
//! "modeled" atomics and mutexes in [`sync`] wrap their std
//! counterparts and announce every operation to a cooperative
//! scheduler, which serializes the logical threads of a scenario and
//! enumerates their interleavings by depth-first search.
//!
//! How it works:
//!
//! - A scenario (closure over [`Threads`]) builds fresh shared state
//!   and spawns 2..=4 logical thread bodies; it is re-run once per
//!   explored schedule.
//! - Each body runs on a real OS thread, but a token-passing scheduler
//!   (mutex + condvar) lets exactly one run at a time. Before every
//!   modeled atomic/mutex operation the running thread yields; the
//!   scheduler then picks which thread runs next.
//! - The first run follows a default schedule (keep running the
//!   current thread). Every decision point records the set of enabled
//!   threads; the search then backtracks, forcing a different choice at
//!   one decision and replaying the prefix — classic stateless model
//!   checking with a bounded number of *preemptions* (switching away
//!   from a thread that could have continued). Context switches at
//!   thread start, block, or exit are free, so small bounds still
//!   explore every blocking pattern.
//! - A modeled `Mutex::lock` that would block parks the thread until
//!   some guard drops; if every live thread is parked the run is
//!   reported as a deadlock. Runaway schedules trip `max_steps`
//!   (livelock), and a forced choice that is no longer enabled on
//!   replay is reported as nondeterminism in the scenario itself.
//!
//! Violations are assertion panics inside bodies or `after` checks,
//! plus deadlock/livelock detected by the scheduler; [`Model::search`]
//! returns the first failing schedule, [`Model::check`] panics with it.

use std::any::Any;
use std::cell::RefCell;
use std::sync::{Arc, Condvar, Mutex};

/// Pseudo thread-id for the driver: never enabled, never scheduled.
const MAIN: usize = usize::MAX;

/// Panic payload used to unwind worker threads when a run is torn down
/// early (deadlock, livelock, or a sibling thread's assertion failure).
const ABORT_MSG: &str = "velm-model: schedule aborted";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    /// Runnable: the scheduler may pick this thread.
    Ready,
    /// Parked on a modeled mutex; re-enabled when any guard drops.
    Blocked,
    /// Body returned (or unwound).
    Done,
}

/// One scheduling decision: who yielded, who was chosen, and who else
/// could have been chosen (the DFS branches over `enabled`).
#[derive(Clone, Debug)]
struct Choice {
    yielder: usize,
    chosen: usize,
    enabled: Vec<usize>,
    preemptive: bool,
}

struct EngState {
    status: Vec<Status>,
    registered: usize,
    /// Thread currently holding the run token (`MAIN` = driver).
    active: usize,
    /// Next decision index (== trace.len()).
    step: usize,
    forced: Vec<usize>,
    trace: Vec<Choice>,
    failure: Option<String>,
    aborting: bool,
    max_steps: usize,
}

struct Engine {
    state: Mutex<EngState>,
    cv: Condvar,
}

impl Engine {
    fn new(n: usize, forced: Vec<usize>, max_steps: usize) -> Self {
        Engine {
            state: Mutex::new(EngState {
                status: vec![Status::Ready; n],
                registered: 0,
                active: MAIN,
                step: 0,
                forced,
                trace: Vec::new(),
                failure: None,
                aborting: false,
                max_steps,
            }),
            cv: Condvar::new(),
        }
    }

    /// Called by each worker before its body: signs in, then parks
    /// until the scheduler hands it the token for the first time.
    fn register_and_wait(&self, me: usize) {
        let mut st = self.state.lock().unwrap();
        st.registered += 1;
        self.cv.notify_all();
        loop {
            if st.aborting {
                drop(st);
                panic!("{ABORT_MSG}");
            }
            if st.active == me {
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Decision point before a modeled operation. `blocked` marks a
    /// mutex acquire that failed: the thread parks and MUST NOT be
    /// rescheduled until some guard drops re-enables it.
    fn yield_at(&self, me: usize, blocked: bool) {
        let mut st = self.state.lock().unwrap();
        if st.aborting {
            drop(st);
            panic!("{ABORT_MSG}");
        }
        st.status[me] = if blocked { Status::Blocked } else { Status::Ready };
        self.pick_next(&mut st, me);
        loop {
            if st.aborting {
                drop(st);
                panic!("{ABORT_MSG}");
            }
            if st.active == me {
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// A modeled mutex guard dropped: every parked thread may retry.
    /// Not a decision point — the release itself is not observable
    /// until the releasing thread's next yield.
    fn unblocked(&self) {
        let mut st = self.state.lock().unwrap();
        for s in &mut st.status {
            if *s == Status::Blocked {
                *s = Status::Ready;
            }
        }
    }

    /// Worker body finished (normally or by panic).
    fn finish(&self, me: usize, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.status[me] = Status::Done;
        if panicked {
            // An assertion failure inside a body is a violation: tear
            // the rest of the run down; the driver reads the payload
            // off the join handle.
            st.aborting = true;
        } else if !st.aborting {
            self.pick_next(&mut st, me);
        }
        self.cv.notify_all();
    }

    /// Pick who runs next. Follows the forced prefix while it lasts,
    /// then defaults to "keep running the yielder" (no preemption).
    fn pick_next(&self, st: &mut EngState, yielder: usize) {
        if st.step >= st.max_steps {
            st.failure = Some(format!(
                "livelock: schedule exceeded {} decisions",
                st.max_steps
            ));
            st.aborting = true;
            self.cv.notify_all();
            return;
        }
        let enabled: Vec<usize> = (0..st.status.len())
            .filter(|&i| st.status[i] == Status::Ready)
            .collect();
        if enabled.is_empty() {
            if st.status.iter().all(|&s| s == Status::Done) {
                st.active = MAIN;
            } else {
                st.failure = Some(format!(
                    "deadlock: every live thread is parked on a mutex (status {:?})",
                    st.status
                ));
                st.aborting = true;
            }
            self.cv.notify_all();
            return;
        }
        let chosen = if st.step < st.forced.len() {
            let c = st.forced[st.step];
            if !enabled.contains(&c) {
                st.failure = Some(format!(
                    "nondeterministic scenario: forced thread {c} not enabled at step {} (enabled {:?})",
                    st.step, enabled
                ));
                st.aborting = true;
                self.cv.notify_all();
                return;
            }
            c
        } else if enabled.contains(&yielder) {
            yielder
        } else {
            enabled[0]
        };
        let preemptive = chosen != yielder && enabled.contains(&yielder);
        st.trace.push(Choice {
            yielder,
            chosen,
            enabled,
            preemptive,
        });
        st.step += 1;
        st.active = chosen;
        self.cv.notify_all();
    }
}

struct Ctx {
    engine: Arc<Engine>,
    id: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn current() -> Option<(Arc<Engine>, usize)> {
    CTX.with(|c| c.borrow().as_ref().map(|x| (Arc::clone(&x.engine), x.id)))
}

/// True when the calling thread belongs to an active model run.
pub(crate) fn in_model() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Decision point before a modeled operation (no-op outside a run).
pub(crate) fn yield_point() {
    if let Some((engine, id)) = current() {
        engine.yield_at(id, false);
    }
}

/// Park until a modeled mutex guard drops (no-op outside a run).
pub(crate) fn yield_blocked() {
    if let Some((engine, id)) = current() {
        engine.yield_at(id, true);
    }
}

/// A modeled mutex guard dropped (no-op outside a run).
pub(crate) fn unlock_hint() {
    if let Some((engine, _)) = current() {
        engine.unblocked();
    }
}

/// Modeled atomics and mutexes. `crate::sync` re-exports these under
/// `--features model`; user code never names this module directly.
pub mod sync {
    use super::{in_model, unlock_hint, yield_blocked, yield_point};
    use std::sync::atomic::Ordering;
    use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};

    macro_rules! modeled_atomic {
        ($name:ident, $std:ty, $ty:ty) => {
            /// Modeled atomic: delegates to std, yielding to the model
            /// scheduler before every operation.
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $ty) -> Self {
                    Self {
                        inner: <$std>::new(v),
                    }
                }

                pub fn load(&self, order: Ordering) -> $ty {
                    yield_point();
                    self.inner.load(order)
                }

                pub fn store(&self, v: $ty, order: Ordering) {
                    yield_point();
                    self.inner.store(v, order);
                }
            }

            impl Default for $name {
                fn default() -> Self {
                    Self::new(<$ty>::default())
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    std::fmt::Debug::fmt(&self.inner, f)
                }
            }
        };
    }

    macro_rules! modeled_fetch_ops {
        ($name:ident, $ty:ty) => {
            impl $name {
                pub fn fetch_add(&self, v: $ty, order: Ordering) -> $ty {
                    yield_point();
                    self.inner.fetch_add(v, order)
                }

                pub fn fetch_sub(&self, v: $ty, order: Ordering) -> $ty {
                    yield_point();
                    self.inner.fetch_sub(v, order)
                }
            }
        };
    }

    modeled_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    modeled_atomic!(AtomicU8, std::sync::atomic::AtomicU8, u8);
    modeled_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    modeled_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    modeled_fetch_ops!(AtomicU64, u64);
    modeled_fetch_ops!(AtomicUsize, usize);

    /// Modeled mutex. Outside a model run it is a plain delegating
    /// wrapper (including blocking `lock`). Inside a run, `lock` spins
    /// on `try_lock` and parks the logical thread between attempts, so
    /// the scheduler observes blocking instead of deadlocking the
    /// token-passing protocol; acquisition yields once more while
    /// holding the guard so other threads can observe contention.
    /// Poison passes through from the inner std mutex unchanged.
    #[derive(Default)]
    pub struct Mutex<T> {
        inner: std::sync::Mutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        inner: Option<std::sync::MutexGuard<'a, T>>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Self {
                inner: std::sync::Mutex::new(t),
            }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if !in_model() {
                return match self.inner.lock() {
                    Ok(g) => Ok(MutexGuard::wrap(g)),
                    Err(p) => Err(PoisonError::new(MutexGuard::wrap(p.into_inner()))),
                };
            }
            yield_point();
            loop {
                match self.inner.try_lock() {
                    Ok(g) => {
                        yield_point();
                        return Ok(MutexGuard::wrap(g));
                    }
                    Err(TryLockError::Poisoned(p)) => {
                        yield_point();
                        return Err(PoisonError::new(MutexGuard::wrap(p.into_inner())));
                    }
                    Err(TryLockError::WouldBlock) => yield_blocked(),
                }
            }
        }

        pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
            yield_point();
            match self.inner.try_lock() {
                Ok(g) => {
                    yield_point();
                    Ok(MutexGuard::wrap(g))
                }
                Err(TryLockError::Poisoned(p)) => Err(TryLockError::Poisoned(PoisonError::new(
                    MutexGuard::wrap(p.into_inner()),
                ))),
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }

        pub fn into_inner(self) -> LockResult<T> {
            match self.inner.into_inner() {
                Ok(t) => Ok(t),
                Err(p) => Err(PoisonError::new(p.into_inner())),
            }
        }
    }

    impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            std::fmt::Debug::fmt(&self.inner, f)
        }
    }

    impl<'a, T> MutexGuard<'a, T> {
        fn wrap(g: std::sync::MutexGuard<'a, T>) -> Self {
            Self { inner: Some(g) }
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;

        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard alive")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard alive")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // Release the inner guard first, then let parked threads
            // retry; the order matters because the hint does not yield
            // and the retry cannot run before this thread's next yield.
            self.inner = None;
            unlock_hint();
        }
    }
}

struct FinishGuard {
    engine: Arc<Engine>,
    id: usize,
}

impl Drop for FinishGuard {
    fn drop(&mut self) {
        self.engine.finish(self.id, std::thread::panicking());
        CTX.with(|c| *c.borrow_mut() = None);
    }
}

type Body = Box<dyn FnOnce() + Send>;
type AfterCheck = Box<dyn FnOnce()>;

/// Scenario builder handed to the closure passed to `Model::check`.
#[derive(Default)]
pub struct Threads {
    bodies: Vec<Body>,
    afters: Vec<AfterCheck>,
}

impl Threads {
    /// Add a logical thread. Bodies run under the model scheduler:
    /// every `crate::sync` operation they perform is a decision point.
    pub fn spawn(&mut self, body: impl FnOnce() + Send + 'static) {
        self.bodies.push(Box::new(body));
    }

    /// Add a quiescence check: runs on the driver after every schedule
    /// once all bodies have finished. Panics here are violations.
    pub fn after(&mut self, check: impl FnOnce() + 'static) {
        self.afters.push(Box::new(check));
    }
}

/// Search bounds. `max_preemptions` is the classic CHESS-style bound:
/// most concurrency bugs need only 1-2 preemptions, and the schedule
/// count grows combinatorially with the bound, so small values buy
/// exhaustiveness within a practical budget.
#[derive(Clone, Copy, Debug)]
pub struct Model {
    pub max_preemptions: usize,
    pub max_schedules: usize,
    pub max_steps: usize,
}

impl Model {
    /// A model with the given preemption bound and default budgets.
    pub fn bounded(max_preemptions: usize) -> Self {
        Model {
            max_preemptions,
            max_schedules: 1_000_000,
            max_steps: 100_000,
        }
    }
}

/// The first failing schedule found by `Model::search`.
#[derive(Debug)]
pub struct Violation {
    /// Thread ids in scheduling order — replays the failure.
    pub schedule: Vec<usize>,
    pub message: String,
    pub schedules_run: usize,
}

/// Outcome of an exhaustive search that found no violation.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub schedules: usize,
    pub max_depth: usize,
    /// False when `max_schedules` stopped the search early; an
    /// incomplete search proves nothing and `check` treats it as a
    /// failure.
    pub complete: bool,
}

struct RunOutcome {
    trace: Vec<Choice>,
    failure: Option<String>,
}

impl Model {
    /// Explore every schedule of `scenario` within the preemption
    /// bound. Returns the first violation, or search statistics when
    /// every explored schedule passed.
    pub fn search<F>(&self, mut scenario: F) -> Result<Stats, Violation>
    where
        F: FnMut(&mut Threads),
    {
        let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
        let mut stats = Stats {
            schedules: 0,
            max_depth: 0,
            complete: true,
        };
        while let Some(forced) = stack.pop() {
            if stats.schedules >= self.max_schedules {
                stats.complete = false;
                break;
            }
            stats.schedules += 1;
            let out = self.run_once(&mut scenario, &forced);
            stats.max_depth = stats.max_depth.max(out.trace.len());
            if let Some(message) = out.failure {
                return Err(Violation {
                    schedule: out.trace.iter().map(|c| c.chosen).collect(),
                    message,
                    schedules_run: stats.schedules,
                });
            }
            // Branch on every decision past the forced prefix (earlier
            // decisions were branched when first discovered). The
            // default policy never preempts, so the cumulative count
            // only reflects the forced prefix and stays within bound.
            let mut preempts = 0usize;
            for (i, c) in out.trace.iter().enumerate() {
                if i >= forced.len() {
                    for &alt in c.enabled.iter().rev() {
                        if alt == c.chosen {
                            continue;
                        }
                        let alt_preempts = c.enabled.contains(&c.yielder) && alt != c.yielder;
                        if preempts + usize::from(alt_preempts) > self.max_preemptions {
                            continue;
                        }
                        let mut next: Vec<usize> =
                            out.trace[..i].iter().map(|x| x.chosen).collect();
                        next.push(alt);
                        stack.push(next);
                    }
                }
                preempts += usize::from(c.preemptive);
            }
        }
        Ok(stats)
    }

    /// Like `search`, but panics (with the failing schedule) on a
    /// violation or an incomplete search.
    pub fn check<F>(&self, name: &str, scenario: F) -> Stats
    where
        F: FnMut(&mut Threads),
    {
        match self.search(scenario) {
            Ok(stats) => {
                assert!(
                    stats.complete,
                    "model '{name}': search hit max_schedules ({}) before completing",
                    self.max_schedules
                );
                stats
            }
            Err(v) => panic!(
                "model '{name}': {} (schedule {:?}, found after {} schedules)",
                v.message, v.schedule, v.schedules_run
            ),
        }
    }

    fn run_once<F>(&self, scenario: &mut F, forced: &[usize]) -> RunOutcome
    where
        F: FnMut(&mut Threads),
    {
        let mut threads = Threads::default();
        scenario(&mut threads);
        let Threads { bodies, afters } = threads;
        let n = bodies.len();
        let engine = Arc::new(Engine::new(n, forced.to_vec(), self.max_steps));
        let mut handles = Vec::with_capacity(n);
        for (id, body) in bodies.into_iter().enumerate() {
            let eng = Arc::clone(&engine);
            let handle = std::thread::Builder::new()
                .name(format!("velm-model-{id}"))
                .spawn(move || {
                    CTX.with(|c| {
                        *c.borrow_mut() = Some(Ctx {
                            engine: Arc::clone(&eng),
                            id,
                        });
                    });
                    let _finish = FinishGuard {
                        engine: Arc::clone(&eng),
                        id,
                    };
                    eng.register_and_wait(id);
                    body();
                })
                .expect("spawn model thread");
            handles.push(handle);
        }
        if n > 0 {
            let mut st = engine.state.lock().unwrap();
            while st.registered < n {
                st = engine.cv.wait(st).unwrap();
            }
            engine.pick_next(&mut st, MAIN);
        }
        let mut body_panic: Option<String> = None;
        for handle in handles {
            if let Err(payload) = handle.join() {
                let msg = payload_message(payload);
                if msg != ABORT_MSG && body_panic.is_none() {
                    body_panic = Some(msg);
                }
            }
        }
        let st = engine.state.lock().unwrap();
        let mut failure = st.failure.clone().or(body_panic);
        let trace = st.trace.clone();
        drop(st);
        if failure.is_none() {
            for check in afters {
                if let Err(payload) =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(check))
                {
                    failure = Some(format!("after-check: {}", payload_message(payload)));
                    break;
                }
            }
        }
        RunOutcome { trace, failure }
    }
}

fn payload_message(payload: Box<dyn Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Every map of `items` positions onto `classes` values, as vectors of
/// class indices — `classes^items` entries. Backs the exhaustive
/// input-space sweeps in `tests/invariants.rs` (tenant-over-row
/// assignments, governor signal sequences).
pub fn assignments(items: u32, classes: usize) -> Vec<Vec<usize>> {
    let total = classes.pow(items);
    let mut out = Vec::with_capacity(total);
    for code in 0..total {
        let mut rest = code;
        let mut v = Vec::with_capacity(items as usize);
        for _ in 0..items {
            v.push(rest % classes);
            rest /= classes;
        }
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::sync::{AtomicU64, Mutex};
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    #[cfg_attr(miri, ignore)] // spawns thousands of short-lived threads
    fn atomic_increments_are_exhaustively_explored() {
        let model = Model::bounded(2);
        let stats = model.check("fetch_add", |t| {
            let count = Arc::new(AtomicU64::new(0));
            for _ in 0..2 {
                let c = Arc::clone(&count);
                t.spawn(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            let c = Arc::clone(&count);
            t.after(move || assert_eq!(c.load(Ordering::Relaxed), 2));
        });
        assert!(stats.schedules > 1, "must explore more than one schedule");
        assert!(stats.complete);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn lost_update_is_found() {
        // Non-atomic increment (load; store v+1): one preemption
        // between the two halves loses an update.
        let model = Model::bounded(1);
        let result = model.search(|t| {
            let count = Arc::new(AtomicU64::new(0));
            for _ in 0..2 {
                let c = Arc::clone(&count);
                t.spawn(move || {
                    let v = c.load(Ordering::Relaxed);
                    c.store(v + 1, Ordering::Relaxed);
                });
            }
            let c = Arc::clone(&count);
            t.after(move || assert_eq!(c.load(Ordering::Relaxed), 2, "lost update"));
        });
        let violation = result.expect_err("checker must find the lost update");
        assert!(
            violation.message.contains("lost update"),
            "unexpected failure: {}",
            violation.message
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn mutex_contention_is_serialized() {
        let model = Model::bounded(2);
        let stats = model.check("mutex", |t| {
            let cell = Arc::new(Mutex::new(0u64));
            for _ in 0..2 {
                let m = Arc::clone(&cell);
                t.spawn(move || {
                    let mut g = m.lock().unwrap();
                    *g += 1;
                });
            }
            let m = Arc::clone(&cell);
            t.after(move || assert_eq!(*m.lock().unwrap(), 2));
        });
        assert!(stats.complete);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn lock_order_inversion_deadlocks() {
        let model = Model::bounded(1);
        let result = model.search(|t| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
            t.spawn(move || {
                let _ga = a1.lock().unwrap();
                let _gb = b1.lock().unwrap();
            });
            t.spawn(move || {
                let _gb = b.lock().unwrap();
                let _ga = a.lock().unwrap();
            });
        });
        let violation = result.expect_err("checker must find the ABBA deadlock");
        assert!(
            violation.message.contains("deadlock"),
            "unexpected failure: {}",
            violation.message
        );
    }

    #[test]
    fn assignments_enumerates_the_full_space() {
        let all = assignments(3, 2);
        assert_eq!(all.len(), 8);
        assert!(all.contains(&vec![0, 0, 0]));
        assert!(all.contains(&vec![1, 1, 1]));
        assert!(all.contains(&vec![1, 0, 1]));
        let dedup: std::collections::BTreeSet<_> = all.iter().cloned().collect();
        assert_eq!(dedup.len(), 8, "no duplicates");
    }
}
