//! Software floating-point ELM baseline (Huang et al. [12]): uniform
//! random input weights + bias, sigmoid activation, L = 1000 hidden
//! neurons. This is the "Software" column of Table II that the chip is
//! compared against.

use crate::elm::train::HiddenLayer;
use crate::util::mat::Mat;
use crate::util::prng::Prng;

/// The canonical software ELM hidden layer.
pub struct SoftElm {
    /// Input weights d x L, U(-1, 1).
    pub w: Mat,
    /// Biases, U(-1, 1).
    pub b: Vec<f64>,
    /// Input rescale applied before projection. The classic sinc setup
    /// feeds raw x in [-10, 10]; our datasets normalise features to
    /// [-1, 1] for the chip, so regression baselines set this to the
    /// de-normalisation factor to recover [12]'s configuration.
    pub input_scale: f64,
}

impl SoftElm {
    pub fn new(d: usize, l: usize, seed: u64) -> Self {
        Self::with_scale(d, l, 1.0, seed)
    }

    pub fn with_scale(d: usize, l: usize, input_scale: f64, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let w = Mat::random_uniform(d, l, -1.0, 1.0, &mut rng);
        let b = (0..l).map(|_| rng.range(-1.0, 1.0)).collect();
        SoftElm { w, b, input_scale }
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl HiddenLayer for SoftElm {
    fn input_dim(&self) -> usize {
        self.w.rows
    }

    fn hidden_dim(&self) -> usize {
        self.w.cols
    }

    fn transform(&mut self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.w.rows);
        let l = self.w.cols;
        let mut z = self.b.clone();
        for (i, &xi) in x.iter().enumerate() {
            let xi = xi * self.input_scale;
            if xi == 0.0 {
                continue;
            }
            let row = self.w.row(i);
            for j in 0..l {
                z[j] += xi * row[j];
            }
        }
        z.iter().map(|&v| sigmoid(v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::train::{assemble_h, misclassification, predict, solve_head};

    #[test]
    fn sigmoid_shape() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(10.0) > 0.999);
        assert!(sigmoid(-10.0) < 0.001);
    }

    #[test]
    fn transform_bounded() {
        let mut elm = SoftElm::new(5, 50, 1);
        let h = elm.transform(&[0.5, -0.5, 0.1, 0.9, -1.0]);
        assert_eq!(h.len(), 50);
        assert!(h.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SoftElm::new(3, 10, 7);
        let mut b = SoftElm::new(3, 10, 7);
        assert_eq!(a.transform(&[0.1, 0.2, 0.3]), b.transform(&[0.1, 0.2, 0.3]));
    }

    #[test]
    fn baseline_learns_xor_like_task() {
        let mut rng = Prng::new(11);
        let xs: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)])
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| if x[0] * x[1] > 0.0 { 1.0 } else { -1.0 })
            .collect();
        let mut elm = SoftElm::new(2, 200, 12);
        let h = assemble_h(&mut elm, &xs);
        let head = solve_head(&h, &ys, 1e-4).unwrap();
        let err = misclassification(&predict(&h, &head), &ys);
        assert!(err < 0.08, "XOR train error {err}");
    }
}
