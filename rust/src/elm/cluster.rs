//! Unsupervised use of the chip (paper conclusion + refs [33], [34]):
//! the mismatch array as a random-projection dimension reducer in front
//! of k-means clustering. The saturating nonlinearity is bypassed by
//! operating the neuron in its linear region (Transfer::Linear and
//! currents far below saturation), exactly as the conclusion suggests
//! ("if the nonlinear saturation in the neuron is not applied").

use crate::util::prng::Prng;

/// Plain Lloyd's k-means on dense points.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub centroids: Vec<Vec<f64>>,
    pub iterations: usize,
    pub inertia: f64,
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl KMeans {
    /// Fit k clusters; k-means++ style seeding from `rng`.
    pub fn fit(points: &[Vec<f64>], k: usize, max_iter: usize, rng: &mut Prng) -> Self {
        assert!(k >= 1 && points.len() >= k);
        // k-means++ seeding
        let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
        centroids.push(points[rng.usize(points.len())].clone());
        while centroids.len() < k {
            let d2: Vec<f64> = points
                .iter()
                .map(|p| {
                    centroids
                        .iter()
                        .map(|c| dist2(p, c))
                        .fold(f64::MAX, f64::min)
                })
                .collect();
            let total: f64 = d2.iter().sum();
            let mut pick = rng.f64() * total;
            let mut idx = 0;
            for (i, &w) in d2.iter().enumerate() {
                pick -= w;
                if pick <= 0.0 {
                    idx = i;
                    break;
                }
            }
            centroids.push(points[idx].clone());
        }
        // Lloyd iterations
        let mut assign = vec![0usize; points.len()];
        let mut iterations = 0;
        for it in 0..max_iter {
            iterations = it + 1;
            let mut changed = false;
            for (i, p) in points.iter().enumerate() {
                let (best, _) = centroids
                    .iter()
                    .enumerate()
                    .map(|(c, cen)| (c, dist2(p, cen)))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap();
                if assign[i] != best {
                    assign[i] = best;
                    changed = true;
                }
            }
            let dim = points[0].len();
            let mut sums = vec![vec![0.0; dim]; k];
            let mut counts = vec![0usize; k];
            for (i, p) in points.iter().enumerate() {
                counts[assign[i]] += 1;
                for (s, &v) in sums[assign[i]].iter_mut().zip(p) {
                    *s += v;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for s in &mut sums[c] {
                        *s /= counts[c] as f64;
                    }
                    centroids[c] = sums[c].clone();
                }
            }
            if !changed {
                break;
            }
        }
        let inertia = points
            .iter()
            .enumerate()
            .map(|(i, p)| dist2(p, &centroids[assign[i]]))
            .sum();
        KMeans { centroids, iterations, inertia }
    }

    pub fn assign(&self, p: &[f64]) -> usize {
        self.centroids
            .iter()
            .enumerate()
            .map(|(c, cen)| (c, dist2(p, cen)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0
    }
}

/// Clustering accuracy against ground-truth labels under the best
/// cluster->label matching (greedy; fine for small k).
pub fn clustering_accuracy(assignments: &[usize], labels: &[usize], k: usize) -> f64 {
    assert_eq!(assignments.len(), labels.len());
    let mut counts = vec![vec![0usize; k]; k];
    for (&a, &l) in assignments.iter().zip(labels) {
        counts[a][l] += 1;
    }
    // greedy matching
    let mut used = vec![false; k];
    let mut correct = 0usize;
    for a in 0..k {
        let mut best = (0usize, 0usize);
        for l in 0..k {
            if !used[l] && counts[a][l] >= best.1 {
                best = (l, counts[a][l]);
            }
        }
        used[best.0] = true;
        correct += best.1;
    }
    correct as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(seed: u64, n_per: usize) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Prng::new(seed);
        let centers = [[0.7, 0.7], [-0.7, 0.0], [0.3, -0.8]];
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for (c, cen) in centers.iter().enumerate() {
            for _ in 0..n_per {
                pts.push(vec![
                    cen[0] + rng.normal(0.0, 0.1),
                    cen[1] + rng.normal(0.0, 0.1),
                ]);
                labels.push(c);
            }
        }
        (pts, labels)
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let (pts, labels) = blobs(1, 60);
        let mut rng = Prng::new(2);
        let km = KMeans::fit(&pts, 3, 50, &mut rng);
        let assign: Vec<usize> = pts.iter().map(|p| km.assign(p)).collect();
        let acc = clustering_accuracy(&assign, &labels, 3);
        assert!(acc > 0.95, "accuracy {acc}");
        assert!(km.iterations < 50);
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let (pts, _) = blobs(3, 40);
        let mut rng = Prng::new(4);
        let k1 = KMeans::fit(&pts, 1, 30, &mut rng);
        let mut rng = Prng::new(4);
        let k3 = KMeans::fit(&pts, 3, 30, &mut rng);
        assert!(k3.inertia < k1.inertia);
    }

    #[test]
    fn accuracy_matching_is_permutation_invariant() {
        let labels = vec![0, 0, 1, 1, 2, 2];
        let perfect_permuted = vec![2, 2, 0, 0, 1, 1];
        assert!((clustering_accuracy(&perfect_permuted, &labels, 3) - 1.0).abs() < 1e-12);
    }
}
