//! Multi-class ELM by one-vs-all output weights (Section II: "the method
//! can be easily extended to multiple outputs by considering each output
//! one by one" [21]) — the paper's stated next step is multi-class image
//! data (MNIST) in the conclusion.

use crate::elm::secondstage::QuantBeta;
use crate::elm::train::HiddenLayer;
use crate::util::mat::{ridge_solve, Mat};

/// One-vs-all trained head: beta is L x C, column c scores class c.
#[derive(Clone, Debug)]
pub struct MultiHead {
    pub beta: Mat,
    pub classes: usize,
    pub lambda: f64,
}

/// Quantised one-vs-all head for the deployed fixed-point second stage.
#[derive(Clone, Debug)]
pub struct QuantMultiHead {
    pub cols: Vec<QuantBeta>,
}

impl MultiHead {
    /// Train on hidden matrix H (N x L) with integer class labels
    /// `0..classes`. Targets are +1 for the class, -1 for the rest.
    pub fn train(h: &Mat, labels: &[usize], classes: usize, lambda: f64) -> Result<Self, String> {
        assert_eq!(h.rows, labels.len());
        assert!(classes >= 2);
        if let Some(&bad) = labels.iter().find(|&&c| c >= classes) {
            return Err(format!("label {bad} out of range for {classes} classes"));
        }
        let t = Mat::from_fn(h.rows, classes, |i, c| {
            if labels[i] == c {
                1.0
            } else {
                -1.0
            }
        });
        let beta = ridge_solve(h, &t, lambda)?;
        Ok(MultiHead { beta, classes, lambda })
    }

    /// Class scores for one hidden vector.
    pub fn scores(&self, h: &[f64]) -> Vec<f64> {
        assert_eq!(h.len(), self.beta.rows);
        (0..self.classes)
            .map(|c| (0..h.len()).map(|j| h[j] * self.beta.get(j, c)).sum())
            .collect()
    }

    /// Argmax class prediction.
    pub fn predict(&self, h: &[f64]) -> usize {
        let s = self.scores(h);
        s.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap()
    }

    /// Quantise each column independently (each output has its own
    /// digital MAC in hardware).
    pub fn quantize(&self, bits: u32) -> QuantMultiHead {
        let cols = (0..self.classes)
            .map(|c| QuantBeta::quantize(&self.beta.col(c), bits))
            .collect();
        QuantMultiHead { cols }
    }
}

impl QuantMultiHead {
    /// Fixed-point argmax over counter outputs.
    pub fn predict(&self, h: &[u32]) -> usize {
        let mut best = (0usize, f64::MIN);
        for (c, q) in self.cols.iter().enumerate() {
            let acc: i64 = h
                .iter()
                .zip(&q.codes)
                .map(|(&hj, &bj)| hj as i64 * bj as i64)
                .sum();
            let s = acc as f64 * q.scale;
            if s > best.1 {
                best = (c, s);
            }
        }
        best.0
    }
}

/// Train a multi-class model through any hidden layer.
pub fn train_multiclass<T: HiddenLayer + ?Sized>(
    layer: &mut T,
    xs: &[Vec<f64>],
    labels: &[usize],
    classes: usize,
    lambda: f64,
) -> Result<(MultiHead, Mat), String> {
    let h = crate::elm::train::assemble_h(layer, xs);
    let head = MultiHead::train(&h, labels, classes, lambda)?;
    Ok((head, h))
}

/// Multi-class error rate through a hidden layer (float head).
pub fn eval_multiclass<T: HiddenLayer + ?Sized>(
    layer: &mut T,
    head: &MultiHead,
    xs: &[Vec<f64>],
    labels: &[usize],
) -> f64 {
    let mut wrong = 0usize;
    for (x, &y) in xs.iter().zip(labels) {
        if head.predict(&layer.transform(x)) != y {
            wrong += 1;
        }
    }
    wrong as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    struct Rbf {
        centers: Vec<Vec<f64>>,
    }
    impl HiddenLayer for Rbf {
        fn input_dim(&self) -> usize {
            self.centers[0].len()
        }
        fn hidden_dim(&self) -> usize {
            self.centers.len()
        }
        fn transform(&mut self, x: &[f64]) -> Vec<f64> {
            self.centers
                .iter()
                .map(|c| {
                    let d2: f64 = c.iter().zip(x).map(|(a, b)| (a - b) * (a - b)).sum();
                    (-4.0 * d2).exp()
                })
                .collect()
        }
    }

    fn three_blobs(seed: u64, n: usize) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Prng::new(seed);
        let centers = [[0.6, 0.6], [-0.6, 0.6], [0.0, -0.6]];
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let c = rng.usize(3);
            xs.push(vec![
                (centers[c][0] + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0),
                (centers[c][1] + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0),
            ]);
            ys.push(c);
        }
        (xs, ys)
    }

    fn rbf_layer(seed: u64, l: usize) -> Rbf {
        let mut rng = Prng::new(seed);
        Rbf {
            centers: (0..l)
                .map(|_| vec![rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)])
                .collect(),
        }
    }

    #[test]
    fn learns_three_classes() {
        let (xs, ys) = three_blobs(1, 300);
        let mut layer = rbf_layer(2, 60);
        let (head, h) = train_multiclass(&mut layer, &xs, &ys, 3, 1e-3).unwrap();
        // train accuracy via the assembled H
        let mut wrong = 0;
        for i in 0..xs.len() {
            if head.predict(h.row(i)) != ys[i] {
                wrong += 1;
            }
        }
        assert!(wrong < 15, "train wrong {wrong}/300");
        let (xt, yt) = three_blobs(3, 150);
        let err = eval_multiclass(&mut layer, &head, &xt, &yt);
        assert!(err < 0.1, "test err {err}");
    }

    #[test]
    fn quantized_head_tracks_float() {
        let (xs, ys) = three_blobs(4, 200);
        let mut layer = rbf_layer(5, 50);
        let (head, _) = train_multiclass(&mut layer, &xs, &ys, 3, 1e-3).unwrap();
        let q = head.quantize(10);
        let mut disagree = 0;
        for x in &xs {
            let h = layer.transform(x);
            let hf = head.predict(&h);
            // counter-style integerisation of the activation
            let hu: Vec<u32> = h.iter().map(|&v| (v * 1000.0) as u32).collect();
            let hq = q.predict(&hu);
            if hf != hq {
                disagree += 1;
            }
        }
        assert!(disagree < 20, "quantised head disagrees on {disagree}/200");
    }

    #[test]
    fn rejects_bad_labels() {
        let h = Mat::from_fn(4, 3, |i, j| (i + j) as f64);
        assert!(MultiHead::train(&h, &[0, 1, 2, 3], 3, 0.1).is_err());
    }

    #[test]
    fn scores_shape_and_argmax_consistency() {
        let h = Mat::from_fn(10, 4, |i, j| ((i * j) % 5) as f64);
        let head = MultiHead::train(&h, &[0, 1, 2, 0, 1, 2, 0, 1, 2, 0], 3, 0.1).unwrap();
        let hv = h.row(0);
        let s = head.scores(hv);
        assert_eq!(s.len(), 3);
        let am = head.predict(hv);
        assert!(s[am] >= s[0] && s[am] >= s[1] && s[am] >= s[2]);
    }
}
