//! Online / adaptive output-weight training (OS-ELM, paper ref [15]
//! "Online and adaptive pseudoinverse solutions for ELM weights"):
//! recursive least squares over the hidden activations, so the second
//! stage can keep learning while the chip serves — no batch re-solve.
//!
//! State: P = (H^T H + lam I)^-1 maintained by the Sherman-Morrison
//! update; beta follows each (h, t) pair in O(L^2).

use crate::util::mat::Mat;

/// Recursive ridge solver over streaming (hidden, target) pairs.
#[derive(Clone, Debug)]
pub struct OnlineElm {
    /// Inverse covariance, L x L.
    p: Mat,
    /// Current output weights.
    pub beta: Vec<f64>,
    /// Samples absorbed.
    pub seen: u64,
}

impl OnlineElm {
    /// Start from the prior `beta = 0`, `P = I / lam` (pure ridge prior).
    pub fn new(l: usize, lambda: f64) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        let mut p = Mat::eye(l);
        p.scale(1.0 / lambda);
        OnlineElm { p, beta: vec![0.0; l], seen: 0 }
    }

    /// Warm-start from a batch solution (the usual OS-ELM init phase).
    pub fn from_batch(h: &Mat, t: &[f64], lambda: f64) -> Result<Self, String> {
        let l = h.cols;
        let mut a = h.gram();
        a.add_diag(lambda);
        // P = A^-1 via Cholesky solves against the identity
        let eye = Mat::eye(l);
        let p = crate::util::mat::cholesky_solve(&a, &eye)?;
        let beta = crate::util::mat::ridge_solve(h, &Mat { rows: t.len(), cols: 1, data: t.to_vec() }, lambda)?;
        Ok(OnlineElm { p, beta: beta.data, seen: h.rows as u64 })
    }

    /// Absorb one sample: h (length L), target t. O(L^2).
    pub fn update(&mut self, h: &[f64], t: f64) {
        let l = self.beta.len();
        assert_eq!(h.len(), l);
        // k = P h / (1 + h' P h)
        let ph = self.p.matvec(h);
        let denom = 1.0 + h.iter().zip(&ph).map(|(a, b)| a * b).sum::<f64>();
        let k: Vec<f64> = ph.iter().map(|v| v / denom).collect();
        // innovation
        let pred: f64 = h.iter().zip(&self.beta).map(|(a, b)| a * b).sum();
        let err = t - pred;
        for j in 0..l {
            self.beta[j] += k[j] * err;
        }
        // P <- P - k (h' P) ; h'P = ph' (P symmetric)
        for i in 0..l {
            let ki = k[i];
            if ki == 0.0 {
                continue;
            }
            let row = self.p.row_mut(i);
            for j in 0..l {
                row[j] -= ki * ph[j];
            }
        }
        self.seen += 1;
    }

    /// Score a hidden vector with the current weights.
    pub fn predict(&self, h: &[f64]) -> f64 {
        h.iter().zip(&self.beta).map(|(a, b)| a * b).sum()
    }
}

/// Multi-head recursive ridge solver over **one shared H stream**: the
/// inverse covariance P depends only on the hidden activations, never
/// on the targets, so C heads trained on the same samples share a
/// single P (and a single Sherman–Morrison update) while keeping one
/// beta each. This is the online half of the registry's shared-H
/// solving (DESIGN.md §14): an OS-ELM update for a 10-class tenant
/// costs one O(L²) P update plus 10 O(L) innovations — not 10 full RLS
/// states. Each head's trajectory is bit-identical to an independent
/// [`OnlineElm`] fed the same stream.
#[derive(Clone, Debug)]
pub struct MultiOnlineElm {
    /// Shared inverse covariance, L x L.
    p: Mat,
    /// One output-weight vector per head.
    pub betas: Vec<Vec<f64>>,
    /// Samples absorbed.
    pub seen: u64,
}

impl MultiOnlineElm {
    /// `heads` zero-initialised heads over an L-wide hidden layer with
    /// the pure ridge prior `P = I / lam`.
    pub fn new(l: usize, heads: usize, lambda: f64) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        assert!(heads > 0, "need at least one head");
        let mut p = Mat::eye(l);
        p.scale(1.0 / lambda);
        MultiOnlineElm { p, betas: vec![vec![0.0; l]; heads], seen: 0 }
    }

    /// Warm-start every head from one batch solve: P from one Cholesky
    /// against the identity, betas from the shared-H multi-head solve
    /// (`train::solve_heads` — the same factored system, one column per
    /// head of `t`).
    pub fn from_batch(h: &Mat, t: &Mat, lambda: f64) -> Result<Self, String> {
        if h.rows != t.rows {
            return Err(format!("H has {} rows but targets have {}", h.rows, t.rows));
        }
        let l = h.cols;
        let mut a = h.gram();
        a.add_diag(lambda);
        let eye = Mat::eye(l);
        let p = crate::util::mat::cholesky_solve(&a, &eye)?;
        let heads = crate::elm::train::solve_heads(h, t, lambda)?;
        let betas = heads.into_iter().map(|head| head.beta).collect();
        Ok(MultiOnlineElm { p, betas, seen: h.rows as u64 })
    }

    /// Absorb one sample into every head: `targets` carries one value
    /// per head. O(L²) for the shared P plus O(L) per head.
    pub fn update(&mut self, h: &[f64], targets: &[f64]) {
        let l = self.p.rows;
        assert_eq!(h.len(), l);
        assert_eq!(targets.len(), self.betas.len());
        let ph = self.p.matvec(h);
        let denom = 1.0 + h.iter().zip(&ph).map(|(a, b)| a * b).sum::<f64>();
        let k: Vec<f64> = ph.iter().map(|v| v / denom).collect();
        for (beta, &t) in self.betas.iter_mut().zip(targets) {
            let pred: f64 = h.iter().zip(beta.iter()).map(|(a, b)| a * b).sum();
            let err = t - pred;
            for (b, &kj) in beta.iter_mut().zip(&k) {
                *b += kj * err;
            }
        }
        for i in 0..l {
            let ki = k[i];
            if ki == 0.0 {
                continue;
            }
            let row = self.p.row_mut(i);
            for (r, &phj) in row.iter_mut().zip(&ph) {
                *r -= ki * phj;
            }
        }
        self.seen += 1;
    }

    /// Scores of every head for one hidden vector.
    pub fn predict(&self, h: &[f64]) -> Vec<f64> {
        self.betas
            .iter()
            .map(|beta| h.iter().zip(beta.iter()).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Score of one head only (avoids the Vec for hot single-head use).
    pub fn predict_head(&self, h: &[f64], head: usize) -> f64 {
        h.iter().zip(self.betas[head].iter()).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::ridge_solve;
    use crate::util::prng::Prng;

    fn make_problem(seed: u64, n: usize, l: usize) -> (Mat, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let h = Mat::from_fn(n, l, |_, _| rng.gaussian());
        let w_true: Vec<f64> = (0..l).map(|_| rng.gaussian()).collect();
        let t: Vec<f64> = (0..n)
            .map(|i| {
                h.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    + rng.normal(0.0, 0.05)
            })
            .collect();
        (h, t)
    }

    #[test]
    fn converges_to_batch_ridge() {
        let (h, t) = make_problem(1, 200, 12);
        let lam = 0.5;
        let batch = ridge_solve(&h, &Mat { rows: 200, cols: 1, data: t.clone() }, lam).unwrap();
        let mut online = OnlineElm::new(12, lam);
        for i in 0..200 {
            online.update(h.row(i), t[i]);
        }
        for j in 0..12 {
            assert!(
                (online.beta[j] - batch.get(j, 0)).abs() < 1e-6,
                "beta {j}: online {} batch {}",
                online.beta[j],
                batch.get(j, 0)
            );
        }
    }

    #[test]
    fn warm_start_plus_stream_equals_full_batch() {
        let (h, t) = make_problem(2, 120, 8);
        let lam = 0.2;
        // init on first 60, stream the rest
        let h0 = Mat::from_rows(&(0..60).map(|i| h.row(i).to_vec()).collect::<Vec<_>>());
        let mut online = OnlineElm::from_batch(&h0, &t[..60], lam).unwrap();
        for i in 60..120 {
            online.update(h.row(i), t[i]);
        }
        let batch = ridge_solve(&h, &Mat { rows: 120, cols: 1, data: t.clone() }, lam).unwrap();
        for j in 0..8 {
            assert!((online.beta[j] - batch.get(j, 0)).abs() < 1e-6, "beta {j}");
        }
        assert_eq!(online.seen, 120);
    }

    #[test]
    fn multi_head_stream_matches_independent_online_elms() {
        // the shared-P solver must be bit-identical to C independent
        // RLS states fed the same (h, t_c) stream
        let (h, _) = make_problem(4, 150, 10);
        let mut rng = Prng::new(44);
        let t = Mat::from_fn(150, 3, |_, _| rng.gaussian());
        let lam = 0.3;
        let mut multi = MultiOnlineElm::new(10, 3, lam);
        let mut singles: Vec<OnlineElm> = (0..3).map(|_| OnlineElm::new(10, lam)).collect();
        for i in 0..150 {
            let targets: Vec<f64> = (0..3).map(|c| t.get(i, c)).collect();
            multi.update(h.row(i), &targets);
            for (c, s) in singles.iter_mut().enumerate() {
                s.update(h.row(i), targets[c]);
            }
        }
        assert_eq!(multi.seen, 150);
        for (c, s) in singles.iter().enumerate() {
            for j in 0..10 {
                assert!(
                    (multi.betas[c][j] - s.beta[j]).abs() < 1e-12,
                    "head {c} beta {j}: {} vs {}",
                    multi.betas[c][j],
                    s.beta[j]
                );
            }
        }
        let p = multi.predict(h.row(0));
        assert_eq!(p.len(), 3);
        for (c, &pc) in p.iter().enumerate() {
            assert!((pc - multi.predict_head(h.row(0), c)).abs() < 1e-15);
        }
    }

    #[test]
    fn multi_head_warm_start_plus_stream_equals_full_batch() {
        let (h, _) = make_problem(5, 120, 8);
        let mut rng = Prng::new(46);
        let t = Mat::from_fn(120, 2, |_, _| rng.gaussian());
        let lam = 0.2;
        let h0 = Mat::from_rows(&(0..60).map(|i| h.row(i).to_vec()).collect::<Vec<_>>());
        let t0 = Mat::from_fn(60, 2, |i, c| t.get(i, c));
        let mut multi = MultiOnlineElm::from_batch(&h0, &t0, lam).unwrap();
        for i in 60..120 {
            multi.update(h.row(i), &[t.get(i, 0), t.get(i, 1)]);
        }
        let batch = ridge_solve(&h, &t, lam).unwrap();
        for c in 0..2 {
            for j in 0..8 {
                assert!(
                    (multi.betas[c][j] - batch.get(j, c)).abs() < 1e-6,
                    "head {c} beta {j}"
                );
            }
        }
        assert_eq!(multi.seen, 120);
    }

    #[test]
    fn prediction_error_shrinks_with_data() {
        let (h, t) = make_problem(3, 300, 10);
        let mut online = OnlineElm::new(10, 0.1);
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..300 {
            let e = (online.predict(h.row(i)) - t[i]).abs();
            if i < 30 {
                early += e;
            }
            if i >= 270 {
                late += e;
            }
            online.update(h.row(i), t[i]);
        }
        assert!(late < 0.3 * early, "early {early} late {late}");
    }
}
