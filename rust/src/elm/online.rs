//! Online / adaptive output-weight training (OS-ELM, paper ref [15]
//! "Online and adaptive pseudoinverse solutions for ELM weights"):
//! recursive least squares over the hidden activations, so the second
//! stage can keep learning while the chip serves — no batch re-solve.
//!
//! State: P = (H^T H + lam I)^-1 maintained by the Sherman-Morrison
//! update; beta follows each (h, t) pair in O(L^2).

use crate::util::mat::Mat;

/// Recursive ridge solver over streaming (hidden, target) pairs.
#[derive(Clone, Debug)]
pub struct OnlineElm {
    /// Inverse covariance, L x L.
    p: Mat,
    /// Current output weights.
    pub beta: Vec<f64>,
    /// Samples absorbed.
    pub seen: u64,
}

impl OnlineElm {
    /// Start from the prior `beta = 0`, `P = I / lam` (pure ridge prior).
    pub fn new(l: usize, lambda: f64) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        let mut p = Mat::eye(l);
        p.scale(1.0 / lambda);
        OnlineElm { p, beta: vec![0.0; l], seen: 0 }
    }

    /// Warm-start from a batch solution (the usual OS-ELM init phase).
    pub fn from_batch(h: &Mat, t: &[f64], lambda: f64) -> Result<Self, String> {
        let l = h.cols;
        let mut a = h.gram();
        a.add_diag(lambda);
        // P = A^-1 via Cholesky solves against the identity
        let eye = Mat::eye(l);
        let p = crate::util::mat::cholesky_solve(&a, &eye)?;
        let beta = crate::util::mat::ridge_solve(h, &Mat { rows: t.len(), cols: 1, data: t.to_vec() }, lambda)?;
        Ok(OnlineElm { p, beta: beta.data, seen: h.rows as u64 })
    }

    /// Absorb one sample: h (length L), target t. O(L^2).
    pub fn update(&mut self, h: &[f64], t: f64) {
        let l = self.beta.len();
        assert_eq!(h.len(), l);
        // k = P h / (1 + h' P h)
        let ph = self.p.matvec(h);
        let denom = 1.0 + h.iter().zip(&ph).map(|(a, b)| a * b).sum::<f64>();
        let k: Vec<f64> = ph.iter().map(|v| v / denom).collect();
        // innovation
        let pred: f64 = h.iter().zip(&self.beta).map(|(a, b)| a * b).sum();
        let err = t - pred;
        for j in 0..l {
            self.beta[j] += k[j] * err;
        }
        // P <- P - k (h' P) ; h'P = ph' (P symmetric)
        for i in 0..l {
            let ki = k[i];
            if ki == 0.0 {
                continue;
            }
            let row = self.p.row_mut(i);
            for j in 0..l {
                row[j] -= ki * ph[j];
            }
        }
        self.seen += 1;
    }

    /// Score a hidden vector with the current weights.
    pub fn predict(&self, h: &[f64]) -> f64 {
        h.iter().zip(&self.beta).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::ridge_solve;
    use crate::util::prng::Prng;

    fn make_problem(seed: u64, n: usize, l: usize) -> (Mat, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let h = Mat::from_fn(n, l, |_, _| rng.gaussian());
        let w_true: Vec<f64> = (0..l).map(|_| rng.gaussian()).collect();
        let t: Vec<f64> = (0..n)
            .map(|i| {
                h.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    + rng.normal(0.0, 0.05)
            })
            .collect();
        (h, t)
    }

    #[test]
    fn converges_to_batch_ridge() {
        let (h, t) = make_problem(1, 200, 12);
        let lam = 0.5;
        let batch = ridge_solve(&h, &Mat { rows: 200, cols: 1, data: t.clone() }, lam).unwrap();
        let mut online = OnlineElm::new(12, lam);
        for i in 0..200 {
            online.update(h.row(i), t[i]);
        }
        for j in 0..12 {
            assert!(
                (online.beta[j] - batch.get(j, 0)).abs() < 1e-6,
                "beta {j}: online {} batch {}",
                online.beta[j],
                batch.get(j, 0)
            );
        }
    }

    #[test]
    fn warm_start_plus_stream_equals_full_batch() {
        let (h, t) = make_problem(2, 120, 8);
        let lam = 0.2;
        // init on first 60, stream the rest
        let h0 = Mat::from_rows(&(0..60).map(|i| h.row(i).to_vec()).collect::<Vec<_>>());
        let mut online = OnlineElm::from_batch(&h0, &t[..60], lam).unwrap();
        for i in 60..120 {
            online.update(h.row(i), t[i]);
        }
        let batch = ridge_solve(&h, &Mat { rows: 120, cols: 1, data: t.clone() }, lam).unwrap();
        for j in 0..8 {
            assert!((online.beta[j] - batch.get(j, 0)).abs() < 1e-6, "beta {j}");
        }
        assert_eq!(online.seen, 120);
    }

    #[test]
    fn prediction_error_shrinks_with_data() {
        let (h, t) = make_problem(3, 300, 10);
        let mut online = OnlineElm::new(10, 0.1);
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..300 {
            let e = (online.predict(h.row(i)) - t[i]).abs();
            if i < 30 {
                early += e;
            }
            if i >= 270 {
                late += e;
            }
            online.update(h.row(i), t[i]);
        }
        assert!(late < 0.3 * early, "early {early} late {late}");
    }
}
