//! Digital second stage of the ELM (the FPGA of Fig. 2): fixed-point
//! output-weight MAC with configurable beta resolution (the Fig. 7b
//! study) and the eq. 26 normalisation divider (Section VI-F).

/// Quantised output-weight vector: symmetric uniform grid over the max
/// magnitude, `bits` total (1 sign + bits-1 magnitude). Matches
/// `model.quantize_beta` on the Python side.
#[derive(Clone, Debug)]
pub struct QuantBeta {
    /// Integer codes in [-(2^(bits-1)-1), 2^(bits-1)-1].
    pub codes: Vec<i32>,
    /// LSB scale back to float.
    pub scale: f64,
    pub bits: u32,
}

impl QuantBeta {
    pub fn quantize(beta: &[f64], bits: u32) -> Self {
        assert!(bits >= 2, "need at least sign + 1 bit");
        let max = beta.iter().fold(0.0f64, |m, &b| m.max(b.abs())).max(1e-30);
        let levels = ((1u32 << (bits - 1)) - 1) as f64;
        let codes = beta
            .iter()
            .map(|&b| (b / max * levels).round() as i32)
            .collect();
        QuantBeta { codes, scale: max / levels, bits }
    }

    /// De-quantised weights (for error analysis).
    pub fn dequantize(&self) -> Vec<f64> {
        self.codes.iter().map(|&c| c as f64 * self.scale).collect()
    }

    /// Worst-case quantisation error bound: half an LSB.
    pub fn lsb(&self) -> f64 {
        self.scale
    }
}

/// The second-stage engine: integer MAC over counter outputs, matching
/// the "14-bit x 10-bit array multiplier" sized in Section VI-B.
#[derive(Clone, Debug)]
pub struct SecondStage {
    pub beta: QuantBeta,
    /// Apply the eq. 26 normalisation before the MAC.
    pub normalize: bool,
}

impl SecondStage {
    pub fn new(beta: &[f64], bits: u32, normalize: bool) -> Self {
        SecondStage { beta: QuantBeta::quantize(beta, bits), normalize }
    }

    /// Score one hidden vector of counter outputs. `codes_sum` is
    /// `sum_i x_i` needed by eq. 26 (the input-side scanner provides it).
    pub fn score(&self, h: &[u32], codes_sum: f64) -> f64 {
        assert_eq!(h.len(), self.beta.codes.len());
        if self.normalize {
            // eq. 26: h_norm_j = h_j * sum_i(x_i) / sum_j(h_j); the
            // divider runs once per vector (the paper's "L divisions").
            let hs: f64 = h.iter().map(|&v| v as f64).sum();
            if hs == 0.0 {
                return 0.0;
            }
            let g = codes_sum / hs;
            let acc: f64 = h
                .iter()
                .zip(&self.beta.codes)
                .map(|(&hj, &bj)| hj as f64 * g * bj as f64)
                .sum();
            acc * self.beta.scale
        } else {
            // pure integer MAC (i64 accumulator cannot overflow: 2^14
            // counts x 2^9 beta x 2^14 neurons < 2^37)
            let acc: i64 = h
                .iter()
                .zip(&self.beta.codes)
                .map(|(&hj, &bj)| hj as i64 * bj as i64)
                .sum();
            acc as f64 * self.beta.scale
        }
    }

    /// Binary decision at threshold `thr` (targets are +-1).
    pub fn classify(&self, h: &[u32], codes_sum: f64, thr: f64) -> i8 {
        if self.score(h, codes_sum) >= thr {
            1
        } else {
            -1
        }
    }
}

/// Normalised hidden vector as floats (training-side eq. 26, matching
/// `ref.normalize` on the Python side).
pub fn normalize_h(h: &[u32], codes_sum: f64) -> Vec<f64> {
    let hs: f64 = h.iter().map(|&v| v as f64).sum();
    if hs == 0.0 {
        return vec![0.0; h.len()];
    }
    let g = codes_sum / hs;
    h.iter().map(|&v| v as f64 * g).collect()
}

/// Sum of DAC codes for eq. 26's `sum_i x_i` term.
pub fn codes_sum(codes: &[u16]) -> f64 {
    codes.iter().map(|&c| c as f64).sum()
}

/// Convenience: the per-sample energy of the digital second stage, from
/// the Section VI-B estimate (7.1 pJ per 14x10-bit multiply at 1.5 V).
pub fn second_stage_energy(l: usize, e_mult: f64) -> f64 {
    l as f64 * e_mult
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let beta: Vec<f64> = (0..32).map(|i| ((i * 37) % 17) as f64 / 8.5 - 1.0).collect();
        for bits in [4u32, 8, 10, 14] {
            let q = QuantBeta::quantize(&beta, bits);
            let back = q.dequantize();
            let max_err = beta
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(max_err <= 0.5 * q.lsb() * (1.0 + 1e-12), "bits {bits}");
        }
    }

    #[test]
    fn more_bits_less_error() {
        let beta: Vec<f64> = (0..64).map(|i| (i as f64 * 0.7).sin()).collect();
        let err = |bits| {
            let q = QuantBeta::quantize(&beta, bits);
            let back = q.dequantize();
            beta.iter()
                .zip(&back)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        };
        assert!(err(10) < err(6));
        assert!(err(6) < err(3));
    }

    #[test]
    fn integer_mac_matches_float_within_lsb() {
        let beta = vec![0.5, -0.25, 1.0, -1.0];
        let ss = SecondStage::new(&beta, 10, false);
        let h = vec![100u32, 200, 50, 25];
        let float_score: f64 = h
            .iter()
            .zip(&beta)
            .map(|(&hj, &bj)| hj as f64 * bj)
            .sum();
        let q_score = ss.score(&h, 0.0);
        let bound = ss.beta.lsb() * 0.5 * h.iter().map(|&x| x as f64).sum::<f64>();
        assert!((q_score - float_score).abs() <= bound, "{q_score} vs {float_score}");
    }

    #[test]
    fn normalized_score_invariant_to_common_gain() {
        let beta = vec![0.3, -0.7, 0.2, 0.9];
        let ss = SecondStage::new(&beta, 10, true);
        let h = vec![100u32, 220, 40, 90];
        let h_gained: Vec<u32> = h.iter().map(|&v| v * 3).collect();
        let s0 = ss.score(&h, 1000.0);
        let s1 = ss.score(&h_gained, 1000.0);
        assert!((s0 - s1).abs() < 1e-9 * s0.abs().max(1.0));
    }

    #[test]
    fn classify_thresholds() {
        let ss = SecondStage::new(&[1.0], 10, false);
        assert_eq!(ss.classify(&[5], 0.0, 0.0), 1);
        let ssn = SecondStage::new(&[-1.0], 10, false);
        assert_eq!(ssn.classify(&[5], 0.0, 0.0), -1);
    }

    #[test]
    fn normalize_h_matches_python_ref_semantics() {
        let h = vec![10u32, 20, 30, 40];
        let codes_sum = 500.0;
        let n = normalize_h(&h, codes_sum);
        let hs = 100.0;
        for (j, &hj) in h.iter().enumerate() {
            assert!((n[j] - hj as f64 * codes_sum / hs).abs() < 1e-12);
        }
        assert_eq!(normalize_h(&[0, 0], 100.0), vec![0.0, 0.0]);
    }
}
