//! ELM algorithm layer: training (eq. 3), the digital second stage, the
//! software float baseline, and the high-level classifier/regressor API
//! gluing a hidden layer (chip / virtual chip / PJRT) to the head.

pub mod cluster;
pub mod multiclass;
pub mod online;
pub mod secondstage;
pub mod softelm;
pub mod train;

use crate::chip::{dac, ChipModel};
use crate::elm::secondstage::{codes_sum, normalize_h, SecondStage};
use crate::elm::train::{
    assemble_h, misclassification, predict, rmse, solve_head, HiddenLayer, TrainedHead,
};
use crate::util::mat::Mat;

/// The chip as an ELM hidden layer (with optional eq. 26 normalisation).
pub struct ChipHidden {
    pub chip: ChipModel,
    pub normalize: bool,
}

impl ChipHidden {
    pub fn new(chip: ChipModel) -> Self {
        ChipHidden { chip, normalize: false }
    }

    pub fn normalized(chip: ChipModel) -> Self {
        ChipHidden { chip, normalize: true }
    }
}

impl HiddenLayer for ChipHidden {
    fn input_dim(&self) -> usize {
        self.chip.cfg.d
    }

    fn hidden_dim(&self) -> usize {
        self.chip.cfg.l
    }

    fn transform(&mut self, x: &[f64]) -> Vec<f64> {
        let codes = dac::features_to_codes(x, &self.chip.cfg);
        let h = self.chip.forward(&codes);
        // counts are rescaled by the counter cap so H is O(1): the ridge
        // lambda then means the same thing across chip, FastSim and
        // software backends. A global scale is invisible to the
        // classifier (beta absorbs it) and to eq. 26.
        let scale = 1.0 / self.chip.cfg.cap() as f64;
        if self.normalize {
            normalize_h(&h, codes_sum(&codes))
                .into_iter()
                .map(|v| v * scale)
                .collect()
        } else {
            h.iter().map(|&v| v as f64 * scale).collect()
        }
    }
}

/// A trained end-to-end model: float head for analysis plus the
/// fixed-point second stage actually deployed (Fig. 7b: 10 bits).
pub struct ElmModel {
    pub head: TrainedHead,
    pub second: SecondStage,
    pub beta_bits: u32,
}

impl ElmModel {
    pub fn from_head(head: TrainedHead, beta_bits: u32, normalize: bool) -> Self {
        let second = SecondStage::new(&head.beta, beta_bits, normalize);
        ElmModel { head, second, beta_bits }
    }
}

/// Train a model on a hidden layer: assemble H, solve the ridge system.
pub fn train_model<T: HiddenLayer + ?Sized>(
    layer: &mut T,
    xs: &[Vec<f64>],
    ys: &[f64],
    lambda: f64,
    beta_bits: u32,
    normalize: bool,
) -> Result<(ElmModel, Mat), String> {
    let h = assemble_h(layer, xs);
    let head = solve_head(&h, ys, lambda)?;
    Ok((ElmModel::from_head(head, beta_bits, normalize), h))
}

/// Classification error of a trained model on a dataset, using the
/// *float* head (upper bound on fixed-point performance).
pub fn eval_classification<T: HiddenLayer + ?Sized>(
    layer: &mut T,
    model: &ElmModel,
    xs: &[Vec<f64>],
    ys: &[f64],
) -> f64 {
    let h = assemble_h(layer, xs);
    misclassification(&predict(&h, &model.head), ys)
}

/// Classification error through the quantised second stage — the number
/// the hardware actually achieves (Table II).
pub fn eval_classification_fixed(
    hidden: &mut ChipHidden,
    model: &ElmModel,
    xs: &[Vec<f64>],
    ys: &[f64],
) -> f64 {
    let mut wrong = 0usize;
    for (x, &y) in xs.iter().zip(ys) {
        let codes = dac::features_to_codes(x, &hidden.chip.cfg);
        let h = hidden.chip.forward(&codes);
        let label = model.second.classify(&h, codes_sum(&codes), 0.0);
        if (label as f64 - y).abs() > 1e-9 {
            wrong += 1;
        }
    }
    wrong as f64 / xs.len() as f64
}

/// Regression RMSE against (possibly clean) targets.
pub fn eval_regression<T: HiddenLayer + ?Sized>(
    layer: &mut T,
    model: &ElmModel,
    xs: &[Vec<f64>],
    ys: &[f64],
) -> f64 {
    let h = assemble_h(layer, xs);
    rmse(&predict(&h, &model.head), ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, Transfer};
    use crate::util::prng::Prng;

    fn chip_hidden(d: usize, l: usize, seed: u64) -> ChipHidden {
        let cfg = ChipConfig::default()
            .with_dims(d, l)
            .with_b(10)
            .with_mode(Transfer::Quadratic);
        ChipHidden::new(ChipModel::fabricate(cfg, seed))
    }

    fn blobs(seed: u64, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        // two well-separated gaussian blobs in [-1,1]^d
        let mut rng = Prng::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
            let center = 0.35 * y;
            xs.push((0..d).map(|_| (center + rng.normal(0.0, 0.18)).clamp(-1.0, 1.0)).collect());
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn chip_hidden_shapes() {
        let mut ch = chip_hidden(8, 12, 1);
        assert_eq!(ch.input_dim(), 8);
        assert_eq!(ch.hidden_dim(), 12);
        assert_eq!(ch.transform(&[0.0; 8]).len(), 12);
    }

    #[test]
    fn chip_elm_separates_blobs() {
        let mut ch = chip_hidden(8, 64, 2);
        let (xs, ys) = blobs(3, 300, 8);
        let (model, h) = train_model(&mut ch, &xs, &ys, 1e-2, 10, false).unwrap();
        let train_err = misclassification(&predict(&h, &model.head), &ys);
        assert!(train_err < 0.05, "train err {train_err}");
        let (xt, yt) = blobs(4, 200, 8);
        let test_err = eval_classification(&mut ch, &model, &xt, &yt);
        assert!(test_err < 0.1, "test err {test_err}");
    }

    #[test]
    fn fixed_point_close_to_float() {
        // Fig. 7(b): 10-bit beta is enough — fixed-point error is within
        // a few points of the float head.
        let mut ch = chip_hidden(8, 64, 5);
        let (xs, ys) = blobs(6, 300, 8);
        let (model, _) = train_model(&mut ch, &xs, &ys, 1e-2, 10, false).unwrap();
        let (xt, yt) = blobs(7, 200, 8);
        let float_err = eval_classification(&mut ch, &model, &xt, &yt);
        let fixed_err = eval_classification_fixed(&mut ch, &model, &xt, &yt);
        assert!(
            (fixed_err - float_err).abs() <= 0.05,
            "float {float_err} fixed {fixed_err}"
        );
    }

    #[test]
    fn normalized_training_still_learns() {
        let cfg = ChipConfig::default().with_dims(8, 64).with_b(10);
        let mut ch = ChipHidden::normalized(ChipModel::fabricate(cfg, 8));
        let (xs, ys) = blobs(9, 300, 8);
        let (model, h) = train_model(&mut ch, &xs, &ys, 1e-2, 10, true).unwrap();
        let err = misclassification(&predict(&h, &model.head), &ys);
        assert!(err < 0.08, "normalized train err {err}");
        let _ = model;
    }
}
