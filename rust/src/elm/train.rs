//! ELM training (Section II): assemble the hidden matrix H by pushing the
//! training set through a hidden-layer transform (chip, virtual chip, or
//! PJRT engine) and solve the ridge system of eq. 3 for the output
//! weights, with cross-validated C.

use crate::util::mat::{ridge_solve, Mat};
use crate::util::prng::Prng;

/// Anything that maps a feature vector in [-1,1]^d to hidden outputs.
/// Implemented by the physical chip, the rotation-extended virtual chip
/// and the PJRT serving engine — training code is agnostic.
pub trait HiddenLayer {
    /// Input dimension d the transform accepts.
    fn input_dim(&self) -> usize;
    /// Hidden width L it produces.
    fn hidden_dim(&self) -> usize;
    /// One sample -> one hidden activation row (float; counters cast up).
    fn transform(&mut self, x: &[f64]) -> Vec<f64>;
}

/// Assemble H (N x L) for a feature matrix (N x d).
pub fn assemble_h<T: HiddenLayer + ?Sized>(layer: &mut T, xs: &[Vec<f64>]) -> Mat {
    let rows: Vec<Vec<f64>> = xs.iter().map(|x| layer.transform(x)).collect();
    Mat::from_rows(&rows)
}

/// Trained ELM head: float beta plus the lambda that produced it.
#[derive(Clone, Debug)]
pub struct TrainedHead {
    pub beta: Vec<f64>,
    pub lambda: f64,
}

/// Solve eq. 3 on an assembled H for scalar targets.
pub fn solve_head(h: &Mat, targets: &[f64], lambda: f64) -> Result<TrainedHead, String> {
    assert_eq!(h.rows, targets.len());
    let t = Mat { rows: targets.len(), cols: 1, data: targets.to_vec() };
    let beta = ridge_solve(h, &t, lambda)?;
    Ok(TrainedHead { beta: beta.data, lambda })
}

/// Solve eq. 3 for **many heads over one shared H**: `targets` carries
/// one column per head, and `ridge_solve` factors the L×L normal matrix
/// once for all of them. This is the registry's shared-H multi-head
/// solver (DESIGN.md §14): a tenant with C output heads (one-vs-all
/// classification) costs one chip-in-the-loop H assembly and one
/// Cholesky, not C of either. Column c of the result is bit-identical
/// to `solve_head(h, targets.col(c), lambda)`.
pub fn solve_heads(h: &Mat, targets: &Mat, lambda: f64) -> Result<Vec<TrainedHead>, String> {
    if h.rows != targets.rows {
        return Err(format!(
            "H has {} rows but targets have {}",
            h.rows, targets.rows
        ));
    }
    if targets.cols == 0 {
        return Err("no target columns to solve".into());
    }
    let beta = ridge_solve(h, targets, lambda)?;
    Ok((0..targets.cols)
        .map(|c| TrainedHead { beta: beta.col(c), lambda })
        .collect())
}

/// Predicted scores H beta.
pub fn predict(h: &Mat, head: &TrainedHead) -> Vec<f64> {
    h.matvec(&head.beta)
}

/// Misclassification rate for +-1 targets at threshold 0.
pub fn misclassification(scores: &[f64], targets: &[f64]) -> f64 {
    assert_eq!(scores.len(), targets.len());
    let wrong = scores
        .iter()
        .zip(targets)
        .filter(|(s, t)| (s.signum() - t.signum()).abs() > 1e-9)
        .count();
    wrong as f64 / targets.len() as f64
}

/// RMSE for regression targets.
pub fn rmse(scores: &[f64], targets: &[f64]) -> f64 {
    crate::util::stats::rmse(scores, targets)
}

/// K-fold cross-validation of the ridge constant over a grid
/// (the paper: "C is typically optimized as a hyperparameter using
/// cross-validation"). Returns (best lambda, its CV loss).
pub fn cross_validate_lambda(
    h: &Mat,
    targets: &[f64],
    grid: &[f64],
    folds: usize,
    classification: bool,
    seed: u64,
) -> (f64, f64) {
    assert!(folds >= 2 && h.rows >= folds);
    let mut rng = Prng::new(seed);
    let perm = rng.permutation(h.rows);
    let mut best = (grid[0], f64::MAX);
    for &lam in grid {
        let mut loss_acc = 0.0;
        for f in 0..folds {
            let val_idx: Vec<usize> = perm
                .iter()
                .enumerate()
                .filter(|(k, _)| k % folds == f)
                .map(|(_, &i)| i)
                .collect();
            let tr_idx: Vec<usize> = perm
                .iter()
                .enumerate()
                .filter(|(k, _)| k % folds != f)
                .map(|(_, &i)| i)
                .collect();
            let h_tr = Mat::from_rows(&tr_idx.iter().map(|&i| h.row(i).to_vec()).collect::<Vec<_>>());
            let t_tr: Vec<f64> = tr_idx.iter().map(|&i| targets[i]).collect();
            let h_va = Mat::from_rows(&val_idx.iter().map(|&i| h.row(i).to_vec()).collect::<Vec<_>>());
            let t_va: Vec<f64> = val_idx.iter().map(|&i| targets[i]).collect();
            match solve_head(&h_tr, &t_tr, lam) {
                Ok(head) => {
                    let scores = predict(&h_va, &head);
                    loss_acc += if classification {
                        misclassification(&scores, &t_va)
                    } else {
                        rmse(&scores, &t_va)
                    };
                }
                Err(_) => loss_acc += f64::MAX / folds as f64,
            }
        }
        let loss = loss_acc / folds as f64;
        if loss < best.1 {
            best = (lam, loss);
        }
    }
    best
}

/// Standard lambda grid used across the benches.
pub fn default_lambda_grid() -> Vec<f64> {
    vec![1e-6, 1e-4, 1e-2, 1.0, 1e2]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy deterministic hidden layer for unit tests.
    struct ToyLayer {
        w: Mat,
    }
    impl HiddenLayer for ToyLayer {
        fn input_dim(&self) -> usize {
            self.w.rows
        }
        fn hidden_dim(&self) -> usize {
            self.w.cols
        }
        fn transform(&mut self, x: &[f64]) -> Vec<f64> {
            let z = self.w.transpose().matvec(x);
            z.iter().map(|v| v.tanh()).collect()
        }
    }

    fn toy(seed: u64, d: usize, l: usize) -> ToyLayer {
        let mut rng = Prng::new(seed);
        ToyLayer { w: Mat::random_uniform(d, l, -1.0, 1.0, &mut rng) }
    }

    fn toy_dataset(seed: u64, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        // nonlinear rule with a margin band removed so random features
        // can realise it reliably
        let mut rng = Prng::new(seed);
        let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        while xs.len() < n {
            let x: Vec<f64> = (0..d).map(|_| rng.range(-1.0, 1.0)).collect();
            let v = x[0] * x[1] + 0.5 * x[2];
            if v.abs() < 0.15 {
                continue;
            }
            ys.push(if v > 0.0 { 1.0 } else { -1.0 });
            xs.push(x);
        }
        (xs, ys)
    }

    #[test]
    fn assemble_shapes() {
        let mut layer = toy(1, 4, 10);
        let (xs, _) = toy_dataset(2, 20, 4);
        let h = assemble_h(&mut layer, &xs);
        assert_eq!((h.rows, h.cols), (20, 10));
    }

    #[test]
    fn elm_learns_nonlinear_rule() {
        let mut layer = toy(3, 4, 150);
        let (xs, ys) = toy_dataset(4, 300, 4);
        let h = assemble_h(&mut layer, &xs);
        let head = solve_head(&h, &ys, 1e-4).unwrap();
        let err = misclassification(&predict(&h, &head), &ys);
        assert!(err < 0.12, "train error {err}");
    }

    #[test]
    fn generalization_on_holdout() {
        let mut layer = toy(5, 4, 150);
        let (xs, ys) = toy_dataset(6, 500, 4);
        let (xt, yt) = toy_dataset(7, 200, 4);
        let h = assemble_h(&mut layer, &xs);
        let head = solve_head(&h, &ys, 1e-3).unwrap();
        let ht = assemble_h(&mut layer, &xt);
        let err = misclassification(&predict(&ht, &head), &yt);
        assert!(err < 0.22, "test error {err}");
    }

    #[test]
    fn cross_validation_picks_reasonable_lambda() {
        let mut layer = toy(8, 4, 40);
        let (xs, ys) = toy_dataset(9, 200, 4);
        let h = assemble_h(&mut layer, &xs);
        let (lam, loss) = cross_validate_lambda(&h, &ys, &default_lambda_grid(), 4, true, 10);
        assert!(default_lambda_grid().contains(&lam));
        assert!(loss < 0.3, "cv loss {loss}");
        // extreme regularisation must be worse than the chosen one
        let head_best = solve_head(&h, &ys, lam).unwrap();
        let head_huge = solve_head(&h, &ys, 1e9).unwrap();
        let e_best = misclassification(&predict(&h, &head_best), &ys);
        let e_huge = misclassification(&predict(&h, &head_huge), &ys);
        assert!(e_best <= e_huge);
    }

    #[test]
    fn solve_heads_matches_independent_solves() {
        let mut layer = toy(11, 4, 30);
        let (xs, _) = toy_dataset(12, 120, 4);
        let h = assemble_h(&mut layer, &xs);
        let targets = Mat::from_fn(120, 3, |i, c| ((i * (c + 2)) % 7) as f64 / 3.0 - 1.0);
        let many = solve_heads(&h, &targets, 1e-3).unwrap();
        assert_eq!(many.len(), 3);
        for (c, head) in many.iter().enumerate() {
            let single = solve_head(&h, &targets.col(c), 1e-3).unwrap();
            for (a, b) in head.beta.iter().zip(&single.beta) {
                assert!((a - b).abs() < 1e-12, "head {c} diverged: {a} vs {b}");
            }
        }
        assert!(solve_heads(&h, &Mat::from_fn(7, 1, |_, _| 0.0), 1e-3).is_err());
    }

    #[test]
    fn misclassification_counts() {
        let s = vec![1.0, -2.0, 0.5, -0.1];
        let t = vec![1.0, -1.0, -1.0, 1.0];
        assert!((misclassification(&s, &t) - 0.5).abs() < 1e-12);
    }
}
