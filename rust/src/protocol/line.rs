//! Protocol v0: the newline-terminated ASCII grammar (DESIGN.md §15).
//!
//! This is the serving surface's original wire format, kept
//! bit-compatible so pre-protocol clients (netcat, old scripts) keep
//! working — the golden-string tests in tests/integration_protocol.rs
//! pin both the command grammar and the reply text. New capability goes
//! into the v1 frame codec instead; v0 only ever gains fixes that its
//! usage lines already promised (e.g. an empty feature list now answers
//! with the command's usage line instead of a float-parse error).
//!
//! Grammar (one command per line; replies are one `OK ...` or
//! `ERR ...` line each):
//!
//! ```text
//! PING                           -> OK pong
//! STATS                          -> OK <metrics one-liner>
//! HEALTH                         -> OK <per-die gauges + fleet counters>
//! MODELS                         -> OK <tenant directory one-liner>
//! DRAIN <die>                    -> OK draining die <die>
//! CLASSIFY x1,x2,...,xd          -> OK <label> <score>
//! PREDICT <tenant> x1,x2,...,xd  -> OK <label> <score>
//! REGISTER <name> <dataset> [s]  -> OK registered <name> (<task>, mean train score <s>)
//! UNREGISTER <name>              -> OK unregistered <name>
//! TRACE [n]                      -> OK trace <entries, ' | ' separated>
//! GOVERNOR                       -> OK <governor status one-liner>
//! QUIT                           closes the connection
//! ```
//!
//! `TRACE` (DESIGN.md §16) is display-only on v0: the reply stays one
//! line (entries joined with `" | "`) so line-per-reply framing holds,
//! and the client side does not parse it back into typed entries —
//! typed traces and the structured [`super::StatsSnapshot`] ride the
//! v1 frame codec only.

use std::io::{BufRead, Write};

use super::{parse_features, Codec, Decoded, Prediction, Request, Response};

/// The v0 ASCII codec. Stateless: one value serves a whole connection.
#[derive(Clone, Copy, Debug, Default)]
pub struct LineCodec;

/// Parse one v0 command line. Never returns [`Decoded::Eof`] — end of
/// stream is the transport's business, not the grammar's.
pub fn parse_line(line: &str) -> Decoded {
    let line = line.trim();
    if line.is_empty() {
        return Decoded::Malformed("empty command".into());
    }
    let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
    match cmd.to_ascii_uppercase().as_str() {
        "PING" => Decoded::Request(Request::Ping),
        "STATS" => Decoded::Request(Request::Stats),
        "HEALTH" => Decoded::Request(Request::Health),
        "MODELS" => Decoded::Request(Request::Models),
        "GOVERNOR" => Decoded::Request(Request::Governor),
        "QUIT" => Decoded::Quit,
        "DRAIN" => match rest.trim().parse::<usize>() {
            Err(_) => Decoded::Malformed(format!("DRAIN wants a die index, got '{rest}'")),
            Ok(die) => Decoded::Request(Request::Drain { die }),
        },
        "CLASSIFY" => {
            let feats = rest.trim();
            if feats.is_empty() {
                return Decoded::Malformed("CLASSIFY wants: CLASSIFY x1,x2,...".into());
            }
            match parse_features(feats) {
                Err(e) => Decoded::Malformed(e),
                Ok(f) => Decoded::Request(Request::Predict { tenant: None, features: f }),
            }
        }
        "PREDICT" => {
            // PREDICT <tenant> x1,x2,...,xd
            let usage = || Decoded::Malformed("PREDICT wants: PREDICT <tenant> x1,x2,...".into());
            let Some((tenant, feats)) = rest.trim().split_once(' ') else {
                return usage();
            };
            let feats = feats.trim();
            if feats.is_empty() {
                return usage();
            }
            match parse_features(feats) {
                Err(e) => Decoded::Malformed(e),
                Ok(f) => Decoded::Request(Request::Predict {
                    tenant: Some(tenant.trim().to_string()),
                    features: f,
                }),
            }
        }
        "REGISTER" => {
            // REGISTER <name> <dataset> [seed]
            let mut parts = rest.split_whitespace();
            let (Some(name), Some(dataset)) = (parts.next(), parts.next()) else {
                return Decoded::Malformed(
                    "REGISTER wants: REGISTER <name> <dataset> [seed]".into(),
                );
            };
            let seed = match parts.next().map(|t| t.parse::<u64>()) {
                None => 1,
                Some(Ok(s)) => s,
                Some(Err(e)) => return Decoded::Malformed(format!("bad seed: {e}")),
            };
            Decoded::Request(Request::Register {
                name: name.to_string(),
                dataset: dataset.to_string(),
                seed,
            })
        }
        "UNREGISTER" => {
            let name = rest.trim();
            if name.is_empty() {
                return Decoded::Malformed("UNREGISTER wants a tenant name".into());
            }
            Decoded::Request(Request::Unregister { name: name.to_string() })
        }
        "TRACE" => {
            let rest = rest.trim();
            if rest.is_empty() {
                return Decoded::Request(Request::Trace { last: 32 });
            }
            match rest.parse::<usize>() {
                Err(_) => Decoded::Malformed(format!("TRACE wants an entry count, got '{rest}'")),
                Ok(last) => Decoded::Request(Request::Trace { last }),
            }
        }
        other => Decoded::Malformed(format!("unknown command {other}")),
    }
}

/// Render a response as its v0 reply line (no trailing newline).
pub fn format_response(resp: &Response) -> String {
    match resp {
        Response::Pong => "OK pong".into(),
        Response::Stats(s)
        | Response::Health(s)
        | Response::Models(s)
        | Response::Governor(s) => format!("OK {s}"),
        Response::Draining { die } => format!("OK draining die {die}"),
        Response::Predict(p) => format!("OK {} {:.6}", p.label, p.score),
        // unreachable from the v0 grammar (no batch command parses),
        // but a total function beats a panic if a caller mixes codecs
        Response::Batch(_) => "ERR batch responses need the v1 framed protocol".into(),
        Response::Trace(ts) if ts.is_empty() => "OK trace empty".into(),
        Response::Trace(ts) => {
            let body =
                ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" | ");
            format!("OK trace {body}")
        }
        Response::Snapshot(_) => "ERR snapshot responses need the v1 framed protocol".into(),
        Response::Timeline(_) => "ERR timeline responses need the v1 framed protocol".into(),
        Response::HelloOk { .. } => "ERR hello responses need the v1 framed protocol".into(),
        Response::Updated { .. } => "ERR update responses need the v1 framed protocol".into(),
        Response::Registered { name, task, score } => {
            format!("OK registered {name} ({task}, mean train score {score:.4})")
        }
        Response::Unregistered { name } => format!("OK unregistered {name}"),
        Response::Error(e) => format!("ERR {e}"),
    }
}

/// Render a request as its v0 command line (no trailing newline).
/// `BatchPredict` has no v0 spelling and is refused.
pub fn format_request(req: &Request) -> Result<String, String> {
    let join = |features: &[f64]| {
        features.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
    };
    match req {
        Request::Ping => Ok("PING".into()),
        Request::Stats => Ok("STATS".into()),
        Request::Health => Ok("HEALTH".into()),
        Request::Models => Ok("MODELS".into()),
        Request::Drain { die } => Ok(format!("DRAIN {die}")),
        Request::Predict { tenant: None, features } => Ok(format!("CLASSIFY {}", join(features))),
        Request::Predict { tenant: Some(t), features } => {
            Ok(format!("PREDICT {t} {}", join(features)))
        }
        Request::BatchPredict { .. } => {
            Err("protocol v0 has no batch frame; send rows as PREDICT lines".into())
        }
        Request::Register { name, dataset, seed } => {
            Ok(format!("REGISTER {name} {dataset} {seed}"))
        }
        Request::Unregister { name } => Ok(format!("UNREGISTER {name}")),
        Request::Trace { last } => Ok(format!("TRACE {last}")),
        Request::Snapshot => {
            Err("protocol v0 has no snapshot frame; read STATS instead".into())
        }
        Request::Governor => Ok("GOVERNOR".into()),
        Request::Timeline { .. } => {
            Err("protocol v0 has no timeline frame; use the v1 framed protocol".into())
        }
        Request::Hello { .. } => {
            Err("protocol v0 has no hello frame; use the v1 framed protocol".into())
        }
        Request::TenantUpdate { .. } => {
            Err("protocol v0 has no tenant-update frame; use the v1 framed protocol".into())
        }
        Request::BatchStream { .. } => {
            Err("protocol v0 has no stream frame; send rows as PREDICT lines".into())
        }
    }
}

/// Client side: parse a v0 reply line given the request it answers
/// (v0 replies are not self-describing).
pub fn parse_response(line: &str, expect: &Request) -> Response {
    if let Some(err) = line.strip_prefix("ERR ") {
        return Response::Error(err.to_string());
    }
    let Some(body) = line.strip_prefix("OK ") else {
        return Response::Error(format!("unparseable v0 reply '{line}'"));
    };
    match expect {
        Request::Ping => Response::Pong,
        Request::Stats => Response::Stats(body.to_string()),
        Request::Health => Response::Health(body.to_string()),
        Request::Models => Response::Models(body.to_string()),
        Request::Drain { die } => Response::Draining { die: *die },
        Request::Predict { tenant, .. } => {
            let mut it = body.split_whitespace();
            let label = it.next().and_then(|t| t.parse::<i8>().ok());
            let score = it.next().and_then(|t| t.parse::<f64>().ok());
            match (label, score) {
                (Some(label), Some(score)) => Response::Predict(Prediction {
                    label,
                    score,
                    tenant: tenant.clone(),
                }),
                _ => Response::Error(format!("unparseable v0 prediction '{line}'")),
            }
        }
        Request::BatchPredict { .. } => {
            Response::Error("protocol v0 has no batch frame".into())
        }
        Request::Register { name, .. } => {
            // "registered <name> (<task>, mean train score <s>)"
            let task = body
                .split_once('(')
                .and_then(|(_, rest)| rest.split_once(','))
                .map(|(t, _)| t.to_string())
                .unwrap_or_default();
            let score = body
                .rsplit_once(' ')
                .and_then(|(_, s)| s.trim_end_matches(')').parse::<f64>().ok())
                .unwrap_or(f64::NAN);
            Response::Registered { name: name.clone(), task, score }
        }
        Request::Unregister { name } => Response::Unregistered { name: name.clone() },
        // v0 trace replies are display text, not a typed dump; the SDK
        // routes trace()/snapshot() over v1 or in-process instead
        Request::Trace { .. } => {
            Response::Error("v0 trace replies are display-only; use the v1 framed protocol".into())
        }
        Request::Snapshot => Response::Error("protocol v0 has no snapshot frame".into()),
        Request::Governor => Response::Governor(body.to_string()),
        Request::Timeline { .. } => {
            Response::Error("protocol v0 has no timeline frame".into())
        }
        Request::Hello { .. } => Response::Error("protocol v0 has no hello frame".into()),
        Request::TenantUpdate { .. } => {
            Response::Error("protocol v0 has no tenant-update frame".into())
        }
        Request::BatchStream { .. } => {
            Response::Error("protocol v0 has no stream frame".into())
        }
    }
}

impl Codec for LineCodec {
    fn version(&self) -> u8 {
        0
    }

    fn read_request(&mut self, r: &mut dyn BufRead) -> std::io::Result<Decoded> {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            return Ok(Decoded::Eof);
        }
        Ok(parse_line(&line))
    }

    fn write_response(&mut self, w: &mut dyn Write, resp: &Response) -> std::io::Result<()> {
        writeln!(w, "{}", format_response(resp))?;
        w.flush()
    }

    fn write_request(&mut self, w: &mut dyn Write, req: &Request) -> std::io::Result<()> {
        match format_request(req) {
            Ok(s) => {
                writeln!(w, "{s}")?;
                w.flush()
            }
            Err(e) => Err(std::io::Error::new(std::io::ErrorKind::InvalidInput, e)),
        }
    }

    fn read_response(
        &mut self,
        r: &mut dyn BufRead,
        expect: &Request,
    ) -> std::io::Result<Option<Response>> {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        Ok(Some(parse_response(line.trim_end(), expect)))
    }

    fn write_quit(&mut self, w: &mut dyn Write) -> std::io::Result<()> {
        writeln!(w, "QUIT")?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: &str) -> Request {
        match parse_line(line) {
            Decoded::Request(r) => r,
            other => panic!("'{line}' did not parse as a request: {other:?}"),
        }
    }

    #[test]
    fn commands_parse_to_typed_requests() {
        assert_eq!(req("PING"), Request::Ping);
        assert_eq!(req("stats"), Request::Stats);
        assert_eq!(req("DRAIN 3"), Request::Drain { die: 3 });
        assert_eq!(
            req("CLASSIFY 0.5,-0.25"),
            Request::Predict { tenant: None, features: vec![0.5, -0.25] }
        );
        assert_eq!(
            req("PREDICT bright 1,0"),
            Request::Predict { tenant: Some("bright".into()), features: vec![1.0, 0.0] }
        );
        assert_eq!(
            req("REGISTER a digits 7"),
            Request::Register { name: "a".into(), dataset: "digits".into(), seed: 7 }
        );
        assert_eq!(
            req("REGISTER a digits"),
            Request::Register { name: "a".into(), dataset: "digits".into(), seed: 1 }
        );
        assert_eq!(req("UNREGISTER a"), Request::Unregister { name: "a".into() });
        assert_eq!(req("TRACE"), Request::Trace { last: 32 });
        assert_eq!(req("trace 5"), Request::Trace { last: 5 });
        assert_eq!(req("GOVERNOR"), Request::Governor);
        assert!(matches!(parse_line("QUIT"), Decoded::Quit));
    }

    #[test]
    fn governor_verb_roundtrips_on_v0() {
        assert_eq!(format_request(&Request::Governor).unwrap(), "GOVERNOR");
        assert_eq!(
            format_response(&Response::Governor("governor off".into())),
            "OK governor off"
        );
        assert_eq!(
            parse_response("OK governor off", &Request::Governor),
            Response::Governor("governor off".into())
        );
    }

    #[test]
    fn trace_verb_is_display_only_on_v0() {
        match parse_line("TRACE nope") {
            Decoded::Malformed(msg) => {
                assert_eq!(msg, "TRACE wants an entry count, got 'nope'")
            }
            other => panic!("expected malformed, got {other:?}"),
        }
        // the reply stays one line: entries joined with " | "
        use super::super::stats::{TraceEntry, TraceOutcome};
        let entry = |id| TraceEntry {
            id,
            tenant: None,
            die: 0,
            pjrt: false,
            passes: 1,
            queue_us: 1,
            batch_us: 2,
            compute_us: 3,
            total_us: 6,
            outcome: TraceOutcome::Ok,
        };
        let line = format_response(&Response::Trace(vec![entry(1), entry(2)]));
        assert!(line.starts_with("OK trace id=1 "), "{line}");
        assert!(line.contains(" | id=2 "), "{line}");
        assert!(!line.contains('\n'), "v0 replies must stay one line");
        assert_eq!(format_response(&Response::Trace(vec![])), "OK trace empty");
        // typed spellings the v0 grammar cannot carry
        assert_eq!(
            format_response(&Response::Snapshot(Default::default())),
            "ERR snapshot responses need the v1 framed protocol"
        );
        assert_eq!(format_request(&Request::Trace { last: 8 }).unwrap(), "TRACE 8");
        assert!(format_request(&Request::Snapshot).is_err());
        // the timeline profiler is v1-only on every surface
        assert!(format_request(&Request::Timeline { last: 8 }).is_err());
        assert_eq!(
            format_response(&Response::Timeline(vec![])),
            "ERR timeline responses need the v1 framed protocol"
        );
        assert!(matches!(
            parse_response("OK whatever", &Request::Timeline { last: 8 }),
            Response::Error(_)
        ));
        assert!(matches!(
            parse_response("OK trace empty", &Request::Trace { last: 8 }),
            Response::Error(_)
        ));
        assert!(matches!(
            parse_response("OK whatever", &Request::Snapshot),
            Response::Error(_)
        ));
    }

    #[test]
    fn malformed_commands_answer_their_usage_line() {
        // the empty-feature-list bugfix: usage, not a float-parse error
        for (line, want) in [
            ("CLASSIFY", "CLASSIFY wants: CLASSIFY x1,x2,..."),
            ("CLASSIFY   ", "CLASSIFY wants: CLASSIFY x1,x2,..."),
            ("PREDICT", "PREDICT wants: PREDICT <tenant> x1,x2,..."),
            ("PREDICT bright", "PREDICT wants: PREDICT <tenant> x1,x2,..."),
            ("PREDICT bright  ", "PREDICT wants: PREDICT <tenant> x1,x2,..."),
            ("REGISTER solo", "REGISTER wants: REGISTER <name> <dataset> [seed]"),
            ("UNREGISTER", "UNREGISTER wants a tenant name"),
            ("DRAIN abc", "DRAIN wants a die index, got 'abc'"),
            ("", "empty command"),
        ] {
            match parse_line(line) {
                Decoded::Malformed(msg) => assert_eq!(msg, want, "for '{line}'"),
                other => panic!("'{line}' should be malformed, got {other:?}"),
            }
        }
        // genuinely bad features keep the parse diagnostic
        match parse_line("CLASSIFY 0.1,bogus") {
            Decoded::Malformed(msg) => assert!(msg.starts_with("bad features:"), "{msg}"),
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn responses_format_to_the_historic_strings() {
        assert_eq!(format_response(&Response::Pong), "OK pong");
        assert_eq!(format_response(&Response::Stats("requests=1".into())), "OK requests=1");
        assert_eq!(format_response(&Response::Draining { die: 2 }), "OK draining die 2");
        assert_eq!(
            format_response(&Response::Predict(Prediction {
                label: -1,
                score: 0.5,
                tenant: None
            })),
            "OK -1 0.500000"
        );
        assert_eq!(
            format_response(&Response::Registered {
                name: "a".into(),
                task: "regression".into(),
                score: 0.0625
            }),
            "OK registered a (regression, mean train score 0.0625)"
        );
        assert_eq!(
            format_response(&Response::Unregistered { name: "a".into() }),
            "OK unregistered a"
        );
        assert_eq!(format_response(&Response::Error("boom".into())), "ERR boom");
    }

    #[test]
    fn client_side_request_format_and_response_parse_roundtrip() {
        let preq = Request::Predict { tenant: Some("t".into()), features: vec![0.5, -1.0] };
        assert_eq!(format_request(&preq).unwrap(), "PREDICT t 0.5,-1");
        // the formatted command re-parses to the same request (f64
        // Display is shortest-roundtrip, so features survive exactly)
        assert_eq!(req(&format_request(&preq).unwrap()), preq);
        assert!(format_request(&Request::BatchPredict { rows: vec![] }).is_err());

        let resp = parse_response("OK 1 0.250000", &preq);
        assert_eq!(
            resp,
            Response::Predict(Prediction { label: 1, score: 0.25, tenant: Some("t".into()) })
        );
        assert_eq!(parse_response("ERR nope", &preq), Response::Error("nope".into()));
        let reg = Request::Register { name: "a".into(), dataset: "digits".into(), seed: 1 };
        match parse_response("OK registered a (classification/10, mean train score 0.0312)", &reg)
        {
            Response::Registered { name, task, score } => {
                assert_eq!(name, "a");
                assert_eq!(task, "classification/10");
                assert!((score - 0.0312).abs() < 1e-12);
            }
            other => panic!("bad register parse: {other:?}"),
        }
    }

    #[test]
    fn streaming_verbs_answer_capability_errors_on_v0() {
        // the reactor-era verbs (DESIGN.md §20) are v1-only: v0 answers
        // a capability line, never a parse panic or a silent drop
        let hello = Request::Hello { token: "k".into() };
        let update = Request::TenantUpdate {
            name: "slope".into(),
            features: vec![0.5],
            targets: vec![1.0],
        };
        let stream = Request::BatchStream { rows: vec![] };
        for (req, want) in [
            (&hello, "protocol v0 has no hello frame; use the v1 framed protocol"),
            (
                &update,
                "protocol v0 has no tenant-update frame; use the v1 framed protocol",
            ),
            (&stream, "protocol v0 has no stream frame; send rows as PREDICT lines"),
        ] {
            assert_eq!(format_request(req).unwrap_err(), want);
            assert!(matches!(parse_response("OK whatever", req), Response::Error(_)));
        }
        assert_eq!(
            format_response(&Response::HelloOk { tenants: vec!["*".into()] }),
            "ERR hello responses need the v1 framed protocol"
        );
        assert_eq!(
            format_response(&Response::Updated { name: "slope".into() }),
            "ERR update responses need the v1 framed protocol"
        );
    }

    #[test]
    fn codec_io_roundtrip_over_a_buffer() {
        let mut codec = LineCodec;
        let mut buf = Vec::new();
        let req = Request::Predict { tenant: None, features: vec![0.125] };
        codec.write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }
        // EOF after the one line
        assert!(matches!(codec.read_request(&mut r).unwrap(), Decoded::Eof));
    }
}
